//! Quickstart: train a Hoeffding tree and a (local-mode) VHT on a dense
//! synthetic stream — the README's 30-second tour.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use samoa::classifiers::vht::{build_topology, VhtConfig};
use samoa::engine::LocalEngine;
use samoa::evaluation::prequential::{
    prequential_run, EvalSink, EvaluatorProcessor, PrequentialConfig,
};
use samoa::streams::random_tree::RandomTreeGenerator;
use samoa::streams::StreamSource;
use samoa::topology::Event;

fn main() {
    println!("criterion backend: {:?}", samoa::runtime::backend_in_use());

    // 1. sequential Hoeffding tree (the paper's "moa" baseline)
    let mut stream = RandomTreeGenerator::new(10, 10, 2, 42);
    let mut tree = HoeffdingTree::new(stream.schema().clone(), HTConfig::default());
    let result = prequential_run(
        &mut tree,
        &mut stream,
        &PrequentialConfig { max_instances: 100_000, report_every: 20_000 },
    );
    println!(
        "hoeffding tree : accuracy={:.3} kappa={:.3} throughput={:.0}/s leaves={}",
        result.final_accuracy(),
        result.measure.kappa(),
        result.throughput(),
        tree.n_leaves(),
    );

    // 2. the same stream through the distributed VHT topology (p = 4 local
    //    statistics processors) on the deterministic local engine
    let mut stream = RandomTreeGenerator::new(10, 10, 2, 42);
    let config = VhtConfig { parallelism: 4, ..Default::default() };
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, 20_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source = (0..100_000u64)
        .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let metrics = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    println!(
        "VHT (p=4)      : accuracy={:.3} events={} attribute-bytes={}",
        sink.accuracy(),
        metrics.total_events(),
        metrics.streams[handles.streams.attribute.0].bytes,
    );
}
