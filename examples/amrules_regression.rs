//! Regression scenario (paper §7): sequential AMRules (MAMR) vs the
//! distributed VAMR and HAMR topologies on the electricity and airlines
//! twins, reporting rules/features (Table 5 shape) and normalized errors
//! (Figs 14-16 shape).

use std::sync::Arc;

use samoa::core::model::Regressor;
use samoa::engine::LocalEngine;
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::regressors::amrules::{AMRules, AMRulesConfig};
use samoa::regressors::{hamr, vamr};
use samoa::streams::StreamSource;
use samoa::topology::Event;

fn main() {
    let n = 60_000u64;
    for ds in ["electricity", "airlines", "waveform"] {
        println!("--- {ds} ({n} instances) ---");

        // MAMR
        let mut stream = samoa::experiments::regression_stream(ds, 3, n);
        let range = stream.schema().label_range();
        let mut model = AMRules::new(stream.schema().clone(), AMRulesConfig::default());
        let mut measure = samoa::evaluation::measures::RegressionMeasure::new(range, n);
        // cap explicitly: the waveform generator is unbounded
        for _ in 0..n {
            let Some(inst) = stream.next_instance() else { break };
            if let Some(y) = inst.numeric_label() {
                measure.add(y, model.predict(&inst));
            }
            model.train(&inst);
        }
        println!(
            "MAMR   : nMAE={:.4} nRMSE={:.4} rules(created/removed/live)={}/{}/{} features={} mem={:.2}MB",
            measure.nmae(),
            measure.nrmse(),
            model.stats.rules_created,
            model.stats.rules_removed,
            model.n_rules(),
            model.stats.features_created,
            model.model_bytes() as f64 / 1e6,
        );

        // VAMR p=4
        let mut stream = samoa::experiments::regression_stream(ds, 3, n);
        let sink = EvalSink::new(0, range, n);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) =
            vamr::build_topology(stream.schema(), &AMRulesConfig::default(), 4, move |_| {
                Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
            });
        let source =
            (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let m = sink.regression.lock().unwrap().clone();
        println!("VAMR p4: nMAE={:.4} nRMSE={:.4}", m.nmae(), m.nrmse());

        // HAMR r=2 MAs, 2 learners
        let mut stream = samoa::experiments::regression_stream(ds, 3, n);
        let sink = EvalSink::new(0, range, n);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) =
            hamr::build_topology(stream.schema(), &AMRulesConfig::default(), 2, 2, move |_| {
                Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
            });
        let source =
            (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let m = sink.regression.lock().unwrap().clone();
        println!("HAMR r2: nMAE={:.4} nRMSE={:.4}", m.nmae(), m.nrmse());
    }
}
