//! Preprocessing pipelines in front of stream learners:
//!
//! 1. `hash → scale → discretize` feeding a prequential Hoeffding tree
//!    through the *topology* path, run on both the local and the threaded
//!    engine — the accuracies match exactly (p = 1, deterministic order).
//! 2. `hash → scale` feeding the distributed VHT on the sparse tweet
//!    generator: feature hashing turns the 10k-word bag-of-words into a
//!    64-dim dense stream, shrinking VHT's attribute fan-out.
//!
//! ```bash
//! cargo run --release --example pipeline_preprocessing
//! ```

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use samoa::classifiers::vht::{build_topology, VhtConfig};
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::preprocess::processor::build_prequential_topology;
use samoa::preprocess::{Discretizer, FeatureHasher, Pipeline, StandardScaler};
use samoa::streams::random_tweet::RandomTweetGenerator;
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::{StreamSource, StreamSourceExt};
use samoa::topology::Event;

const N: u64 = 30_000;

fn make_pipeline() -> Pipeline {
    Pipeline::new()
        .then(FeatureHasher::new(16))
        .then(StandardScaler::new())
        .then(Discretizer::new(8))
}

/// Part 1: the same preprocessed prequential task on two engines.
fn ht_on_two_engines() {
    for threaded in [false, true] {
        let mut stream = WaveformGenerator::classification(42);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, N);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_prequential_topology(
            &schema,
            1,
            |_| make_pipeline(),
            |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let source = (0..N)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let started = std::time::Instant::now();
        let m = if threaded {
            ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {})
        } else {
            LocalEngine::new().run(&topo, handles.entry, source, |_| {})
        };
        println!(
            "hash:16,scale,discretize:8 | HT | {:<8} engine : accuracy={:.4} wall={:.2}s events={}",
            if threaded { "threaded" } else { "local" },
            sink.accuracy(),
            started.elapsed().as_secs_f64(),
            m.total_events(),
        );
    }
    println!("(identical accuracy on both engines — same order, same statistics)\n");
}

/// Part 2: hasher → scaler in front of the distributed VHT on tweets.
fn vht_on_hashed_tweets() {
    let source = RandomTweetGenerator::new(10_000, 42);
    let mut ts = source
        .pipe(Pipeline::new().then(FeatureHasher::new(64)).then(StandardScaler::new()));
    let schema = ts.schema().clone();

    let config = VhtConfig { parallelism: 4, ..Default::default() };
    let sink = EvalSink::new(schema.n_classes(), 1.0, N);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(&schema, &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source =
        (0..N).map_while(|id| ts.next_instance().map(|inst| Event::Instance { id, inst }));
    let metrics = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    println!(
        "hash:64,scale | VHT p=4 on 10k-word tweets: accuracy={:.4} instances={} attr-bytes={}",
        sink.accuracy(),
        metrics.source_instances,
        metrics.streams[handles.streams.attribute.0].bytes,
    );
}

fn main() {
    println!("== preprocessing pipelines ==\n");
    ht_on_two_engines();
    vht_on_hashed_tweets();
}
