//! CluStream (paper §5): online micro-clusters + periodic macro k-means
//! over an evolving stream of Gaussian blobs, with the nearest-centroid
//! assignment running through the XLA `cluster` artifact (MXU-mapped
//! distance matmul) when artifacts are built.

use samoa::clustering::clustream::{CluStream, CluStreamConfig};
use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::core::Schema;

fn main() {
    println!("backend: {:?}", samoa::runtime::backend_in_use());
    let d = 16usize;
    let schema = Schema::classification("blobs", Schema::all_numeric(d), 2);
    let config =
        CluStreamConfig { max_micro: 60, k: 4, macro_period: 20_000, ..Default::default() };
    let mut cs = CluStream::new(&schema, config, 99);
    let mut rng = Rng::new(7);

    // four blobs; one drifts after half the stream
    let centers = [0.0f32, 8.0, 16.0, 24.0];
    let n = 120_000;
    for i in 0..n {
        let b = i % 4;
        let drift = if b == 3 && i > n / 2 { 10.0 } else { 0.0 };
        let vals: Vec<f32> =
            (0..d).map(|_| centers[b] + drift + 0.5 * rng.gaussian() as f32).collect();
        cs.add(&Instance::dense(vals, Label::None));
    }
    cs.flush();
    cs.run_macro();

    println!(
        "instances={n} micro-clusters={} macro-runs={} memory={:.2}MB",
        cs.n_micro(),
        cs.macro_runs,
        cs.mem_bytes() as f64 / 1e6
    );
    println!("macro centroids (mean of coords):");
    for (i, c) in cs.macro_centers.chunks(d).enumerate() {
        let m: f32 = c.iter().sum::<f32>() / d as f32;
        println!("  k{i}: {m:.2}");
    }
    let radii: Vec<String> =
        cs.micro_clusters().iter().take(8).map(|m| format!("{:.2}", m.radius())).collect();
    println!("first micro-cluster radii: {radii:?}");
}
