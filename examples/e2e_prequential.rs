//! END-TO-END driver: the full three-layer system on a real small
//! workload, proving all layers compose (EXPERIMENTS.md §E2E).
//!
//! Pipeline: covtype twin (581k × 54, 7 classes; or the real
//! `data/covtype.arff` if present) → VHT topology (1 MA + 4 LS + evaluator)
//! on the **threaded engine** with real queues/backpressure; the LS split
//! criterion runs through the **AOT XLA artifact** compiled from the
//! Pallas kernel (or the native twin if artifacts are absent). Reports the
//! paper's headline metrics: accuracy, throughput, per-stream traffic,
//! model memory.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_prequential [-- n]
//! ```

use std::sync::Arc;
use std::time::Instant;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree, LeafPrediction};
use samoa::classifiers::vht::{build_topology, SplitBuffering, VhtConfig};
use samoa::core::model::Classifier;
use samoa::engine::ThreadedEngine;
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::experiments::dataset_stream;
use samoa::streams::StreamSource;
use samoa::topology::Event;

fn main() {
    let n: u64 = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(150_000);
    println!("=== samoa-rs end-to-end prequential run ===");
    println!("backend: {:?} (artifacts: {:?})", samoa::runtime::backend_in_use(),
        samoa::runtime::registry::artifacts_dir());

    // --- baseline: sequential tree ("moa" row)
    let mut stream = dataset_stream("covtype", 42);
    let mut tree = HoeffdingTree::new(
        stream.schema().clone(),
        HTConfig { leaf_prediction: LeafPrediction::MajorityClass, ..Default::default() },
    );
    let started = Instant::now();
    let mut correct = 0u64;
    for _ in 0..n {
        let Some(inst) = stream.next_instance() else { break };
        if tree.predict(&inst) == inst.class() {
            correct += 1;
        }
        tree.train(&inst);
    }
    let moa_wall = started.elapsed().as_secs_f64();
    println!(
        "moa      : acc={:.3} wall={:.2}s throughput={:.0}/s model={:.2}MB",
        correct as f64 / n as f64,
        moa_wall,
        n as f64 / moa_wall,
        tree.model_bytes() as f64 / 1e6
    );

    // --- distributed VHT wok p=4, threaded engine
    for (label, buffering) in [
        ("VHT wok  (p=4)", SplitBuffering::Discard),
        ("VHT wk(10k) p=4", SplitBuffering::Buffer(10_000)),
    ] {
        let mut stream = dataset_stream("covtype", 42);
        let config = VhtConfig { parallelism: 4, buffering, ..Default::default() };
        let sink = EvalSink::new(stream.schema().n_classes(), 1.0, n / 5);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
        let source =
            (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let started = Instant::now();
        let mut ls_bytes = 0usize;
        let mut ma_bytes = 0usize;
        let metrics = ThreadedEngine::default().run(&topo, handles.entry, source, |pid, _, p| {
            if pid == handles.ma.0 {
                ma_bytes += p.mem_bytes();
            } else if pid == handles.ls.0 {
                ls_bytes += p.mem_bytes();
            }
        });
        let wall = started.elapsed().as_secs_f64();
        println!(
            "{label}: acc={:.3} wall={:.2}s throughput={:.0}/s ma={:.2}MB ls(total)={:.2}MB",
            sink.accuracy(),
            wall,
            metrics.source_instances as f64 / wall,
            ma_bytes as f64 / 1e6,
            ls_bytes as f64 / 1e6,
        );
        println!(
            "          accuracy curve: {:?}",
            sink.classification
                .lock()
                .unwrap()
                .curve
                .iter()
                .map(|(at, a)| format!("{}k:{:.3}", at / 1000, a))
                .collect::<Vec<_>>()
        );
        println!(
            "          traffic: instances={} attributes={} ({} KB) compute={} local-result={} drop={}",
            metrics.streams[0].events,
            metrics.streams[handles.streams.attribute.0].events,
            metrics.streams[handles.streams.attribute.0].bytes / 1024,
            metrics.streams[handles.streams.compute.0].events,
            metrics.streams[handles.streams.local_result.0].events,
            metrics.streams[handles.streams.drop_leaf.0].events,
        );
    }
    println!("=== done ===");
}
