//! Sparse scenario (paper §6.3, Figs 5/7/9): VHT over the random-tweet
//! bag-of-words stream — vertical parallelism only ships the ~15 non-zero
//! attributes per instance, which is what makes high-dimensional sparse
//! streams cheap for VHT and fatal for sharding's per-shard full models.

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree, LeafPrediction};
use samoa::classifiers::sharding::Sharding;
use samoa::classifiers::vht::{build_topology, VhtConfig};
use samoa::core::model::Classifier;
use samoa::engine::LocalEngine;
use samoa::evaluation::prequential::{
    prequential_run, EvalSink, EvaluatorProcessor, PrequentialConfig,
};
use samoa::streams::random_tweet::RandomTweetGenerator;
use samoa::streams::StreamSource;
use samoa::topology::Event;

fn main() {
    let dims = [100u32, 1000, 10_000];
    let n = 100_000u64;
    println!("| dim | algorithm | accuracy | model MB |");
    println!("|---|---|---|---|");
    for dim in dims {
        // VHT sparse, p=4
        let mut stream = RandomTweetGenerator::new(dim, 7);
        let config = VhtConfig { parallelism: 4, sparse: true, ..Default::default() };
        let sink = EvalSink::new(2, 1.0, n);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
        let source =
            (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let mut ls_bytes = 0;
        LocalEngine::new().run(&topo, handles.entry, source, |inst| {
            ls_bytes = inst[handles.ls.0].iter().map(|p| p.mem_bytes()).sum::<usize>();
        });
        println!("| {dim} | VHT wok p=4 | {:.3} | {:.2} |", sink.accuracy(), ls_bytes as f64 / 1e6);

        // sharding baseline: p full models
        let mut stream = RandomTweetGenerator::new(dim, 7);
        let mut sharding = Sharding::new(
            stream.schema().clone(),
            HTConfig {
                sparse: true,
                leaf_prediction: LeafPrediction::MajorityClass,
                ..Default::default()
            },
            4,
        );
        let r = prequential_run(
            &mut sharding,
            &mut stream,
            &PrequentialConfig { max_instances: n, report_every: n },
        );
        println!(
            "| {dim} | sharding p=4 | {:.3} | {:.2} |",
            r.final_accuracy(),
            r.model_bytes as f64 / 1e6
        );
    }
}
