#!/usr/bin/env python3
"""Unit tests for the perf-trajectory gate (tools/bench_compare.py).

Run directly (no pytest in the image):

    python3 tools/test_bench_compare.py

Covers the two boundary states the gate must not error on:
  * an empty (or missing) baseline dir — "no baseline, seeding", exit 0;
  * a single committed baseline file — trajectory table with one PR
    column, the regression gate armed against it;
plus the multi-prefix gate ("tput/,kern/,clu/,fig/") that CI uses once
the kernel, cluster data-plane and VHT-scaling benches joined the
trajectory.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_compare.py")


def write_current(path, rate, kern_rate=None, clu_rate=None, fig_rate=None):
    rows = [
        {"name": "tput/engine_throughput", "items_per_s": rate},
        {"name": "other/ignored", "items_per_s": 1.0},
        {"name": "tput/no_rate_row"},
    ]
    if kern_rate is not None:
        rows.append({"name": "kern/infogain_simd_a256", "items_per_s": kern_rate})
    if clu_rate is not None:
        rows.append({"name": "clu/relay w=2 peer-det", "items_per_s": clu_rate})
    if fig_rate is not None:
        rows.append({"name": "fig/vht_wok p=4", "items_per_s": fig_rate})
    with open(path, "w", encoding="utf-8") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")


def write_baseline(dirpath, pr, rate, kern_rate=None, clu_rate=None, fig_rate=None):
    results = [{"name": "tput/engine_throughput", "items_per_s": rate}]
    if kern_rate is not None:
        results.append({"name": "kern/infogain_simd_a256", "items_per_s": kern_rate})
    if clu_rate is not None:
        results.append({"name": "clu/relay w=2 peer-det", "items_per_s": clu_rate})
    if fig_rate is not None:
        results.append({"name": "fig/vht_wok p=4", "items_per_s": fig_rate})
    doc = {"results": results}
    with open(os.path.join(dirpath, f"BENCH_PR{pr}.json"), "w", encoding="utf-8") as fh:
        json.dump(doc, fh)


def run_gate(current, baseline_dir, *extra):
    cmd = [sys.executable, SCRIPT, "--current", current, "--baseline-dir", baseline_dir]
    cmd += list(extra)
    return subprocess.run(cmd, capture_output=True, text=True)


class EmptyTrajectory(unittest.TestCase):
    def test_empty_baseline_dir_seeds_and_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("no baseline, seeding", res.stdout)

    def test_missing_baseline_dir_seeds_and_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6)
            res = run_gate(current, os.path.join(td, "does-not-exist"))
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("no baseline, seeding", res.stdout)


class SingleBaseline(unittest.TestCase):
    def test_within_threshold_passes_with_trajectory_table(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 0.95e6)  # -5% vs baseline: inside the 15% gate
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 5, 1e6)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("PR5", res.stdout)
            self.assertIn("tput/engine_throughput", res.stdout)
            self.assertNotIn("REGRESSION", res.stdout)

    def test_regression_fails_and_soft_mode_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 0.5e6)  # -50%: well past the 15% gate
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 5, 1e6)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
            self.assertIn("REGRESSION", res.stdout)
            soft = run_gate(current, perf, "--soft")
            self.assertEqual(soft.returncode, 0, soft.stdout + soft.stderr)


class MultiPrefix(unittest.TestCase):
    def test_kern_rows_gated_only_with_multi_prefix(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            # tput healthy, kern collapsed to -50%
            write_current(current, 1e6, kern_rate=0.5e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 7, 1e6, kern_rate=1e6)
            # default single prefix: the kern regression is invisible
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertNotIn("kern/infogain_simd_a256", res.stdout)
            # multi prefix (what CI passes): the kern regression fails the gate
            res = run_gate(current, perf, "--prefix", "tput/,kern/")
            self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
            self.assertIn("kern/infogain_simd_a256", res.stdout)
            self.assertIn("REGRESSION", res.stdout)

    def test_multi_prefix_all_healthy_passes_and_tabulates_both(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6, kern_rate=2e6)  # kern improved
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 7, 1e6, kern_rate=1e6)
            res = run_gate(current, perf, "--prefix", "tput/,kern/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("tput/engine_throughput", res.stdout)
            self.assertIn("kern/infogain_simd_a256", res.stdout)
            self.assertNotIn("REGRESSION", res.stdout)

    def test_clu_rows_gated_only_with_clu_prefix(self):
        # the cluster data-plane rows (clu/) gate exactly like tput/kern
        # once CI's prefix list includes them — and not before
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            # tput healthy, peer plane collapsed to -50%
            write_current(current, 1e6, clu_rate=0.5e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 9, 1e6, clu_rate=1e6)
            res = run_gate(current, perf, "--prefix", "tput/,kern/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertNotIn("clu/relay w=2 peer-det", res.stdout)
            res = run_gate(current, perf, "--prefix", "tput/,kern/,clu/")
            self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
            self.assertIn("clu/relay w=2 peer-det", res.stdout)
            self.assertIn("REGRESSION", res.stdout)

    def test_clu_row_missing_from_baseline_is_not_an_error(self):
        # first run after the peer-plane benches land: baseline predates clu/
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6, clu_rate=1e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 9, 1e6)  # no clu rows yet
            res = run_gate(current, perf, "--prefix", "tput/,kern/,clu/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)

    def test_fig_rows_gated_only_with_fig_prefix(self):
        # the VHT-scaling rows (fig/) gate exactly like tput/kern/clu
        # once CI's prefix list includes them — and not before
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            # tput healthy, scaling bench collapsed to -50%
            write_current(current, 1e6, fig_rate=0.5e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 10, 1e6, fig_rate=1e6)
            res = run_gate(current, perf, "--prefix", "tput/,kern/,clu/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertNotIn("fig/vht_wok p=4", res.stdout)
            res = run_gate(current, perf, "--prefix", "tput/,kern/,clu/,fig/")
            self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
            self.assertIn("fig/vht_wok p=4", res.stdout)
            self.assertIn("REGRESSION", res.stdout)

    def test_fig_row_missing_from_baseline_is_not_an_error(self):
        # first run after the fig benches land: baseline predates fig/
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6, fig_rate=1e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 10, 1e6)  # no fig rows yet
            res = run_gate(current, perf, "--prefix", "tput/,kern/,clu/,fig/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)

    def test_kern_row_missing_from_baseline_is_not_an_error(self):
        # first run after the kernel benches land: baseline predates kern/
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6, kern_rate=1e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 7, 1e6)  # no kern rows yet
            res = run_gate(current, perf, "--prefix", "tput/,kern/")
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)


if __name__ == "__main__":
    unittest.main()
