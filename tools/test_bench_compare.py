#!/usr/bin/env python3
"""Unit tests for the perf-trajectory gate (tools/bench_compare.py).

Run directly (no pytest in the image):

    python3 tools/test_bench_compare.py

Covers the two boundary states the gate must not error on:
  * an empty (or missing) baseline dir — "no baseline, seeding", exit 0;
  * a single committed baseline file — trajectory table with one PR
    column, the regression gate armed against it.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_compare.py")


def write_current(path, rate):
    rows = [
        {"name": "tput/engine_throughput", "items_per_s": rate},
        {"name": "other/ignored", "items_per_s": 1.0},
        {"name": "tput/no_rate_row"},
    ]
    with open(path, "w", encoding="utf-8") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")


def write_baseline(dirpath, pr, rate):
    doc = {"results": [{"name": "tput/engine_throughput", "items_per_s": rate}]}
    with open(os.path.join(dirpath, f"BENCH_PR{pr}.json"), "w", encoding="utf-8") as fh:
        json.dump(doc, fh)


def run_gate(current, baseline_dir, *extra):
    cmd = [sys.executable, SCRIPT, "--current", current, "--baseline-dir", baseline_dir]
    cmd += list(extra)
    return subprocess.run(cmd, capture_output=True, text=True)


class EmptyTrajectory(unittest.TestCase):
    def test_empty_baseline_dir_seeds_and_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6)
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("no baseline, seeding", res.stdout)

    def test_missing_baseline_dir_seeds_and_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 1e6)
            res = run_gate(current, os.path.join(td, "does-not-exist"))
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("no baseline, seeding", res.stdout)


class SingleBaseline(unittest.TestCase):
    def test_within_threshold_passes_with_trajectory_table(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 0.95e6)  # -5% vs baseline: inside the 15% gate
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 5, 1e6)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
            self.assertIn("PR5", res.stdout)
            self.assertIn("tput/engine_throughput", res.stdout)
            self.assertNotIn("REGRESSION", res.stdout)

    def test_regression_fails_and_soft_mode_passes(self):
        with tempfile.TemporaryDirectory() as td:
            current = os.path.join(td, "bench.jsonl")
            write_current(current, 0.5e6)  # -50%: well past the 15% gate
            perf = os.path.join(td, "perf")
            os.mkdir(perf)
            write_baseline(perf, 5, 1e6)
            res = run_gate(current, perf)
            self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
            self.assertIn("REGRESSION", res.stdout)
            soft = run_gate(current, perf, "--soft")
            self.assertEqual(soft.returncode, 0, soft.stdout + soft.stderr)


if __name__ == "__main__":
    unittest.main()
