#!/usr/bin/env python3
"""Perf-trajectory gate: diff the current bench-smoke run against the
committed baseline history in perf/BENCH_PR<k>.json.

CI calls this after the smoke benches wrote their JSONL rows:

    python3 tools/bench_compare.py \
        --current bench_results.jsonl --baseline-dir perf \
        --prefix tput/ --max-regress 0.15 --summary "$GITHUB_STEP_SUMMARY"

Behavior:
  * the latest committed BENCH_PR<k>.json (highest k) is the baseline;
  * rows are matched by exact bench name, filtered to --prefix — a
    comma-separated list of name prefixes (engine_throughput's tput/
    rows, the kernel benches' kern/ rows) — and to rows that carry
    items_per_s;
  * a row regressing by more than --max-regress (relative items/s)
    fails the job, listing every offender;
  * a trajectory table (every committed file + the current run) is
    printed, and appended to --summary when given (the GitHub job
    summary);
  * no committed baselines yet -> pass with a note (the trajectory is
    seeded by the auto-commit step on the next main push).

Smoke-mode numbers are single-rep and noisy; the 15% default gate is
deliberately loose — it catches collapses (a lost fast path, an
accidental O(n^2)), not 2% drifts.
"""

import argparse
import glob
import json
import os
import re
import sys


def load_jsonl(path):
    rows = {}
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue
            if "name" in row:
                rows[row["name"]] = row
    return rows


def load_baselines(baseline_dir):
    """[(pr_number, path, {name: row})] sorted by PR number."""
    out = []
    for path in glob.glob(os.path.join(baseline_dir, "BENCH_PR*.json")):
        m = re.search(r"BENCH_PR(\d+)\.json$", os.path.basename(path))
        if not m:
            continue
        try:
            with open(path, encoding="utf-8") as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: unreadable baseline {path}: {e}", file=sys.stderr)
            continue
        rows = {r["name"]: r for r in doc.get("results", []) if "name" in r}
        out.append((int(m.group(1)), path, rows))
    out.sort(key=lambda t: t[0])
    return out


def fmt_rate(v):
    if v is None:
        return "-"
    if v >= 1e6:
        return f"{v / 1e6:.2f}M"
    if v >= 1e3:
        return f"{v / 1e3:.1f}k"
    return f"{v:.0f}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True, help="bench JSONL of this run")
    ap.add_argument("--baseline-dir", default="perf")
    ap.add_argument("--prefix", default="tput/",
                    help="gate rows whose name starts with any of these "
                         "comma-separated prefixes (e.g. 'tput/,kern/')")
    ap.add_argument("--max-regress", type=float, default=0.15)
    ap.add_argument("--summary", default=None, help="markdown summary file to append to")
    ap.add_argument("--soft", action="store_true",
                    help="report regressions but always exit 0 (main-branch "
                         "trajectory recording must not be blocked by an "
                         "already-accepted regression)")
    args = ap.parse_args()

    prefixes = tuple(p for p in args.prefix.split(",") if p)
    current = load_jsonl(args.current)
    gated = {
        name: row
        for name, row in current.items()
        if name.startswith(prefixes) and isinstance(row.get("items_per_s"), (int, float))
    }
    baselines = load_baselines(args.baseline_dir)

    lines = ["## Perf trajectory", ""]
    regressions = []
    if not baselines:
        msg = (
            f"no baseline, seeding: {args.baseline_dir}/ holds no committed "
            "BENCH_PR<k>.json yet — gate passes; the trajectory is seeded "
            "when this run's BENCH_PR<k>.json is committed on the main branch."
        )
        print(msg)
        lines.append(msg)
    else:
        pr, path, base_rows = baselines[-1]
        print(f"baseline: {path} (PR {pr}); gating {len(gated)} '{args.prefix}' rows "
              f"at -{args.max_regress:.0%}")

        # trajectory table: the last few committed PRs + current (CI also
        # prunes perf/ to a window; cap the columns so the summary stays
        # readable regardless)
        shown = baselines[-8:]
        cols = [f"PR{p}" for p, _, _ in shown] + ["current"]
        lines.append("| bench | " + " | ".join(cols) + " |")
        lines.append("|---|" + "---|" * len(cols))
        for name in sorted(gated):
            cells = []
            for _, _, rows in shown:
                cells.append(fmt_rate(rows.get(name, {}).get("items_per_s")))
            cells.append(fmt_rate(gated[name]["items_per_s"]))
            lines.append(f"| {name} | " + " | ".join(cells) + " |")

        for name, row in sorted(gated.items()):
            base = base_rows.get(name, {}).get("items_per_s")
            if not base:
                continue
            ratio = row["items_per_s"] / base
            status = "REGRESSION" if ratio < 1.0 - args.max_regress else "ok"
            print(f"  {name}: base={fmt_rate(base)} cur={fmt_rate(row['items_per_s'])} "
                  f"({ratio:.2f}x) {status}")
            if status == "REGRESSION":
                regressions.append((name, base, row["items_per_s"], ratio))

        if regressions:
            lines.append("")
            lines.append(f"**FAIL: {len(regressions)} row(s) regressed more than "
                         f"{args.max_regress:.0%} vs PR{pr}:**")
            for name, base, cur, ratio in regressions:
                lines.append(f"- `{name}`: {fmt_rate(base)} -> {fmt_rate(cur)} ({ratio:.2f}x)")
        else:
            lines.append("")
            lines.append(f"All {len(gated)} gated rows within {args.max_regress:.0%} of PR{pr}.")

    text = "\n".join(lines) + "\n"
    print(text)
    if args.summary:
        try:
            with open(args.summary, "a", encoding="utf-8") as fh:
                fh.write(text)
        except OSError as e:
            print(f"warning: could not write summary: {e}", file=sys.stderr)

    if regressions and args.soft:
        print("(--soft: regressions reported above, exit 0)")
    sys.exit(1 if regressions and not args.soft else 0)


if __name__ == "__main__":
    main()
