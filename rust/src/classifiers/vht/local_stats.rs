//! VHT local-statistics processor (paper Alg. 2 + Alg. 3).
//!
//! Conceptually a slice of the big distributed table indexed by
//! (leaf id, attribute id): this instance holds the counter blocks of the
//! attributes key-routed to it. On `compute` it evaluates the split
//! criterion of every attribute it tracks for the leaf — through
//! [`crate::runtime::gain`]'s batch-of-blocks entry point (native, SIMD
//! or XLA artifact, registry-selected) — and replies
//! with its local top-2 plus the winner's class distribution.

use std::sync::Arc;

use crate::common::fxhash::FxHashMap;

use crate::core::observers::CounterBlock;
use crate::runtime::gain;
use crate::topology::{Ctx, Event, Processor};

use super::VhtStreamIds;

/// One leaf's slice: attribute id → counter block.
type LeafTable = FxHashMap<u32, CounterBlock>;

/// The local-statistics processor.
pub struct LocalStats {
    n_classes: u32,
    /// Sparse mode: presence observers (V=2); absence rows derived from
    /// the class marginals carried by the `compute` event.
    sparse: bool,
    streams: VhtStreamIds,
    /// leaf id → (attr → counters); blocks created lazily at the max bin
    /// count seen so far for the attribute (MA sends bins).
    table: FxHashMap<u64, LeafTable>,
    pub computes_served: u64,
    pub attributes_seen: u64,
}

impl LocalStats {
    pub fn new(n_classes: u32, streams: VhtStreamIds) -> Self {
        Self::with_sparse(n_classes, false, streams)
    }

    pub fn with_sparse(n_classes: u32, sparse: bool, streams: VhtStreamIds) -> Self {
        LocalStats {
            n_classes,
            sparse,
            streams,
            table: FxHashMap::default(),
            computes_served: 0,
            attributes_seen: 0,
        }
    }

    #[inline]
    fn update(&mut self, leaf: u64, attr: u32, bin: u32, class: u32, weight: f32) {
        self.attributes_seen += 1;
        let n_classes = self.n_classes;
        let init_v = if self.sparse { 2 } else { 16 };
        let block = self
            .table
            .entry(leaf)
            .or_default()
            .entry(attr)
            .or_insert_with(|| CounterBlock::new(init_v.max(bin + 1), n_classes));
        if bin < block.v() {
            block.add(bin, class, weight);
        } else {
            // rare: categorical arity above initial guess — grow by rebuild
            let mut bigger = CounterBlock::new(bin + 1, n_classes);
            for v in 0..block.v() {
                for c in 0..n_classes {
                    let w = block.get(v, c);
                    if w > 0.0 {
                        bigger.add(v, c, w);
                    }
                }
            }
            bigger.add(bin, class, weight);
            *block = bigger;
        }
    }

    /// Alg. 3: compute local top-2 for `leaf` and reply.
    fn compute(&mut self, leaf: u64, seq: u32, class_counts: &[f32], ctx: &mut Ctx) {
        self.computes_served += 1;
        let reply = match self.table.get(&leaf) {
            Some(slice) if !slice.is_empty() => {
                let mut attrs: Vec<u32> = slice.keys().copied().collect();
                attrs.sort_unstable(); // determinism
                // sparse mode: materialize absence rows from the leaf's
                // class marginals (presence-only counters otherwise have
                // a single populated value and zero gain)
                let derived: Vec<CounterBlock>;
                let blocks: Vec<&CounterBlock> = if self.sparse && !class_counts.is_empty() {
                    derived = attrs
                        .iter()
                        .map(|a| {
                            let present = &slice[a];
                            let mut blk = CounterBlock::new(2, self.n_classes);
                            for c in 0..self.n_classes {
                                let p = present.get(1.min(present.v() - 1), c);
                                let absent = (class_counts
                                    .get(c as usize)
                                    .copied()
                                    .unwrap_or(0.0)
                                    - p)
                                    .max(0.0);
                                blk.add(0, c, absent);
                                blk.add(1, c, p);
                            }
                            blk
                        })
                        .collect();
                    derived.iter().collect()
                } else {
                    attrs.iter().map(|a| &slice[a]).collect()
                };
                let gains = gain::gains(&blocks);
                let (bi, best, _si, second) = gain::top2(&gains);
                let best_block = blocks[bi];
                let mut dist = Vec::with_capacity((best_block.v() * best_block.c()) as usize);
                for v in 0..best_block.v() {
                    for c in 0..best_block.c() {
                        dist.push(best_block.get(v, c));
                    }
                }
                Event::LocalResult {
                    leaf,
                    seq,
                    best_attr: attrs[bi],
                    best,
                    second_attr: attrs.get(1).copied().unwrap_or(attrs[bi]),
                    second: second.max(0.0),
                    best_dist: Arc::new(dist),
                }
            }
            // no data for this leaf here: report a null result so the MA
            // doesn't have to wait for the timeout
            _ => Event::LocalResult {
                leaf,
                seq,
                best_attr: u32::MAX,
                best: 0.0,
                second_attr: u32::MAX,
                second: 0.0,
                best_dist: Arc::new(Vec::new()),
            },
        };
        ctx.emit_any(self.streams.local_result, reply);
    }
}

impl Processor for LocalStats {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Attribute { leaf, attr, value, class, weight } => {
                self.update(leaf, attr, value as u32, class, weight);
            }
            Event::AttributeBatch { leaf, class, weight, attrs } => {
                for &(attr, bin) in attrs.iter() {
                    self.update(leaf, attr, bin as u32, class, weight);
                }
            }
            Event::Compute { leaf, seq, class_counts, .. } => {
                self.compute(leaf, seq, &class_counts, ctx)
            }
            Event::DropLeaf { leaf } => {
                self.table.remove(&leaf);
            }
            _ => {}
        }
    }

    fn mem_bytes(&self) -> usize {
        use crate::common::MemSize;
        std::mem::size_of::<Self>()
            + self
                .table
                .values()
                .map(|slice| {
                    32 + slice.values().map(|b| b.mem_bytes() + 16).sum::<usize>()
                })
                .sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "vht-local-statistics"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::StreamId;

    fn ids() -> VhtStreamIds {
        VhtStreamIds {
            attribute: StreamId(1),
            compute: StreamId(2),
            local_result: StreamId(3),
            drop_leaf: StreamId(4),
            prediction: StreamId(5),
        }
    }

    fn attr_ev(leaf: u64, attr: u32, bin: u32, class: u32) -> Event {
        Event::Attribute { leaf, attr, value: bin as f32, class, weight: 1.0 }
    }

    #[test]
    fn accumulates_and_computes_top2() {
        let mut ls = LocalStats::new(2, ids());
        let mut ctx = Ctx::new(0, 1);
        // attr 7 perfectly separates classes; attr 3 is pure noise
        // (consecutive pairs share a value but differ in class)
        for i in 0..100u32 {
            ls.process(attr_ev(5, 7, i % 2, i % 2), &mut ctx);
            ls.process(attr_ev(5, 3, (i / 2) % 4, i % 2), &mut ctx);
        }
        let compute =
            Event::Compute { leaf: 5, seq: 1, n_l: 200.0, class_counts: Arc::new(vec![]) };
        ls.process(compute, &mut ctx);
        let out = ctx.take();
        assert_eq!(out.len(), 1);
        match &out[0].2 {
            Event::LocalResult { leaf, seq, best_attr, best, second, best_dist, .. } => {
                assert_eq!((*leaf, *seq), (5, 1));
                assert_eq!(*best_attr, 7);
                assert!(*best > 0.9, "best={best}");
                assert!(*second < *best);
                assert!(!best_dist.is_empty());
            }
            other => panic!("expected LocalResult, got {other:?}"),
        }
    }

    #[test]
    fn compute_unknown_leaf_replies_null() {
        let mut ls = LocalStats::new(2, ids());
        let mut ctx = Ctx::new(0, 1);
        let compute =
            Event::Compute { leaf: 99, seq: 2, n_l: 10.0, class_counts: Arc::new(vec![]) };
        ls.process(compute, &mut ctx);
        let out = ctx.take();
        match &out[0].2 {
            Event::LocalResult { best_attr, best, .. } => {
                assert_eq!(*best_attr, u32::MAX);
                assert_eq!(*best, 0.0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn drop_releases_state() {
        let mut ls = LocalStats::new(2, ids());
        let mut ctx = Ctx::new(0, 1);
        for i in 0..50u32 {
            ls.process(attr_ev(1, 0, i % 2, i % 2), &mut ctx);
        }
        let before = ls.mem_bytes();
        ls.process(Event::DropLeaf { leaf: 1 }, &mut ctx);
        assert!(ls.mem_bytes() < before);
        assert!(ls.table.is_empty());
    }

    #[test]
    fn batch_equals_singles() {
        let mut a = LocalStats::new(2, ids());
        let mut b = LocalStats::new(2, ids());
        let mut ctx = Ctx::new(0, 1);
        for i in 0..60u32 {
            a.process(attr_ev(2, 0, i % 2, i % 2), &mut ctx);
            a.process(attr_ev(2, 1, i % 3, i % 2), &mut ctx);
            b.process(
                Event::AttributeBatch {
                    leaf: 2,
                    class: i % 2,
                    weight: 1.0,
                    attrs: Arc::new(vec![(0, (i % 2) as u8), (1, (i % 3) as u8)]),
                },
                &mut ctx,
            );
        }
        ctx.take();
        let mut ca = Ctx::new(0, 1);
        let mut cb = Ctx::new(0, 1);
        let compute =
            || Event::Compute { leaf: 2, seq: 1, n_l: 120.0, class_counts: Arc::new(vec![]) };
        a.process(compute(), &mut ca);
        b.process(compute(), &mut cb);
        let (ea, eb) = (ca.take(), cb.take());
        match (&ea[0].2, &eb[0].2) {
            (
                Event::LocalResult { best_attr: a1, best: g1, .. },
                Event::LocalResult { best_attr: a2, best: g2, .. },
            ) => {
                assert_eq!(a1, a2);
                assert!((g1 - g2).abs() < 1e-12);
            }
            _ => panic!("expected results"),
        }
    }
}
