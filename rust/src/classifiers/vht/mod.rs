//! Vertical Hoeffding Tree (paper §6): model-aggregator + local-statistics
//! processors communicating via the Table-2 content events.
//!
//! ```text
//!            instance                attribute (key: leaf+attr)
//!   source ───────────► MA ════════════════════════════► LS × p
//!                        ▲   compute (all) ────────────►
//!                        ╚══════ local-result ══════════╝
//!                        │        drop (all) ──────────►
//!                        └──► prediction ──► evaluator
//! ```
//!
//! Variants (paper §6.3): **wok** discards instances reaching a leaf with
//! an in-flight split decision; **wk(z)** buffers up to `z` and replays
//! them through the updated tree once the split resolves.

pub mod tree;
pub mod model_aggregator;
pub mod local_stats;

use crate::core::Schema;
use crate::topology::{Grouping, ProcessorId, StreamId, Topology, TopologyBuilder};

pub use local_stats::LocalStats;
pub use model_aggregator::ModelAggregator;

/// Buffering policy while a split decision is pending.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitBuffering {
    /// `wok`: discard (load shedding).
    Discard,
    /// `wk(z)`: buffer up to z instances, replay on split.
    Buffer(usize),
}

/// VHT hyperparameters.
#[derive(Clone, Debug)]
pub struct VhtConfig {
    /// LS parallelism (the paper's p).
    pub parallelism: usize,
    /// n_min grace period.
    pub grace_period: u32,
    pub delta: f64,
    pub tau: f64,
    pub buffering: SplitBuffering,
    /// Resolve a split round after this many source instances even if not
    /// all LS replied (Alg. 4 line 3, "or time out reached").
    pub timeout_instances: u32,
    /// Group attribute events per destination LS (one message per LS per
    /// instance instead of one per attribute). Semantics-preserving.
    pub batch_attributes: bool,
    /// Local-engine delivery delay on the local-result stream — models the
    /// distributed feedback latency deterministically (0 = `local` mode).
    pub feedback_delay: usize,
    /// Sparse instances: decompose only stored (non-zero) attributes and
    /// observe them as binary presence features.
    pub sparse: bool,
}

impl Default for VhtConfig {
    fn default() -> Self {
        VhtConfig {
            parallelism: 4,
            grace_period: 200,
            delta: 1e-7,
            tau: 0.05,
            buffering: SplitBuffering::Discard,
            timeout_instances: 1000,
            batch_attributes: true,
            feedback_delay: 0,
            sparse: false,
        }
    }
}

/// Compact copy of the stream ids handed to processor factories.
/// Stream declaration order in [`build_topology`] fixes these values.
#[derive(Clone, Copy, Debug)]
pub struct VhtStreamIds {
    pub attribute: StreamId,
    pub compute: StreamId,
    pub local_result: StreamId,
    pub drop_leaf: StreamId,
    pub prediction: StreamId,
}

/// Handles of an assembled VHT topology.
#[derive(Clone, Copy, Debug)]
pub struct VhtHandles {
    pub entry: StreamId,
    pub streams: VhtStreamIds,
    pub ma: ProcessorId,
    pub ls: ProcessorId,
    pub evaluator: ProcessorId,
}

/// Assemble the VHT topology (paper Fig. 2). The caller supplies the
/// evaluator factory (usually
/// [`crate::evaluation::prequential::EvaluatorProcessor`]) so the same
/// topology serves accuracy and throughput experiments.
pub fn build_topology(
    schema: &Schema,
    config: &VhtConfig,
    evaluator: impl Fn(usize) -> Box<dyn crate::topology::Processor> + 'static,
) -> (Topology, VhtHandles) {
    let mut b = TopologyBuilder::new("vht");
    let p = config.parallelism;

    let eval = b.add_processor("evaluator", 1, evaluator);
    // Stream ids by declaration order below: 0 entry, 1 attribute,
    // 2 compute, 3 local-result, 4 drop, 5 prediction.
    let ids = VhtStreamIds {
        attribute: StreamId(1),
        compute: StreamId(2),
        local_result: StreamId(3),
        drop_leaf: StreamId(4),
        prediction: StreamId(5),
    };

    let ma_cfg = config.clone();
    let schema_ma = schema.clone();
    let ma = b.add_processor("model-aggregator", 1, move |_| {
        Box::new(ModelAggregator::new(schema_ma.clone(), ma_cfg.clone(), ids))
    });
    let schema_ls = schema.clone();
    let sparse = config.sparse;
    let ls = b.add_processor("local-statistics", p, move |_| {
        Box::new(LocalStats::with_sparse(schema_ls.n_classes(), sparse, ids))
    });

    let entry = b.stream("instance", None, ma, Grouping::Shuffle);
    let attribute = if config.batch_attributes {
        b.stream("attribute", Some(ma), ls, Grouping::Direct)
    } else {
        b.stream("attribute", Some(ma), ls, Grouping::Key)
    };
    let compute = b.stream("compute", Some(ma), ls, Grouping::All);
    let local_result =
        b.stream_delayed("local-result", Some(ls), ma, Grouping::Shuffle, config.feedback_delay);
    let drop_leaf = b.stream("drop", Some(ma), ls, Grouping::All);
    let prediction = b.stream("prediction", Some(ma), eval, Grouping::Shuffle);

    debug_assert_eq!(attribute, ids.attribute);
    debug_assert_eq!(compute, ids.compute);
    debug_assert_eq!(local_result, ids.local_result);
    debug_assert_eq!(drop_leaf, ids.drop_leaf);
    debug_assert_eq!(prediction, ids.prediction);

    let topo = b.build();
    (topo, VhtHandles { entry, streams: ids, ma, ls, evaluator: eval })
}
