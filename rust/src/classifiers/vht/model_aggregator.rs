//! VHT model aggregator (paper Alg. 1 + Alg. 4).
//!
//! Receives instances, predicts + trains (prequential), decomposes labeled
//! instances into attribute events for the local statistics, coordinates
//! split rounds (compute → local-result → split/drop), and applies the
//! wok / wk(z) policy to instances that reach a leaf with an in-flight
//! decision.

use std::sync::Arc;

use crate::core::hoeffding::{hoeffding_bound, infogain_range, should_split};
use crate::core::instance::{Instance, Label};
use crate::core::Schema;
use crate::topology::stream::{hash64, leaf_attr_key};
use crate::topology::{Ctx, Event, Output, Processor};

use super::tree::{MaTree, PendingSplit};
use super::{SplitBuffering, VhtConfig, VhtStreamIds};

/// Statistics the experiments read back from the MA after a run.
#[derive(Clone, Debug, Default)]
pub struct MaStats {
    pub instances: u64,
    pub shed: u64,
    pub buffered_replayed: u64,
    pub splits: u64,
    pub split_rounds: u64,
    pub timeouts: u64,
}

/// The model-aggregator processor (parallelism 1; the paper disables model
/// replication in its experiments, as do we).
pub struct ModelAggregator {
    tree: MaTree,
    config: VhtConfig,
    streams: VhtStreamIds,
    seq: u32,
    pub stats: MaStats,
    /// Reusable per-destination batch buffers (perf: no alloc per event).
    batches: Vec<Vec<(u32, u8)>>,
}

impl ModelAggregator {
    pub fn new(schema: Schema, config: VhtConfig, streams: VhtStreamIds) -> Self {
        let p = config.parallelism;
        let mut tree = MaTree::new(schema);
        tree.sparse = config.sparse;
        ModelAggregator {
            tree,
            config,
            streams,
            seq: 0,
            stats: MaStats::default(),
            batches: vec![Vec::new(); p],
        }
    }

    pub fn tree(&self) -> &MaTree {
        &self.tree
    }

    /// Predict with the current tree (majority class at the sorted leaf —
    /// the MA holds no attribute observers, per the vertical design).
    fn predict(&self, inst: &Instance) -> Output {
        let node = self.tree.sort(inst);
        match self.tree.leaf(node).majority() {
            Some(c) => Output::Class(c),
            None => Output::None,
        }
    }

    /// Decompose a labeled instance into attribute events (Alg. 1 line 2).
    fn send_attributes(&mut self, leaf_id: u64, inst: &Instance, class: u32, ctx: &mut Ctx) {
        let w = inst.weight;
        if self.config.batch_attributes {
            let p = self.config.parallelism;
            for b in self.batches.iter_mut() {
                b.clear();
            }
            if self.config.sparse {
                for (a, v) in inst.iter_stored() {
                    if v != 0.0 {
                        let dest = (hash64(leaf_attr_key(leaf_id, a as u32)) as usize) % p;
                        self.batches[dest].push((a as u32, 1));
                    }
                }
            } else {
                for (a, v) in inst.iter_stored() {
                    let bin = self.tree.bin_observe(a, v) as u8;
                    let dest = (hash64(leaf_attr_key(leaf_id, a as u32)) as usize) % p;
                    self.batches[dest].push((a as u32, bin));
                }
            }
            for (dest, batch) in self.batches.iter_mut().enumerate() {
                if !batch.is_empty() {
                    ctx.emit(
                        self.streams.attribute,
                        dest as u64,
                        Event::AttributeBatch {
                            leaf: leaf_id,
                            class,
                            weight: w,
                            attrs: Arc::new(std::mem::take(batch)),
                        },
                    );
                }
            }
        } else if self.config.sparse {
            for (a, v) in inst.iter_stored() {
                if v != 0.0 {
                    ctx.emit(
                        self.streams.attribute,
                        leaf_attr_key(leaf_id, a as u32),
                        Event::Attribute {
                            leaf: leaf_id,
                            attr: a as u32,
                            value: 1.0,
                            class,
                            weight: w,
                        },
                    );
                }
            }
        } else {
            for (a, v) in inst.iter_stored() {
                let bin = self.tree.bin_observe(a, v);
                ctx.emit(
                    self.streams.attribute,
                    leaf_attr_key(leaf_id, a as u32),
                    Event::Attribute {
                        leaf: leaf_id,
                        attr: a as u32,
                        value: bin as f32,
                        class,
                        weight: w,
                    },
                );
            }
        }
    }

    /// Train on one labeled instance: update the sorted leaf, ship the
    /// attributes, maybe open a split round (Alg. 1 lines 3-7).
    fn train(&mut self, inst: &Instance, class: u32, ctx: &mut Ctx) {
        let node = self.tree.sort(inst);
        let leaf_id = self.tree.leaf_id(node);

        // wok / wk(z): leaf has an in-flight split decision
        if self.tree.leaf(node).pending.is_some() {
            let pending = self.tree.leaf_mut(node).pending.as_mut().unwrap();
            match self.config.buffering {
                SplitBuffering::Discard => {
                    pending.shed += 1;
                    self.stats.shed += 1;
                }
                SplitBuffering::Buffer(z) => {
                    if pending.buffer.len() < z {
                        pending.buffer.push(inst.clone());
                    } else {
                        pending.shed += 1;
                        self.stats.shed += 1;
                    }
                }
            }
            return;
        }

        let w = inst.weight as f64;
        {
            let leaf = self.tree.leaf_mut(node);
            leaf.class_counts[class as usize] += w;
            leaf.n_l += w;
            leaf.weight_since_attempt += w;
        }
        self.send_attributes(leaf_id, inst, class, ctx);

        let leaf = self.tree.leaf(node);
        if leaf.weight_since_attempt >= self.config.grace_period as f64 && !leaf.is_pure() {
            let n_l = leaf.n_l;
            let leaf = self.tree.leaf_mut(node);
            leaf.weight_since_attempt = 0.0;
            self.seq += 1;
            leaf.pending = Some(PendingSplit {
                seq: self.seq,
                expected: self.config.parallelism as u32,
                replies: Vec::new(),
                n_l,
                age: 0,
                buffer: Vec::new(),
                shed: 0,
            });
            self.stats.split_rounds += 1;
            let class_counts: Vec<f32> = if self.config.sparse {
                self.tree.leaf(node).class_counts.iter().map(|&c| c as f32).collect()
            } else {
                Vec::new()
            };
            ctx.emit_any(
                self.streams.compute,
                Event::Compute {
                    leaf: leaf_id,
                    seq: self.seq,
                    n_l,
                    class_counts: Arc::new(class_counts),
                },
            );
        }
    }

    /// Resolve the pending split round at `node` (Alg. 4).
    fn resolve(&mut self, node: u32, ctx: &mut Ctx) {
        let Some(pending) = self.tree.leaf_mut(node).pending.take() else { return };
        let leaf_id = self.tree.leaf_id(node);

        // overall top-2 across LS replies (each reply is a local top-2);
        // the dists are borrowed straight out of the Arc'd replies — the
        // split path below never copies the winning distribution
        let mut cands: Vec<(u32, f64, &[f32])> = Vec::with_capacity(pending.replies.len() * 2);
        for (attr, best, second, dist) in &pending.replies {
            cands.push((*attr, *best, dist.as_slice()));
            cands.push((u32::MAX, *second, &[])); // runner-up, attr unknown
        }
        cands.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let (best_attr, best, best_dist) = match cands.first() {
            Some(&(a, g, d)) if a != u32::MAX => (a, g, d),
            _ => {
                // no usable winner: replay buffer as plain training input
                self.replay(pending.buffer, ctx);
                return;
            }
        };
        // pre-pruning: X∅ (no split) competes with gain 0
        let second = cands.get(1).map(|c| c.1).unwrap_or(0.0).max(0.0);

        let eps = hoeffding_bound(
            infogain_range(self.tree.schema.n_classes()),
            self.config.delta,
            pending.n_l,
        );
        if best > 0.0 && should_split(best, second, eps, self.config.tau) {
            self.tree.split(node, best_attr, best_dist);
            self.stats.splits += 1;
            ctx.emit_any(self.streams.drop_leaf, Event::DropLeaf { leaf: leaf_id });
            self.replay(pending.buffer, ctx);
        } else {
            // no split: instances already trained downstream; discard buffer
            // (their attributes were NOT sent — wk semantics per the paper:
            // "Otherwise, it discards the buffer, as the instances have
            // already been incorporated in the statistics downstream."
            // In our implementation buffered instances were withheld, so we
            // replay them to keep the statistics consistent.)
            self.replay(pending.buffer, ctx);
        }
    }

    /// Replay buffered instances through the (possibly updated) tree.
    fn replay(&mut self, buffer: Vec<Instance>, ctx: &mut Ctx) {
        for inst in buffer {
            if let Some(class) = inst.class() {
                self.stats.buffered_replayed += 1;
                self.train(&inst, class, ctx);
            }
        }
    }

    /// Tick timeout counters on all pending rounds (called per instance).
    fn tick_timeouts(&mut self, ctx: &mut Ctx) {
        let timeout = self.config.timeout_instances;
        let mut expired = Vec::new();
        for node in self.tree.pending_leaves() {
            let p = self.tree.leaf_mut(node).pending.as_mut().unwrap();
            p.age += 1;
            if p.age >= timeout {
                expired.push(node);
            }
        }
        for node in expired {
            self.stats.timeouts += 1;
            self.resolve(node, ctx);
        }
    }
}

impl Processor for ModelAggregator {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { id, inst } => {
                self.stats.instances += 1;
                // prequential: test ...
                let output = self.predict(&inst);
                ctx.emit_any(
                    self.streams.prediction,
                    Event::Prediction { id, truth: inst.label, output },
                );
                // ... then train
                if let Some(class) = inst.class() {
                    self.train(&inst, class, ctx);
                }
                self.tick_timeouts(ctx);
            }
            Event::LocalResult {
                leaf, seq, best_attr, best, second_attr: _, second, best_dist
            } => {
                // the leaf may have split already — stale results dropped
                let Some(node) = self.tree.node_of_leaf(leaf) else { return };
                let Some(pending) = self.tree.leaf_mut(node).pending.as_mut() else { return };
                if pending.seq != seq {
                    return; // stale round
                }
                pending.replies.push((best_attr, best, second, best_dist));
                if pending.replies.len() as u32 >= pending.expected {
                    self.resolve(node, ctx);
                }
            }
            Event::Shutdown => {}
            _ => {}
        }
    }

    fn mem_bytes(&self) -> usize {
        self.tree.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "vht-model-aggregator"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    fn report(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("instances", self.stats.instances as f64),
            ("shed", self.stats.shed as f64),
            ("buffered_replayed", self.stats.buffered_replayed as f64),
            ("splits", self.stats.splits as f64),
            ("split_rounds", self.stats.split_rounds as f64),
            ("timeouts", self.stats.timeouts as f64),
        ]
    }

    /// Checkpoint the MA's run counters and the split-round sequence
    /// number. The tree itself is deliberately NOT captured: it is
    /// reconstructed implicitly by the replay log (instances replayed
    /// after restore re-grow the leaf counts), and any splits lost to a
    /// kill merely delay convergence — they cannot corrupt it, because
    /// the local statistics drop stale rounds by `seq`. Carrying `seq`
    /// forward is what keeps pre-kill `LocalResult`s stale after recovery.
    fn snapshot(&self) -> Option<Vec<u8>> {
        use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
        let counters = vec![
            self.stats.instances as f64,
            self.stats.shed as f64,
            self.stats.buffered_replayed as f64,
            self.stats.splits as f64,
            self.stats.split_rounds as f64,
            self.stats.timeouts as f64,
            self.seq as f64,
        ];
        Some(encode_frame(&[(TAG_META_BASE, counters)]))
    }

    fn restore(&mut self, frame: &[u8]) -> crate::Result<()> {
        use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
        let sections = decode_frame(frame)?;
        let c = section(&sections, TAG_META_BASE)
            .ok_or_else(|| crate::anyhow!("vht ma restore: counter section missing"))?;
        crate::ensure!(c.len() == 7, "vht ma restore: got {} counters, need 7", c.len());
        self.stats.instances = c[0] as u64;
        self.stats.shed = c[1] as u64;
        self.stats.buffered_replayed = c[2] as u64;
        self.stats.splits = c[3] as u64;
        self.stats.split_rounds = c[4] as u64;
        self.stats.timeouts = c[5] as u64;
        self.seq = c[6] as u32;
        Ok(())
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Instance;
    use crate::topology::StreamId;

    fn ids() -> VhtStreamIds {
        VhtStreamIds {
            attribute: StreamId(1),
            compute: StreamId(2),
            local_result: StreamId(3),
            drop_leaf: StreamId(4),
            prediction: StreamId(5),
        }
    }

    fn schema() -> Schema {
        Schema::classification("t", Schema::all_categorical(4, 2), 2)
    }

    fn ma(config: VhtConfig) -> ModelAggregator {
        ModelAggregator::new(schema(), config, ids())
    }

    fn inst(bits: [u32; 4], class: u32) -> Instance {
        Instance::dense(bits.map(|b| b as f32).to_vec(), Label::Class(class))
    }

    /// Feed instances where attribute 0 determines the class until the MA
    /// opens a split round; reply as all LS instances; check it splits.
    #[test]
    fn full_split_round_via_events() {
        let config = VhtConfig { parallelism: 2, grace_period: 50, ..Default::default() };
        let mut m = ma(config);
        let mut ctx = Ctx::new(0, 1);
        let mut compute_seen = None;
        for i in 0..200u32 {
            let a0 = i % 2;
            let ev = Event::Instance { id: i as u64, inst: inst([a0, i % 2, 0, 1], a0) };
            m.process(ev, &mut ctx);
            for (s, _, e) in ctx.take() {
                if s == ids().compute {
                    if let Event::Compute { leaf, seq, .. } = e {
                        compute_seen = Some((leaf, seq));
                    }
                }
            }
            if compute_seen.is_some() {
                break;
            }
        }
        let (leaf, seq) = compute_seen.expect("MA never opened a split round");

        // two LS replies over disjoint attribute sets (key grouping
        // guarantees disjointness): attr 0 is the clear winner
        let dist = vec![30.0, 0.0, 0.0, 30.0]; // v0->c0, v1->c1
        m.process(
            Event::LocalResult {
                leaf,
                seq,
                best_attr: 0,
                best: 0.95,
                second_attr: 2,
                second: 0.01,
                best_dist: Arc::new(dist.clone()),
            },
            &mut ctx,
        );
        m.process(
            Event::LocalResult {
                leaf,
                seq,
                best_attr: 1,
                best: 0.02,
                second_attr: 3,
                second: 0.0,
                best_dist: Arc::new(vec![1.0; 4]),
            },
            &mut ctx,
        );
        let drops: Vec<_> = ctx
            .take()
            .into_iter()
            .filter(|(s, _, _)| *s == ids().drop_leaf)
            .collect();
        assert_eq!(drops.len(), 1, "split must broadcast exactly one drop");
        assert_eq!(m.tree().n_splits, 1);
        // children seeded from dist: majority predictions follow attr 0
        let p0 = m.predict(&inst([0, 0, 0, 0], 0));
        let p1 = m.predict(&inst([1, 0, 0, 0], 0));
        assert_eq!(p0, Output::Class(0));
        assert_eq!(p1, Output::Class(1));
    }

    #[test]
    fn stale_local_result_ignored() {
        let config = VhtConfig { parallelism: 1, grace_period: 50, ..Default::default() };
        let mut m = ma(config);
        let mut ctx = Ctx::new(0, 1);
        // result for an unknown leaf/seq must be a no-op
        m.process(
            Event::LocalResult {
                leaf: 999,
                seq: 7,
                best_attr: 0,
                best: 1.0,
                second_attr: 1,
                second: 0.0,
                best_dist: Arc::new(vec![]),
            },
            &mut ctx,
        );
        assert_eq!(m.tree().n_splits, 0);
        assert!(ctx.take().is_empty());
    }

    #[test]
    fn wok_sheds_and_wk_buffers_during_round() {
        for (buffering, expect_shed) in
            [(SplitBuffering::Discard, true), (SplitBuffering::Buffer(1000), false)]
        {
            let config = VhtConfig {
                parallelism: 1,
                grace_period: 10,
                timeout_instances: 10_000,
                buffering,
                ..Default::default()
            };
            let mut m = ma(config);
            let mut ctx = Ctx::new(0, 1);
            // drive until a round opens, then keep sending to the same leaf
            for i in 0..200u32 {
                let a0 = i % 2;
                m.process(
                    Event::Instance { id: i as u64, inst: inst([a0, 0, 0, 0], a0) },
                    &mut ctx,
                );
                ctx.take();
            }
            if expect_shed {
                assert!(m.stats.shed > 0, "wok should shed during pending round");
            } else {
                assert_eq!(m.stats.shed, 0, "wk(1000) should buffer, not shed");
            }
        }
    }

    #[test]
    fn timeout_resolves_round_without_all_replies() {
        let config = VhtConfig {
            parallelism: 4, // 4 replies expected, none will come
            grace_period: 10,
            timeout_instances: 20,
            ..Default::default()
        };
        let mut m = ma(config);
        let mut ctx = Ctx::new(0, 1);
        for i in 0..200u32 {
            let a0 = i % 2;
            m.process(Event::Instance { id: i as u64, inst: inst([a0, 0, 0, 0], a0) }, &mut ctx);
            ctx.take();
        }
        assert!(m.stats.timeouts > 0, "rounds must time out");
        assert!(
            m.tree().pending_leaves().len() <= 1,
            "timed-out rounds must not accumulate"
        );
    }
}
