//! MA-side tree structure for the Vertical Hoeffding Tree.
//!
//! The model aggregator holds the tree *without* attribute observers —
//! those live in the distributed local-statistics table (the memory
//! argument of §6.1). Leaves keep only the class marginals (for prediction
//! and purity checks), the instance count `n_l`, and the in-flight split
//! state.
//!
//! Binning happens at the MA before decomposition (source-side
//! discretization): attribute events carry the *bin*, so all LS instances
//! and the tree agree on thresholds by construction.

use std::sync::Arc;

use crate::common::fxhash::FxHashMap;

use crate::common::memsize::vec_flat_bytes;
use crate::core::instance::Instance;
use crate::core::observers::Binner;
use crate::core::{AttributeKind, Schema};

/// In-flight split-decision state of a leaf (one `compute` round).
#[derive(Clone, Debug)]
pub struct PendingSplit {
    pub seq: u32,
    /// LS instances expected to reply.
    pub expected: u32,
    /// (best_attr, best, second, child-dist of best) per received reply.
    /// The dist stays behind the `LocalResult` event's Arc — no copy on
    /// receipt.
    pub replies: Vec<(u32, f64, f64, Arc<Vec<f32>>)>,
    /// n_l when the round started (used in the Hoeffding bound).
    pub n_l: f64,
    /// Source instances seen since the round started (timeout ticking).
    pub age: u32,
    /// Instances buffered while the decision is pending (wk(z) mode).
    pub buffer: Vec<Instance>,
    /// Instances discarded while pending (wok) — load-shedding metric.
    pub shed: u64,
}

/// A leaf of the MA tree.
#[derive(Clone, Debug)]
pub struct MaLeaf {
    pub class_counts: Vec<f64>,
    pub n_l: f64,
    pub weight_since_attempt: f64,
    pub depth: u32,
    pub pending: Option<PendingSplit>,
}

impl MaLeaf {
    pub fn new(n_classes: u32, depth: u32) -> Self {
        MaLeaf {
            class_counts: vec![0.0; n_classes as usize],
            n_l: 0.0,
            weight_since_attempt: 0.0,
            depth,
            pending: None,
        }
    }

    pub fn majority(&self) -> Option<u32> {
        let (mut best, mut bw) = (None, 0.0);
        for (c, &w) in self.class_counts.iter().enumerate() {
            if w > bw {
                bw = w;
                best = Some(c as u32);
            }
        }
        best
    }

    pub fn is_pure(&self) -> bool {
        self.class_counts.iter().filter(|&&w| w > 0.0).count() <= 1
    }
}

/// MA tree node.
#[derive(Clone, Debug)]
pub enum MaNode {
    Split { attr: u32, children: Vec<u32> },
    Leaf(MaLeaf),
}

/// The VHT model as held by the model aggregator.
pub struct MaTree {
    pub schema: Schema,
    /// Sparse mode: presence routing (2-way splits), no binners.
    pub sparse: bool,
    nodes: Vec<MaNode>,
    binners: Vec<Option<Binner>>,
    /// Monotonic leaf ids: the LS table is keyed by these, never reused, so
    /// a stale `attribute` event for a dropped leaf cannot corrupt a new
    /// leaf's statistics.
    leaf_ids: Vec<u64>,
    /// Reverse map: live leaf id → node index (split rounds resolve by id).
    leaf_index: FxHashMap<u64, u32>,
    next_leaf_id: u64,
    pub n_splits: u64,
}

impl MaTree {
    pub fn new(schema: Schema) -> Self {
        let binners = schema
            .attributes
            .iter()
            .map(|a| match a {
                AttributeKind::Numeric => Some(Binner::new(schema.numeric_bins)),
                AttributeKind::Categorical { .. } => None,
            })
            .collect();
        let root = MaNode::Leaf(MaLeaf::new(schema.n_classes(), 0));
        MaTree {
            schema,
            sparse: false,
            nodes: vec![root],
            binners,
            leaf_ids: vec![0],
            leaf_index: { let mut m = FxHashMap::default(); m.insert(0u64, 0u32); m },
            next_leaf_id: 1,
            n_splits: 0,
        }
    }

    /// Observe + bin a value (training path).
    #[inline]
    pub fn bin_observe(&mut self, attr: usize, value: f32) -> u32 {
        match &mut self.binners[attr] {
            Some(b) => b.observe(value),
            None => value as u32,
        }
    }

    #[inline]
    pub fn bin_of(&self, attr: usize, value: f32) -> u32 {
        match &self.binners[attr] {
            Some(b) => b.bin_of(value),
            None => value as u32,
        }
    }

    /// Sort to a leaf; returns the node index. Sparse mode routes by
    /// presence (children: 0 = absent, 1 = present).
    pub fn sort(&self, inst: &Instance) -> u32 {
        let mut node = 0u32;
        loop {
            match &self.nodes[node as usize] {
                MaNode::Leaf(_) => return node,
                MaNode::Split { attr, children } => {
                    let v = inst.value(*attr as usize);
                    let bin = if self.sparse {
                        (v != 0.0) as usize
                    } else {
                        self.bin_of(*attr as usize, v) as usize
                    };
                    node = children[bin.min(children.len() - 1)];
                }
            }
        }
    }

    /// Stable leaf id of a leaf node index (key of the LS table).
    pub fn leaf_id(&self, node: u32) -> u64 {
        self.leaf_ids[node as usize]
    }

    /// Leaf id if `node` is (still) a leaf.
    pub fn leaf_id_checked(&self, node: u32) -> Option<u64> {
        matches!(self.nodes.get(node as usize), Some(MaNode::Leaf(_)))
            .then(|| self.leaf_ids[node as usize])
    }

    /// Node index of a live leaf id (None once the leaf was split).
    pub fn node_of_leaf(&self, leaf_id: u64) -> Option<u32> {
        self.leaf_index.get(&leaf_id).copied()
    }

    pub fn leaf(&self, node: u32) -> &MaLeaf {
        match &self.nodes[node as usize] {
            MaNode::Leaf(l) => l,
            _ => unreachable!("not a leaf"),
        }
    }

    pub fn leaf_mut(&mut self, node: u32) -> &mut MaLeaf {
        match &mut self.nodes[node as usize] {
            MaNode::Leaf(l) => l,
            _ => unreachable!("not a leaf"),
        }
    }

    /// All node indices that are leaves with a pending split.
    pub fn pending_leaves(&self) -> Vec<u32> {
        (0..self.nodes.len() as u32)
            .filter(|&i| matches!(&self.nodes[i as usize], MaNode::Leaf(l) if l.pending.is_some()))
            .collect()
    }

    /// Split `node` on `attr`; children seeded from `dist` (flattened
    /// `[arity, n_classes]` counts observed at the winning LS). Returns the
    /// dropped leaf id (to broadcast `drop`).
    pub fn split(&mut self, node: u32, attr: u32, dist: &[f32]) -> u64 {
        let depth = self.leaf(node).depth;
        let dropped = self.leaf_ids[node as usize];
        let arity =
            if self.sparse { 2 } else { self.schema.arity(attr as usize) as usize };
        let c = self.schema.n_classes() as usize;
        let mut children = Vec::with_capacity(arity);
        for v in 0..arity {
            let mut leaf = MaLeaf::new(c as u32, depth + 1);
            for cc in 0..c {
                let idx = v * c + cc;
                if idx < dist.len() {
                    leaf.class_counts[cc] = dist[idx] as f64;
                }
            }
            leaf.n_l = leaf.class_counts.iter().sum();
            self.nodes.push(MaNode::Leaf(leaf));
            self.leaf_ids.push(self.next_leaf_id);
            self.leaf_index.insert(self.next_leaf_id, (self.nodes.len() - 1) as u32);
            self.next_leaf_id += 1;
            children.push((self.nodes.len() - 1) as u32);
        }
        self.leaf_index.remove(&dropped);
        self.nodes[node as usize] = MaNode::Split { attr, children };
        self.n_splits += 1;
        dropped
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, MaNode::Leaf(_))).count()
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .nodes
                .iter()
                .map(|n| match n {
                    MaNode::Split { children, .. } => 16 + vec_flat_bytes(children),
                    MaNode::Leaf(l) => {
                        std::mem::size_of::<MaLeaf>() + vec_flat_bytes(&l.class_counts)
                    }
                })
                .sum::<usize>()
            + self.leaf_ids.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Label;

    fn schema() -> Schema {
        Schema::classification("t", Schema::all_categorical(3, 2), 2)
    }

    #[test]
    fn root_is_leaf_zero() {
        let t = MaTree::new(schema());
        let inst = Instance::dense(vec![0.0, 1.0, 0.0], Label::None);
        assert_eq!(t.sort(&inst), 0);
        assert_eq!(t.leaf_id(0), 0);
    }

    #[test]
    fn split_routes_children_and_ids_are_fresh() {
        let mut t = MaTree::new(schema());
        // dist: value 0 -> class 0 (10), value 1 -> class 1 (20)
        let dropped = t.split(0, 1, &[10.0, 0.0, 0.0, 20.0]);
        assert_eq!(dropped, 0);
        assert_eq!(t.n_leaves(), 2);
        let i0 = Instance::dense(vec![0.0, 0.0, 0.0], Label::None);
        let i1 = Instance::dense(vec![0.0, 1.0, 0.0], Label::None);
        let l0 = t.sort(&i0);
        let l1 = t.sort(&i1);
        assert_ne!(l0, l1);
        assert_ne!(t.leaf_id(l0), 0, "new leaves must have fresh ids");
        assert_eq!(t.leaf(l0).majority(), Some(0));
        assert_eq!(t.leaf(l1).majority(), Some(1));
        assert_eq!(t.leaf(l1).depth, 1);
    }

    #[test]
    fn numeric_binning_routes() {
        let s = Schema::classification("n", Schema::all_numeric(1), 2);
        let mut t = MaTree::new(s);
        for i in 0..200 {
            t.bin_observe(0, i as f32);
        }
        let dist = vec![0.0; 32];
        t.split(0, 0, &dist);
        let low = t.sort(&Instance::dense(vec![1.0], Label::None));
        let high = t.sort(&Instance::dense(vec![199.0], Label::None));
        assert_ne!(low, high);
    }
}
