//! Streaming naive Bayes — a cheap single-machine classifier used as an
//! ensemble base learner and as a sanity baseline.

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;
use crate::core::instance::Instance;
use crate::core::model::Classifier;
use crate::core::observers::{Binner, CounterBlock};
use crate::core::{AttributeKind, Schema};

/// Multinomial NB over binned attributes with Laplace smoothing.
pub struct NaiveBayes {
    schema: Schema,
    class_counts: Vec<f64>,
    blocks: Vec<CounterBlock>,
    binners: Vec<Option<Binner>>,
    trained: u64,
}

impl NaiveBayes {
    pub fn new(schema: Schema) -> Self {
        let blocks = (0..schema.n_attributes())
            .map(|i| CounterBlock::new(schema.arity(i), schema.n_classes()))
            .collect();
        let binners = schema
            .attributes
            .iter()
            .map(|a| match a {
                AttributeKind::Numeric => Some(Binner::new(schema.numeric_bins)),
                AttributeKind::Categorical { .. } => None,
            })
            .collect();
        NaiveBayes {
            class_counts: vec![0.0; schema.n_classes() as usize],
            blocks,
            binners,
            schema,
            trained: 0,
        }
    }

    #[inline]
    fn bin(&self, attr: usize, v: f32) -> u32 {
        match &self.binners[attr] {
            Some(b) => b.bin_of(v),
            None => v as u32,
        }
    }
}

impl Classifier for NaiveBayes {
    fn predict(&self, inst: &Instance) -> Option<u32> {
        if self.trained == 0 {
            return None;
        }
        let total: f64 = self.class_counts.iter().sum();
        let c_n = self.class_counts.len();
        let mut best = (None, f64::NEG_INFINITY);
        for c in 0..c_n {
            let mut lp = ((self.class_counts[c] + 1.0) / (total + c_n as f64)).ln();
            for a in 0..self.schema.n_attributes() {
                let bin = self.bin(a, inst.value(a));
                let block = &self.blocks[a];
                let like = (block.get(bin.min(block.v() - 1), c as u32) as f64 + 1.0)
                    / (self.class_counts[c] + block.v() as f64);
                lp += like.ln();
            }
            if lp > best.1 {
                best = (Some(c as u32), lp);
            }
        }
        best.0
    }

    fn train(&mut self, inst: &Instance) {
        let Some(class) = inst.class() else { return };
        self.trained += 1;
        self.class_counts[class as usize] += inst.weight as f64;
        for a in 0..self.schema.n_attributes() {
            let v = inst.value(a);
            let bin = match &mut self.binners[a] {
                Some(b) => b.observe(v),
                None => v as u32,
            };
            let block = &mut self.blocks[a];
            block.add(bin.min(block.v() - 1), class, inst.weight);
        }
    }

    fn model_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + vec_flat_bytes(&self.class_counts)
            + self.blocks.iter().map(|b| b.mem_bytes()).sum::<usize>()
            + self.binners.iter().map(|b| b.mem_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    #[test]
    fn learns_conditional_concept() {
        let schema = Schema::classification("nb", Schema::all_categorical(2, 2), 2);
        let mut nb = NaiveBayes::new(schema);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let a = rng.below(2) as f32;
            nb.train(&Instance::dense(vec![a, rng.below(2) as f32], Label::Class(a as u32)));
        }
        assert_eq!(nb.predict(&Instance::dense(vec![1.0, 0.0], Label::None)), Some(1));
        assert_eq!(nb.predict(&Instance::dense(vec![0.0, 1.0], Label::None)), Some(0));
    }

    #[test]
    fn untrained_predicts_none() {
        let schema = Schema::classification("nb", Schema::all_numeric(3), 2);
        let nb = NaiveBayes::new(schema);
        assert_eq!(nb.predict(&Instance::dense(vec![0.0; 3], Label::None)), None);
    }
}
