//! Streaming classifiers: sequential Hoeffding tree (the "moa" baseline),
//! the Vertical Hoeffding Tree (paper §6), the horizontal sharding
//! baseline, and naive Bayes.
pub mod hoeffding_tree;
pub mod naive_bayes;
pub mod vht;
pub mod sharding;
