//! Sequential Hoeffding tree (VFDT, Domingos & Hulten 2000) — the paper's
//! **moa** baseline and the semantic reference for VHT: `VHT local` with
//! zero feedback delay must learn exactly this tree.
//!
//! Leaves hold one [`CounterBlock`] per attribute (the `n_ijk` of §6.1);
//! every `grace_period` instances a leaf evaluates all attributes' split
//! criterion — through [`crate::runtime::gain`]'s batch entry point
//! (native, SIMD or XLA, registry-selected) — applies the Hoeffding
//! bound with tie-break τ
//! (Alg. 4), and splits pre-pruned against the no-split scenario X∅.

use crate::common::fxhash::FxHashMap;

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;
use crate::core::hoeffding::{hoeffding_bound, infogain_range, should_split};
use crate::core::instance::{Instance, Label, Values};
use crate::core::model::Classifier;
use crate::core::observers::{Binner, CounterBlock};
use crate::core::{AttributeKind, Schema};
use crate::runtime::gain;

/// Leaf prediction strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafPrediction {
    /// Majority class of the leaf.
    MajorityClass,
    /// Naive Bayes over the leaf's attribute observers (MOA's `NBAdaptive`
    /// simplified: NB once the leaf has enough weight, else majority).
    NaiveBayes,
}

/// Hoeffding tree hyperparameters (MOA defaults).
#[derive(Clone, Debug)]
pub struct HTConfig {
    /// n_min: instances a leaf accumulates between split attempts.
    pub grace_period: u32,
    /// δ: confidence for the Hoeffding bound.
    pub delta: f64,
    /// τ: tie-break threshold.
    pub tau: f64,
    pub leaf_prediction: LeafPrediction,
    /// Hard cap on tree depth (0 = unlimited).
    pub max_depth: u32,
    /// Sparse mode: binary presence observers materialized on demand
    /// (absence counts derived from the leaf's class marginals).
    pub sparse: bool,
}

impl Default for HTConfig {
    fn default() -> Self {
        HTConfig {
            grace_period: 200,
            delta: 1e-7,
            tau: 0.05,
            leaf_prediction: LeafPrediction::NaiveBayes,
            max_depth: 0,
            sparse: false,
        }
    }
}

/// Per-leaf sufficient statistics.
pub struct LeafStats {
    /// Class marginals at the leaf.
    pub class_counts: Vec<f64>,
    /// Weight seen since the last split attempt.
    pub weight_since_attempt: f64,
    /// Dense: one block per attribute.
    dense: Vec<CounterBlock>,
    /// Sparse: per-attribute presence blocks, on demand.
    sparse: FxHashMap<u32, CounterBlock>,
}

impl LeafStats {
    fn new(schema: &Schema, sparse: bool) -> Self {
        let c = schema.n_classes();
        LeafStats {
            class_counts: vec![0.0; c as usize],
            weight_since_attempt: 0.0,
            dense: if sparse {
                Vec::new()
            } else {
                (0..schema.n_attributes())
                    .map(|i| CounterBlock::new(schema.arity(i), c))
                    .collect()
            },
            sparse: FxHashMap::default(),
        }
    }

    pub fn total_weight(&self) -> f64 {
        self.class_counts.iter().sum()
    }

    fn majority(&self) -> Option<u32> {
        let (mut best, mut bw) = (None, 0.0);
        for (c, &w) in self.class_counts.iter().enumerate() {
            if w > bw {
                bw = w;
                best = Some(c as u32);
            }
        }
        best
    }

    fn is_pure(&self) -> bool {
        self.class_counts.iter().filter(|&&w| w > 0.0).count() <= 1
    }

    /// Materialize the binary (absent/present) block of a sparse attribute.
    fn sparse_block(&self, attr: u32, n_classes: u32) -> CounterBlock {
        let mut blk = CounterBlock::new(2, n_classes);
        if let Some(p) = self.sparse.get(&attr) {
            for c in 0..n_classes {
                let pr = p.get(1, c);
                blk.add(0, c, (self.class_counts[c as usize] as f32 - pr).max(0.0));
                blk.add(1, c, pr);
            }
        }
        blk
    }
}

impl MemSize for LeafStats {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + vec_flat_bytes(&self.class_counts)
            + self.dense.iter().map(|b| b.mem_bytes()).sum::<usize>()
            + self.sparse.values().map(|b| b.mem_bytes() + 16).sum::<usize>()
    }
}

/// Tree node.
enum Node {
    Split { attr: u32, children: Vec<u32> },
    Leaf { stats: LeafStats, depth: u32 },
}

/// The sequential Hoeffding tree.
pub struct HoeffdingTree {
    pub schema: Schema,
    pub config: HTConfig,
    nodes: Vec<Node>,
    /// Shared per-attribute binners for numeric attributes (None for
    /// categorical) — bin thresholds are global, like a feature transform.
    binners: Vec<Option<Binner>>,
    pub n_splits: u64,
    pub n_split_attempts: u64,
    trained: u64,
}

impl HoeffdingTree {
    pub fn new(schema: Schema, config: HTConfig) -> Self {
        let binners = schema
            .attributes
            .iter()
            .map(|a| match a {
                AttributeKind::Numeric => Some(Binner::new(schema.numeric_bins)),
                AttributeKind::Categorical { .. } => None,
            })
            .collect();
        let root = Node::Leaf { stats: LeafStats::new(&schema, config.sparse), depth: 0 };
        HoeffdingTree {
            schema,
            config,
            nodes: vec![root],
            binners,
            n_splits: 0,
            n_split_attempts: 0,
            trained: 0,
        }
    }

    /// Bin of attribute `attr`'s value (training path: updates ranges).
    #[inline]
    fn bin_observe(&mut self, attr: usize, value: f32) -> u32 {
        match &mut self.binners[attr] {
            Some(b) => b.observe(value),
            None => value as u32,
        }
    }

    #[inline]
    fn bin_of(&self, attr: usize, value: f32) -> u32 {
        match &self.binners[attr] {
            Some(b) => b.bin_of(value),
            None => value as u32,
        }
    }

    /// Sort an instance to its leaf (read-only). Sparse mode routes by
    /// presence (children: 0 = absent, 1 = present).
    pub fn sort_to_leaf(&self, inst: &Instance) -> u32 {
        let mut node = 0u32;
        loop {
            match &self.nodes[node as usize] {
                Node::Leaf { .. } => return node,
                Node::Split { attr, children } => {
                    let v = inst.value(*attr as usize);
                    let bin = if self.config.sparse {
                        (v != 0.0) as usize
                    } else {
                        self.bin_of(*attr as usize, v) as usize
                    };
                    node = children[bin.min(children.len() - 1)];
                }
            }
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn trained_instances(&self) -> u64 {
        self.trained
    }

    fn leaf_stats(&self, leaf: u32) -> &LeafStats {
        match &self.nodes[leaf as usize] {
            Node::Leaf { stats, .. } => stats,
            _ => unreachable!("sort_to_leaf returned a split node"),
        }
    }

    fn train_inner(&mut self, inst: &Instance) {
        let Some(class) = inst.class() else { return };
        self.trained += 1;
        let leaf = self.sort_to_leaf(inst);
        let w = inst.weight as f64;
        let sparse_mode = self.config.sparse;
        let n_classes = self.schema.n_classes();

        // (attr, bin) updates collected first: binner updates need &mut self
        let mut updates: Vec<(usize, u32)> = Vec::with_capacity(inst.n_stored());
        match (inst.values(), sparse_mode) {
            (Values::Sparse { .. }, true) => {
                for (a, v) in inst.iter_stored() {
                    if v != 0.0 {
                        updates.push((a, 1));
                    }
                }
            }
            _ => {
                for (a, v) in inst.iter_stored() {
                    let bin = self.bin_observe(a, v);
                    updates.push((a, bin));
                }
            }
        }

        let (depth, should_attempt) = {
            let Node::Leaf { stats, depth } = &mut self.nodes[leaf as usize] else {
                unreachable!()
            };
            stats.class_counts[class as usize] += w;
            stats.weight_since_attempt += w;
            for &(a, bin) in &updates {
                if sparse_mode {
                    stats
                        .sparse
                        .entry(a as u32)
                        .or_insert_with(|| CounterBlock::new(2, n_classes))
                        .add(bin.min(1), class, w as f32);
                } else {
                    stats.dense[a].add(bin, class, w as f32);
                }
            }
            let attempt = stats.weight_since_attempt >= self.config.grace_period as f64
                && !stats.is_pure();
            if attempt {
                stats.weight_since_attempt = 0.0;
            }
            (*depth, attempt)
        };

        if should_attempt && (self.config.max_depth == 0 || depth < self.config.max_depth) {
            self.attempt_split(leaf, depth);
        }
    }

    /// Evaluate the split criterion at `leaf` and split if warranted.
    fn attempt_split(&mut self, leaf: u32, depth: u32) {
        self.n_split_attempts += 1;
        let (gains, attrs): (Vec<f64>, Vec<u32>) = {
            let stats = self.leaf_stats(leaf);
            if self.config.sparse {
                let mut blocks = Vec::with_capacity(stats.sparse.len());
                let mut attrs = Vec::with_capacity(stats.sparse.len());
                for &a in stats.sparse.keys() {
                    blocks.push(stats.sparse_block(a, self.schema.n_classes()));
                    attrs.push(a);
                }
                let refs: Vec<&CounterBlock> = blocks.iter().collect();
                (gain::gains(&refs), attrs)
            } else {
                let refs: Vec<&CounterBlock> = stats.dense.iter().collect();
                (gain::gains(&refs), (0..refs.len() as u32).collect())
            }
        };
        if gains.is_empty() {
            return;
        }

        let (bi, best, _si, second) = gain::top2(&gains);
        // pre-pruning: the no-split scenario X∅ competes with gain 0
        let second = second.max(0.0);
        let n = self.leaf_stats(leaf).total_weight();
        let eps = hoeffding_bound(infogain_range(self.schema.n_classes()), self.config.delta, n);
        if best > 0.0 && should_split(best, second, eps, self.config.tau) {
            self.split(leaf, attrs[bi], depth);
        }
    }

    /// Replace `leaf` by a split node on `attr` (Alg. 4 lines 6-9).
    fn split(&mut self, leaf: u32, attr: u32, depth: u32) {
        self.n_splits += 1;
        let arity = if self.config.sparse { 2 } else { self.schema.arity(attr as usize) };
        let child_dists: Vec<Vec<f64>> = {
            let stats = self.leaf_stats(leaf);
            let block_owned;
            let block: &CounterBlock = if self.config.sparse {
                block_owned = stats.sparse_block(attr, self.schema.n_classes());
                &block_owned
            } else {
                &stats.dense[attr as usize]
            };
            (0..arity)
                .map(|v| {
                    (0..self.schema.n_classes())
                        .map(|c| block.get(v, c) as f64)
                        .collect()
                })
                .collect()
        };

        let mut children = Vec::with_capacity(arity as usize);
        for dist in child_dists {
            let mut stats = LeafStats::new(&self.schema, self.config.sparse);
            stats.class_counts = dist;
            self.nodes.push(Node::Leaf { stats, depth: depth + 1 });
            children.push((self.nodes.len() - 1) as u32);
        }
        self.nodes[leaf as usize] = Node::Split { attr, children };
    }

    /// Naive-Bayes prediction at a leaf.
    fn nb_predict(&self, stats: &LeafStats, inst: &Instance) -> Option<u32> {
        let total = stats.total_weight();
        if total < 1.0 {
            return stats.majority();
        }
        let c_n = self.schema.n_classes() as usize;
        let mut log_post: Vec<f64> = (0..c_n)
            .map(|c| ((stats.class_counts[c] + 1.0) / (total + c_n as f64)).ln())
            .collect();
        let mut add_block = |block: &CounterBlock, bin: u32| {
            for (c, lp) in log_post.iter_mut().enumerate() {
                let likelihood = (block.get(bin, c as u32) as f64 + 1.0)
                    / (stats.class_counts[c] + block.v() as f64);
                *lp += likelihood.ln();
            }
        };
        if self.config.sparse {
            for (a, v) in inst.iter_stored() {
                if let Some(block) = stats.sparse.get(&(a as u32)) {
                    add_block(block, if v != 0.0 { 1 } else { 0 });
                }
            }
        } else {
            for a in 0..self.schema.n_attributes() {
                let bin = self.bin_of(a, inst.value(a));
                add_block(&stats.dense[a], bin);
            }
        }
        log_post
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c as u32)
    }
}

impl Classifier for HoeffdingTree {
    fn predict(&self, inst: &Instance) -> Option<u32> {
        let leaf = self.sort_to_leaf(inst);
        let stats = self.leaf_stats(leaf);
        match self.config.leaf_prediction {
            LeafPrediction::MajorityClass => stats.majority(),
            LeafPrediction::NaiveBayes => {
                if stats.total_weight() >= 10.0 {
                    self.nb_predict(stats, inst)
                } else {
                    stats.majority()
                }
            }
        }
    }

    fn train(&mut self, inst: &Instance) {
        self.train_inner(inst);
    }

    fn model_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .nodes
                .iter()
                .map(|n| match n {
                    Node::Split { children, .. } => 16 + vec_flat_bytes(children),
                    Node::Leaf { stats, .. } => 8 + stats.mem_bytes(),
                })
                .sum::<usize>()
            + self.binners.iter().map(|b| b.mem_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    /// Stream where attribute 0 fully determines the class.
    fn easy_instance(rng: &mut Rng) -> Instance {
        let a0 = rng.below(2) as f32;
        let mut vals = vec![a0];
        vals.extend((0..4).map(|_| rng.f32()));
        Instance::dense(vals, Label::Class(a0 as u32))
    }

    fn easy_schema() -> Schema {
        let mut attrs = vec![AttributeKind::Categorical { n_values: 2 }];
        attrs.extend(Schema::all_numeric(4));
        Schema::classification("easy", attrs, 2)
    }

    #[test]
    fn learns_simple_concept() {
        let mut rng = Rng::new(1);
        let mut ht = HoeffdingTree::new(easy_schema(), HTConfig::default());
        for _ in 0..2000 {
            ht.train(&easy_instance(&mut rng));
        }
        assert!(ht.n_splits >= 1, "should split on the determining attribute");
        let mut correct = 0;
        for _ in 0..500 {
            let inst = easy_instance(&mut rng);
            if ht.predict(&inst) == inst.class() {
                correct += 1;
            }
        }
        assert!(correct > 480, "correct={correct}/500");
    }

    #[test]
    fn no_split_on_pure_stream() {
        let mut rng = Rng::new(2);
        let mut ht = HoeffdingTree::new(easy_schema(), HTConfig::default());
        for _ in 0..1500 {
            let mut inst = easy_instance(&mut rng);
            inst.label = Label::Class(0);
            ht.train(&inst);
        }
        assert_eq!(ht.n_splits, 0);
        assert_eq!(ht.n_leaves(), 1);
    }

    #[test]
    fn empty_model_predicts_none() {
        let ht = HoeffdingTree::new(easy_schema(), HTConfig::default());
        assert_eq!(ht.predict(&Instance::dense(vec![0.0; 5], Label::None)), None);
    }

    #[test]
    fn tree_grows_monotonically() {
        let mut rng = Rng::new(3);
        let mut ht = HoeffdingTree::new(easy_schema(), HTConfig::default());
        let mut leaves_prev = ht.n_leaves();
        for _ in 0..10 {
            for _ in 0..500 {
                ht.train(&easy_instance(&mut rng));
            }
            let leaves = ht.n_leaves();
            assert!(leaves >= leaves_prev);
            leaves_prev = leaves;
        }
    }

    #[test]
    fn max_depth_respected() {
        let mut rng = Rng::new(4);
        let cfg = HTConfig { max_depth: 1, ..Default::default() };
        let mut ht = HoeffdingTree::new(easy_schema(), cfg);
        for _ in 0..20_000 {
            let a0 = rng.below(2) as f32;
            let a1 = rng.below(2) as f32;
            let cls = (a0 as u32) ^ (a1 as u32);
            let vals = vec![a0, a1.into(), rng.f32(), rng.f32(), rng.f32()];
            let inst = Instance::dense(vals, Label::Class(cls));
            ht.train(&inst);
        }
        // one split layer max: root + its children (arity <= 16)
        assert!(ht.n_nodes() <= 1 + 16, "nodes={}", ht.n_nodes());
    }

    #[test]
    fn sparse_mode_learns_presence_concept() {
        let mut rng = Rng::new(5);
        let schema = Schema::classification("sparse", Schema::all_numeric(100), 2);
        let cfg = HTConfig { sparse: true, grace_period: 100, ..Default::default() };
        let mut ht = HoeffdingTree::new(schema, cfg);
        for _ in 0..3000 {
            let has = rng.bool(0.5);
            let mut idx: Vec<u32> = vec![10 + rng.below(50) as u32];
            if has {
                idx.push(3);
            }
            idx.sort_unstable();
            idx.dedup();
            let vals = vec![1.0; idx.len()];
            ht.train(&Instance::sparse(idx, vals, 100, Label::Class(has as u32)));
        }
        assert!(ht.n_splits >= 1);
        assert_eq!(ht.predict(&Instance::sparse(vec![3], vec![1.0], 100, Label::None)), Some(1));
        assert_eq!(ht.predict(&Instance::sparse(vec![20], vec![1.0], 100, Label::None)), Some(0));
    }

    #[test]
    fn model_bytes_grows_with_training() {
        let mut rng = Rng::new(6);
        let mut ht = HoeffdingTree::new(easy_schema(), HTConfig::default());
        let b0 = ht.model_bytes();
        for _ in 0..3000 {
            ht.train(&easy_instance(&mut rng));
        }
        assert!(ht.model_bytes() > b0);
    }
}
