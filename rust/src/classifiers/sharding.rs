//! Horizontal-parallelism baseline (paper §6.3, "sharding"): the incoming
//! stream is shuffle-split across an ensemble of p independent Hoeffding
//! trees; prediction is majority vote over all shards.
//!
//! This is the Jubatus-style "local model" design the paper compares
//! against: each shard sees 1/p of the instances but tracks *all*
//! attributes, so memory grows ~p× the sequential tree (which is why
//! sharding runs out of memory at 20k dense attributes in Fig. 4).

use crate::core::instance::Instance;
use crate::core::model::Classifier;
use crate::core::Schema;

use super::hoeffding_tree::{HTConfig, HoeffdingTree};

/// Sharded Hoeffding-tree ensemble (sequential driver form).
pub struct Sharding {
    shards: Vec<HoeffdingTree>,
    next: usize,
    n_classes: u32,
}

impl Sharding {
    pub fn new(schema: Schema, config: HTConfig, p: usize) -> Self {
        assert!(p >= 1);
        Sharding {
            shards: (0..p).map(|_| HoeffdingTree::new(schema.clone(), config.clone())).collect(),
            next: 0,
            n_classes: schema.n_classes(),
        }
    }

    pub fn p(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &HoeffdingTree {
        &self.shards[i]
    }
}

impl Classifier for Sharding {
    /// Majority vote across shards.
    fn predict(&self, inst: &Instance) -> Option<u32> {
        let mut votes = vec![0u32; self.n_classes as usize];
        for s in &self.shards {
            if let Some(c) = s.predict(inst) {
                votes[c as usize] += 1;
            }
        }
        votes
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0)
            .max_by_key(|(_, &v)| v)
            .map(|(c, _)| c as u32)
    }

    /// Shuffle grouping: round-robin shard training.
    fn train(&mut self, inst: &Instance) {
        let i = self.next;
        self.next = (self.next + 1) % self.shards.len();
        self.shards[i].train(inst);
    }

    fn model_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.model_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;
    use crate::core::AttributeKind;

    fn schema() -> Schema {
        let mut attrs = vec![AttributeKind::Categorical { n_values: 2 }];
        attrs.extend(Schema::all_numeric(3));
        Schema::classification("s", attrs, 2)
    }

    fn easy(rng: &mut Rng) -> Instance {
        let a = rng.below(2) as f32;
        Instance::dense(vec![a, rng.f32(), rng.f32(), rng.f32()], Label::Class(a as u32))
    }

    #[test]
    fn ensemble_learns_and_votes() {
        let mut rng = Rng::new(1);
        let mut sh = Sharding::new(schema(), HTConfig::default(), 4);
        for _ in 0..8000 {
            sh.train(&easy(&mut rng));
        }
        let mut correct = 0;
        for _ in 0..300 {
            let i = easy(&mut rng);
            if sh.predict(&i) == i.class() {
                correct += 1;
            }
        }
        assert!(correct > 280, "correct={correct}");
    }

    #[test]
    fn shards_receive_balanced_load() {
        let mut rng = Rng::new(2);
        let mut sh = Sharding::new(schema(), HTConfig::default(), 3);
        for _ in 0..999 {
            sh.train(&easy(&mut rng));
        }
        for i in 0..3 {
            assert_eq!(sh.shard(i).trained_instances(), 333);
        }
    }

    #[test]
    fn memory_scales_with_p() {
        let mut rng = Rng::new(3);
        let mut s1 = Sharding::new(schema(), HTConfig::default(), 1);
        let mut s4 = Sharding::new(schema(), HTConfig::default(), 4);
        for _ in 0..4000 {
            let i = easy(&mut rng);
            s1.train(&i);
            s4.train(&i);
        }
        // p=4 tracks all attributes in 4 trees: memory strictly larger
        assert!(s4.model_bytes() > s1.model_bytes());
    }
}
