//! `samoa` — the leader entrypoint / CLI of samoa-rs.
//!
//! ```text
//! samoa run  --task prequential --learner vht --stream covtype [--p 4 ...]
//! samoa exp  fig4 [--instances 60000 --p 2,4 --seeds 3 --delay 100]
//! samoa exp  all
//! samoa list
//! samoa backend
//! ```
//!
//! `samoa run` is the paper's `PrequentialEvaluation` task runner;
//! `samoa exp` regenerates the paper's tables and figures (DESIGN.md §5).

use samoa::common::cli::Args;
use samoa::core::model::{Classifier, Regressor};
use samoa::evaluation::prequential::{
    prequential_run, prequential_run_regression, PrequentialConfig,
};
use samoa::experiments;
use samoa::runtime::backend_in_use;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // Hidden re-exec entrypoint: the cluster engine spawns `samoa
    // --cluster-worker <addr> ...` child processes (engine::cluster).
    if args.get("cluster-worker").is_some() {
        if let Err(e) = samoa::engine::cluster::worker_main(&args) {
            eprintln!("cluster worker error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "run" => cmd_run(&args),
        "exp" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            experiments::run(id, &args)
        }
        "list" => {
            println!("experiments: {:?}", experiments::ALL);
            println!("learners: moa | vht | sharding | nb | bag | boost | amrules | clustream");
            println!(
                "streams: random-tree | random-tweet | waveform | elec | phy | covtype | electricity | airlines | <path>.arff"
            );
            println!(
                "pipeline ops (--pipeline a,b,...): hash:D | scale | minmax | discretize:K | topk:K"
            );
            println!(
                "exp preprocess knobs: --p 1,2,4 --sync N|drift[:staleness]|hybrid[:interval] \
                 (0/off disables) --learner ht|amrules; fig8/fig9/fig12/fig13/fig14 also \
                 accept --pipeline"
            );
            println!(
                "exp sync-cost knobs: --p 4 --drift-every 0,2000 --drift-mag 4 \
                 --sync 64,256 --staleness 256,1024 --delta 0.002 (policy × interval × \
                 drift-rate sweep under the simtime cost model)"
            );
            println!(
                "exp flowcontrol knobs: --p 4 --spin 2000 --capacity 4,64,1024,0 \
                 --batch 32 --workers 0,2 (threaded-engine capacity × batch policy × \
                 scheduler sweep; 0 = unbounded / pinned)"
            );
            println!(
                "exp cluster knobs: --n 20000 --workers 2 --window 128 --stream elec \
                 --tcp --threads --peer [det|fast] --smoke (multi-process wire-cost \
                 sweep + relay/VHT/StatsSync workloads over sockets, measured vs \
                 SimCostModel; --peer ships key-routed hops worker↔worker)"
            );
            println!(
                "exp recovery knobs: --n 20000 --p 2 --stream elec --seed 42 \
                 --replay-cap 65536 --peer [det|fast] --smoke (checkpoint interval × \
                 kill point vs accuracy/throughput, threaded fault injection + cluster \
                 worker death; --peer kills a worker with live peer links)"
            );
            Ok(())
        }
        "backend" => {
            println!("criterion backend: {:?}", backend_in_use());
            println!(
                "artifacts dir: {:?}",
                samoa::runtime::registry::artifacts_dir()
            );
            println!("xla bindings compiled in: {}", samoa::runtime::xla::AVAILABLE);
            println!("(pin with SAMOA_BACKEND=native|simd|xla|auto; auto micro-probes once)");
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "samoa-rs — Apache SAMOA reproduction (rust + JAX/Pallas)\n\n\
         USAGE:\n  samoa run --learner <l> --stream <s> [--instances N] [--p K] [--pipeline hash:64,scale,...]\n  \
         samoa exp <fig3..fig16|table3..table7|all> [--instances N --seeds K --p 2,4]\n  \
         samoa list\n  samoa backend\n\nRun `samoa list` for learners/streams.\n\
         SAMOA_BACKEND=native|simd|xla|auto pins the criterion kernel backend (`samoa backend` shows the decision)."
    );
}

fn make_stream(name: &str, seed: u64, sparse_dim: u32) -> Box<dyn samoa::streams::StreamSource> {
    use samoa::streams::*;
    if name.ends_with(".arff") {
        return Box::new(
            arff::ArffStream::from_file(std::path::Path::new(name)).expect("parse arff"),
        );
    }
    match name {
        "random-tree" => Box::new(random_tree::RandomTreeGenerator::new(10, 10, 2, seed)),
        "random-tweet" => Box::new(random_tweet::RandomTweetGenerator::new(sparse_dim, seed)),
        "waveform" => Box::new(waveform::WaveformGenerator::new(seed)),
        "waveform-cls" => Box::new(waveform::WaveformGenerator::classification(seed)),
        other => experiments::dataset_stream(other, seed),
    }
}

fn cmd_run(args: &Args) -> samoa::Result<()> {
    let learner = args.get_or("learner", "vht");
    let stream_name = args.get_or("stream", "random-tree");
    let seed = args.u64("seed", 42);
    let n = args.u64("instances", 100_000);
    let p = args.usize("p", 4);
    let mut stream = make_stream(stream_name, seed, args.usize("dim", 1000) as u32);
    // --pipeline hash:64,scale,discretize:8 — route the source through a
    // preprocessing pipeline; every learner below sees the rewritten schema
    if let Some(spec) = args.get("pipeline") {
        let pipeline = samoa::preprocess::parse_pipeline(spec)?;
        println!("pipeline: {spec} -> stages {:?}", pipeline.stage_names());
        stream = Box::new(samoa::preprocess::TransformedStream::new(stream, pipeline));
    }
    let config = PrequentialConfig { max_instances: n, report_every: args.u64("report", n / 10) };
    let schema = stream.schema().clone();

    println!(
        "samoa run: learner={learner} stream={stream_name} instances={n} p={p} backend={:?}",
        backend_in_use()
    );

    if schema.is_regression() || learner == "amrules" {
        let mut model: Box<dyn Regressor> = Box::new(
            samoa::regressors::amrules::AMRules::new(schema, Default::default()),
        );
        let r = prequential_run_regression(model.as_mut(), stream.as_mut(), &config);
        println!(
            "instances={} mae={:.4} rmse={:.4} throughput={:.0}/s model={:.2}MB",
            r.instances,
            r.measure.mae(),
            r.measure.rmse(),
            r.throughput(),
            r.model_bytes as f64 / 1e6
        );
        return Ok(());
    }

    if learner == "clustream" {
        let mut model = samoa::clustering::clustream::CluStream::new(
            &schema,
            Default::default(),
            seed,
        );
        let started = std::time::Instant::now();
        let mut count = 0u64;
        while count < n {
            let Some(inst) = stream.next_instance() else { break };
            model.add(&inst);
            count += 1;
        }
        model.flush();
        model.run_macro();
        println!(
            "instances={count} micro-clusters={} macro-runs={} throughput={:.0}/s",
            model.n_micro(),
            model.macro_runs,
            count as f64 / started.elapsed().as_secs_f64()
        );
        return Ok(());
    }

    use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    // a hashing/filtering pipeline changes instance density, so only the
    // raw tweet stream gets the sparse observers
    let sparse = matches!(stream_name, "random-tweet") && args.get("pipeline").is_none();
    let ht_cfg = HTConfig { sparse, ..Default::default() };
    let mut model: Box<dyn Classifier> = match learner {
        "moa" | "ht" => Box::new(HoeffdingTree::new(schema.clone(), ht_cfg)),
        "nb" => Box::new(samoa::classifiers::naive_bayes::NaiveBayes::new(schema.clone())),
        "sharding" => Box::new(samoa::classifiers::sharding::Sharding::new(
            schema.clone(),
            ht_cfg,
            p,
        )),
        "bag" => {
            let s = schema.clone();
            Box::new(samoa::ensemble::oza_bag::OzaBag::new(
                &schema,
                p.max(2),
                seed,
                Box::new(move || -> Box<dyn Classifier> {
                    Box::new(HoeffdingTree::new(s.clone(), Default::default()))
                }),
            ))
        }
        "boost" => {
            let s = schema.clone();
            Box::new(samoa::ensemble::oza_boost::OzaBoost::new(
                &schema,
                p.max(2),
                seed,
                Box::new(move || Box::new(HoeffdingTree::new(s.clone(), Default::default()))),
            ))
        }
        "vht" => {
            // distributed VHT behind the sequential interface is exercised
            // via `samoa exp`; `run` uses the topology on the local engine
            return run_vht_task(args, stream.as_mut(), p, sparse, n);
        }
        other => samoa::bail!("unknown learner {other}"),
    };
    let r = prequential_run(model.as_mut(), stream.as_mut(), &config);
    println!(
        "instances={} accuracy={:.4} kappa={:.4} throughput={:.0}/s model={:.2}MB",
        r.instances,
        r.final_accuracy(),
        r.measure.kappa(),
        r.throughput(),
        r.model_bytes as f64 / 1e6
    );
    Ok(())
}

fn run_vht_task(
    args: &Args,
    stream: &mut dyn samoa::streams::StreamSource,
    p: usize,
    sparse: bool,
    n: u64,
) -> samoa::Result<()> {
    use samoa::classifiers::vht::{build_topology, SplitBuffering, VhtConfig};
    use samoa::engine::{LocalEngine, ThreadedEngine};
    use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use samoa::topology::Event;
    use std::sync::Arc;

    let config = VhtConfig {
        parallelism: p,
        sparse,
        feedback_delay: args.usize("delay", 0),
        buffering: match args.usize("buffer", 0) {
            0 => SplitBuffering::Discard,
            z => SplitBuffering::Buffer(z),
        },
        batch_attributes: !args.flag("no-batch"),
        ..Default::default()
    };
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, n / 10);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let started = std::time::Instant::now();
    let metrics = if args.flag("threaded") {
        ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {})
    } else {
        LocalEngine::new().run(&topo, handles.entry, source, |_| {})
    };
    println!(
        "instances={} accuracy={:.4} wall={:.2}s events={} attr-bytes={}",
        metrics.source_instances,
        sink.accuracy(),
        started.elapsed().as_secs_f64(),
        metrics.total_events(),
        metrics.streams[handles.streams.attribute.0].bytes,
    );
    Ok(())
}
