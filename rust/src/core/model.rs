//! Model traits consumed by the prequential evaluator: anything that can
//! test-then-train sequentially. Distributed algorithms implement these on
//! their *driver* wrappers (which pump a topology), sequential ones
//! directly.

use super::instance::Instance;


/// Streaming classifier.
pub trait Classifier: Send {
    /// Predict the class of `inst` (None if the model is still empty).
    fn predict(&self, inst: &Instance) -> Option<u32>;
    /// Train on a labeled instance.
    fn train(&mut self, inst: &Instance);
    /// Model-state bytes (Tables 6-7 reporting).
    fn model_bytes(&self) -> usize;
}

/// Streaming regressor.
pub trait Regressor: Send {
    fn predict(&self, inst: &Instance) -> f64;
    fn train(&mut self, inst: &Instance);
    fn model_bytes(&self) -> usize;
}

// (MemSize is the usual way to implement model_bytes)
#[allow(unused_imports)]
use crate::common::memsize as _memsize_doc;
