//! Native split criteria — the semantic twin of `python/compile/kernels`.
//!
//! These implementations follow `ref.py` exactly (same EPS policy: clamp
//! denominators, never add eps to counts, 0·log 0 = 0) so that the XLA
//! path and the native path are interchangeable to float tolerance. The
//! integration test `tests/runtime_vs_native.rs` enforces this.

use super::observers::CounterBlock;

/// Matches `_EPS` in ref.py.
pub const EPS: f64 = 1e-12;

/// Shannon entropy (bits) of an unnormalized count slice.
/// All-zero counts yield 0.
pub fn entropy(counts: &[f32]) -> f64 {
    let total: f64 = counts.iter().map(|&c| c as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0.0 {
            let p = c as f64 / total;
            h -= p * p.log2();
        }
    }
    h
}

/// Information gain of splitting on the attribute observed by `block`.
///
/// gain = H(class) - Σ_v (N_v / N) · H(class | value = v); 0 if empty.
pub fn info_gain(block: &CounterBlock) -> f64 {
    let total = block.total() as f64;
    if total <= 0.0 {
        return 0.0;
    }
    let h_before = entropy(&block.class_counts());
    let c = block.c() as usize;
    let mut h_after = 0.0;
    for v in 0..block.v() {
        let row = &block.raw()[(v as usize) * c..(v as usize + 1) * c];
        let nv: f64 = row.iter().map(|&x| x as f64).sum();
        if nv > 0.0 {
            h_after += (nv / total) * entropy(row);
        }
    }
    h_before - h_after
}

/// Gini impurity reduction — alternative criterion (ablation bench).
pub fn gini_gain(block: &CounterBlock) -> f64 {
    fn gini(counts: &[f32]) -> f64 {
        let total: f64 = counts.iter().map(|&c| c as f64).sum();
        if total <= 0.0 {
            return 0.0;
        }
        1.0 - counts
            .iter()
            .map(|&c| {
                let p = c as f64 / total;
                p * p
            })
            .sum::<f64>()
    }
    let total = block.total() as f64;
    if total <= 0.0 {
        return 0.0;
    }
    let g_before = gini(&block.class_counts());
    let c = block.c() as usize;
    let mut g_after = 0.0;
    for v in 0..block.v() {
        let row = &block.raw()[(v as usize) * c..(v as usize + 1) * c];
        let nv: f64 = row.iter().map(|&x| x as f64).sum();
        if nv > 0.0 {
            g_after += (nv / total) * gini(row);
        }
    }
    g_before - g_after
}

/// (count, sum, sum-of-squares) accumulator for regression targets.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VarStats {
    pub n: f64,
    pub sum: f64,
    pub sq: f64,
}

impl VarStats {
    #[inline]
    pub fn add(&mut self, y: f64, w: f64) {
        self.n += w;
        self.sum += w * y;
        self.sq += w * y * y;
    }

    pub fn merge(&self, other: &VarStats) -> VarStats {
        VarStats { n: self.n + other.n, sum: self.sum + other.sum, sq: self.sq + other.sq }
    }

    pub fn sub(&self, other: &VarStats) -> VarStats {
        VarStats { n: self.n - other.n, sum: self.sum - other.sum, sq: self.sq - other.sq }
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.n.max(EPS)
    }

    pub fn variance(&self) -> f64 {
        (self.sq / self.n.max(EPS) - self.mean() * self.mean()).max(0.0)
    }

    pub fn sd(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Standard-deviation reduction of splitting `total` into `left`/`right`
/// (matches `sdr_ref` in ref.py; empty side ⇒ 0).
pub fn sdr(total: &VarStats, left: &VarStats, right: &VarStats) -> f64 {
    if left.n <= 0.0 || right.n <= 0.0 {
        return 0.0;
    }
    let n = total.n.max(EPS);
    total.sd() - (left.n / n) * left.sd() - (right.n / n) * right.sd()
}

/// Full SDR surface over cumulative per-bin stats, as the XLA kernel
/// computes it: `bins[b]` holds the VarStats of target values whose
/// attribute fell in bin b; returns SDR for thresholds after each bin.
pub fn sdr_surface(bins: &[VarStats]) -> Vec<f64> {
    let total = bins.iter().fold(VarStats::default(), |a, b| a.merge(b));
    let mut out = Vec::with_capacity(bins.len());
    let mut left = VarStats::default();
    for b in bins {
        left = left.merge(b);
        let right = total.sub(&left);
        out.push(sdr(&total, &left, &right));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_basics() {
        assert_eq!(entropy(&[]), 0.0);
        assert_eq!(entropy(&[0.0, 0.0]), 0.0);
        assert!((entropy(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((entropy(&[1.0, 1.0, 1.0, 1.0]) - 2.0).abs() < 1e-12);
        assert_eq!(entropy(&[5.0]), 0.0);
    }

    #[test]
    fn info_gain_perfect_split() {
        // value v determines class v%2: gain = H(class) = 1 bit
        let mut b = CounterBlock::new(4, 2);
        for v in 0..4 {
            b.add(v, v % 2, 10.0);
        }
        assert!((info_gain(&b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn info_gain_useless_attribute() {
        // class independent of value: gain 0
        let mut b = CounterBlock::new(4, 2);
        for v in 0..4 {
            b.add(v, 0, 5.0);
            b.add(v, 1, 5.0);
        }
        assert!(info_gain(&b).abs() < 1e-9);
    }

    #[test]
    fn info_gain_empty_block_zero() {
        let b = CounterBlock::new(4, 2);
        assert_eq!(info_gain(&b), 0.0);
    }

    #[test]
    fn gini_orders_like_entropy_on_clear_cases() {
        let mut good = CounterBlock::new(2, 2);
        good.add(0, 0, 10.0);
        good.add(1, 1, 10.0);
        let mut bad = CounterBlock::new(2, 2);
        for v in 0..2 {
            bad.add(v, 0, 5.0);
            bad.add(v, 1, 5.0);
        }
        assert!(gini_gain(&good) > gini_gain(&bad));
    }

    #[test]
    fn varstats_moments() {
        let mut s = VarStats::default();
        for y in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(y, 1.0);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.sd() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sdr_perfect_separation() {
        let mut l = VarStats::default();
        let mut r = VarStats::default();
        for _ in 0..10 {
            l.add(0.0, 1.0);
            r.add(10.0, 1.0);
        }
        let t = l.merge(&r);
        // sd(total)=5, children sd=0 → sdr=5
        assert!((sdr(&t, &l, &r) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sdr_empty_side_invalid() {
        let mut l = VarStats::default();
        for y in [1.0, 2.0, 3.0] {
            l.add(y, 1.0);
        }
        let r = VarStats::default();
        let t = l.merge(&r);
        assert_eq!(sdr(&t, &l, &r), 0.0);
    }

    #[test]
    fn sdr_surface_peak_at_boundary() {
        // bins 0..4 hold y=0, bins 4..8 hold y=10 → best threshold after bin 3
        let mut bins = vec![VarStats::default(); 8];
        for (i, b) in bins.iter_mut().enumerate() {
            for _ in 0..5 {
                b.add(if i < 4 { 0.0 } else { 10.0 }, 1.0);
            }
        }
        let surf = sdr_surface(&bins);
        let best = surf
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 3);
        assert_eq!(*surf.last().unwrap(), 0.0); // right side empty at last bin
    }
}
