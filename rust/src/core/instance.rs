//! Instances: the unit of data flowing through every topology.
//!
//! Dense instances store all attribute values; sparse instances (the
//! random-tweet stream, §6.3) store only the non-zero (attribute, value)
//! pairs — VHT's vertical parallelism only ships the non-zeros downstream,
//! which is where the constant-per-instance overhead observed for sparse
//! data in Fig. 9 comes from.

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;

/// Attribute values of one instance.
#[derive(Clone, Debug, PartialEq)]
pub enum Values {
    Dense(Vec<f32>),
    /// Sorted by attribute index; attributes not present are 0.
    Sparse { indices: Vec<u32>, values: Vec<f32>, n_attributes: u32 },
}

/// Prediction target of one instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Label {
    Class(u32),
    Numeric(f64),
    /// Unlabeled (serving-only instance).
    None,
}

/// One stream element.
#[derive(Clone, Debug)]
pub struct Instance {
    pub values: Values,
    pub label: Label,
    pub weight: f32,
}

impl Instance {
    pub fn dense(values: Vec<f32>, label: Label) -> Self {
        Instance { values: Values::Dense(values), label, weight: 1.0 }
    }

    pub fn sparse(indices: Vec<u32>, values: Vec<f32>, n_attributes: u32, label: Label) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        debug_assert_eq!(indices.len(), values.len());
        Instance { values: Values::Sparse { indices, values, n_attributes }, label, weight: 1.0 }
    }

    /// Value of attribute `i` (0.0 for absent sparse attributes).
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        match &self.values {
            Values::Dense(v) => v[i],
            Values::Sparse { indices, values, .. } => {
                match indices.binary_search(&(i as u32)) {
                    Ok(pos) => values[pos],
                    Err(_) => 0.0,
                }
            }
        }
    }

    pub fn n_attributes(&self) -> usize {
        match &self.values {
            Values::Dense(v) => v.len(),
            Values::Sparse { n_attributes, .. } => *n_attributes as usize,
        }
    }

    /// Number of explicitly stored values (= attribute messages VHT sends).
    pub fn n_stored(&self) -> usize {
        match &self.values {
            Values::Dense(v) => v.len(),
            Values::Sparse { values, .. } => values.len(),
        }
    }

    /// Iterate (attribute index, value) over stored values.
    pub fn iter_stored(&self) -> Box<dyn Iterator<Item = (usize, f32)> + '_> {
        match &self.values {
            Values::Dense(v) => Box::new(v.iter().copied().enumerate()),
            Values::Sparse { indices, values, .. } => Box::new(
                indices.iter().zip(values.iter()).map(|(&i, &v)| (i as usize, v)),
            ),
        }
    }

    pub fn class(&self) -> Option<u32> {
        match self.label {
            Label::Class(c) => Some(c),
            _ => None,
        }
    }

    pub fn numeric_label(&self) -> Option<f64> {
        match self.label {
            Label::Numeric(y) => Some(y),
            _ => None,
        }
    }

    /// Approximate serialized size in bytes — drives the message-size cost
    /// model of `engine::simtime` and the Fig. 13 message-size sweep.
    pub fn wire_bytes(&self) -> usize {
        let payload = match &self.values {
            Values::Dense(v) => 4 * v.len(),
            Values::Sparse { values, .. } => 8 * values.len(),
        };
        payload + 16 // label + weight + framing
    }
}

impl MemSize for Instance {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + match &self.values {
                Values::Dense(v) => vec_flat_bytes(v),
                Values::Sparse { indices, values, .. } => {
                    vec_flat_bytes(indices) + vec_flat_bytes(values)
                }
            }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_access() {
        let i = Instance::dense(vec![1.0, 2.0, 3.0], Label::Class(1));
        assert_eq!(i.value(1), 2.0);
        assert_eq!(i.n_attributes(), 3);
        assert_eq!(i.class(), Some(1));
    }

    #[test]
    fn sparse_access_and_default_zero() {
        let i = Instance::sparse(vec![2, 7], vec![1.5, -3.0], 100, Label::Class(0));
        assert_eq!(i.value(2), 1.5);
        assert_eq!(i.value(7), -3.0);
        assert_eq!(i.value(3), 0.0);
        assert_eq!(i.n_attributes(), 100);
        assert_eq!(i.n_stored(), 2);
    }

    #[test]
    fn iter_stored_sparse() {
        let i = Instance::sparse(vec![1, 4], vec![9.0, 8.0], 10, Label::None);
        let v: Vec<_> = i.iter_stored().collect();
        assert_eq!(v, vec![(1, 9.0), (4, 8.0)]);
    }

    #[test]
    fn wire_bytes_sparse_smaller_than_dense_equivalent() {
        let s = Instance::sparse(vec![1, 2], vec![1.0, 1.0], 10_000, Label::None);
        let d = Instance::dense(vec![0.0; 10_000], Label::None);
        assert!(s.wire_bytes() < d.wire_bytes());
    }
}
