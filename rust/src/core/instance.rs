//! Instances: the unit of data flowing through every topology.
//!
//! Dense instances store all attribute values; sparse instances (the
//! random-tweet stream, §6.3) store only the non-zero (attribute, value)
//! pairs — VHT's vertical parallelism only ships the non-zeros downstream,
//! which is where the constant-per-instance overhead observed for sparse
//! data in Fig. 9 comes from.
//!
//! # Zero-copy data plane
//!
//! The attribute payload lives behind an [`Arc`], so `Instance::clone` is
//! a pointer bump + label/weight copy — an All-grouped broadcast at
//! parallelism `p` shares one heap payload across all `p` deliveries
//! instead of deep-copying it `p` times. Mutation goes through
//! [`Instance::values_mut`], which is copy-on-write (`Arc::make_mut`): a
//! sole owner mutates in place, a sharer first unshares. The constructor
//! and read API are unchanged from the pre-Arc layout.

use std::sync::Arc;

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;

/// Attribute values of one instance.
#[derive(Clone, Debug, PartialEq)]
pub enum Values {
    Dense(Vec<f32>),
    /// Sorted by attribute index; attributes not present are 0.
    Sparse { indices: Vec<u32>, values: Vec<f32>, n_attributes: u32 },
}

impl Values {
    /// Heap bytes of the payload itself (excluding any container).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Values::Dense(v) => vec_flat_bytes(v),
            Values::Sparse { indices, values, .. } => {
                vec_flat_bytes(indices) + vec_flat_bytes(values)
            }
        }
    }
}

/// Prediction target of one instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Label {
    Class(u32),
    Numeric(f64),
    /// Unlabeled (serving-only instance).
    None,
}

/// One stream element. Cloning shares the attribute payload (see the
/// module docs); `label` and `weight` stay per-clone, so e.g. the bagging
/// workers can re-weight their shared broadcast copy without touching the
/// other destinations.
#[derive(Clone, Debug)]
pub struct Instance {
    values: Arc<Values>,
    pub label: Label,
    pub weight: f32,
}

impl Instance {
    pub fn dense(values: Vec<f32>, label: Label) -> Self {
        Instance { values: Arc::new(Values::Dense(values)), label, weight: 1.0 }
    }

    pub fn sparse(indices: Vec<u32>, values: Vec<f32>, n_attributes: u32, label: Label) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        debug_assert_eq!(indices.len(), values.len());
        Instance {
            values: Arc::new(Values::Sparse { indices, values, n_attributes }),
            label,
            weight: 1.0,
        }
    }

    /// Read access to the attribute payload.
    #[inline]
    pub fn values(&self) -> &Values {
        &self.values
    }

    /// Mutable access — copy-on-write: clones the payload first iff it is
    /// currently shared with another `Instance`.
    #[inline]
    pub fn values_mut(&mut self) -> &mut Values {
        Arc::make_mut(&mut self.values)
    }

    /// The shared payload handle (tests / wrappers that need to check or
    /// extend sharing explicitly).
    #[inline]
    pub fn shared_values(&self) -> &Arc<Values> {
        &self.values
    }

    /// How many `Instance`s currently share this payload.
    pub fn payload_sharers(&self) -> usize {
        Arc::strong_count(&self.values)
    }

    /// Value of attribute `i` (0.0 for absent sparse attributes).
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        match self.values() {
            Values::Dense(v) => v[i],
            Values::Sparse { indices, values, .. } => {
                match indices.binary_search(&(i as u32)) {
                    Ok(pos) => values[pos],
                    Err(_) => 0.0,
                }
            }
        }
    }

    pub fn n_attributes(&self) -> usize {
        match self.values() {
            Values::Dense(v) => v.len(),
            Values::Sparse { n_attributes, .. } => *n_attributes as usize,
        }
    }

    /// Number of explicitly stored values (= attribute messages VHT sends).
    pub fn n_stored(&self) -> usize {
        match self.values() {
            Values::Dense(v) => v.len(),
            Values::Sparse { values, .. } => values.len(),
        }
    }

    /// Iterate (attribute index, value) over stored values.
    pub fn iter_stored(&self) -> Box<dyn Iterator<Item = (usize, f32)> + '_> {
        match self.values() {
            Values::Dense(v) => Box::new(v.iter().copied().enumerate()),
            Values::Sparse { indices, values, .. } => Box::new(
                indices.iter().zip(values.iter()).map(|(&i, &v)| (i as usize, v)),
            ),
        }
    }

    pub fn class(&self) -> Option<u32> {
        match self.label {
            Label::Class(c) => Some(c),
            _ => None,
        }
    }

    pub fn numeric_label(&self) -> Option<f64> {
        match self.label {
            Label::Numeric(y) => Some(y),
            _ => None,
        }
    }

    /// Approximate serialized size in bytes — drives the message-size cost
    /// model of `engine::simtime` and the Fig. 13 message-size sweep.
    /// Counts the full payload regardless of Arc sharing: the *wire* cost
    /// of a delivery is what a real DSPE would serialize.
    pub fn wire_bytes(&self) -> usize {
        let payload = match self.values() {
            Values::Dense(v) => 4 * v.len(),
            Values::Sparse { values, .. } => 8 * values.len(),
        };
        payload + 16 // label + weight + framing
    }

    /// Deep copy: unshares the payload (pre-refactor clone semantics; used
    /// by `Event::deep_clone` for bench baselines).
    pub fn deep_clone(&self) -> Self {
        Instance {
            values: Arc::new((*self.values).clone()),
            label: self.label,
            weight: self.weight,
        }
    }
}

impl MemSize for Instance {
    /// Arc-shared payloads are charged `payload / sharers` to each holder,
    /// so summing `mem_bytes` across all holders counts the payload
    /// exactly once (a sole owner is charged in full). See
    /// `common::memsize` for the convention.
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.values.payload_bytes() / Arc::strong_count(&self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_access() {
        let i = Instance::dense(vec![1.0, 2.0, 3.0], Label::Class(1));
        assert_eq!(i.value(1), 2.0);
        assert_eq!(i.n_attributes(), 3);
        assert_eq!(i.class(), Some(1));
    }

    #[test]
    fn sparse_access_and_default_zero() {
        let i = Instance::sparse(vec![2, 7], vec![1.5, -3.0], 100, Label::Class(0));
        assert_eq!(i.value(2), 1.5);
        assert_eq!(i.value(7), -3.0);
        assert_eq!(i.value(3), 0.0);
        assert_eq!(i.n_attributes(), 100);
        assert_eq!(i.n_stored(), 2);
    }

    #[test]
    fn iter_stored_sparse() {
        let i = Instance::sparse(vec![1, 4], vec![9.0, 8.0], 10, Label::None);
        let v: Vec<_> = i.iter_stored().collect();
        assert_eq!(v, vec![(1, 9.0), (4, 8.0)]);
    }

    #[test]
    fn wire_bytes_sparse_smaller_than_dense_equivalent() {
        let s = Instance::sparse(vec![1, 2], vec![1.0, 1.0], 10_000, Label::None);
        let d = Instance::dense(vec![0.0; 10_000], Label::None);
        assert!(s.wire_bytes() < d.wire_bytes());
    }

    #[test]
    fn clone_shares_payload_and_cow_unshares() {
        let a = Instance::dense(vec![1.0, 2.0], Label::Class(0));
        let mut b = a.clone();
        assert_eq!(a.payload_sharers(), 2);
        assert!(Arc::ptr_eq(a.shared_values(), b.shared_values()));
        // label/weight are per-clone
        b.weight = 3.0;
        assert_eq!(a.weight, 1.0);
        // mutation unshares (copy-on-write); the original is untouched
        if let Values::Dense(v) = b.values_mut() {
            v[0] = 9.0;
        }
        assert_eq!(a.value(0), 1.0);
        assert_eq!(b.value(0), 9.0);
        assert_eq!(a.payload_sharers(), 1);
    }

    #[test]
    fn deep_clone_unshares_immediately() {
        let a = Instance::dense(vec![1.0], Label::None);
        let b = a.deep_clone();
        assert_eq!(a.payload_sharers(), 1);
        assert!(!Arc::ptr_eq(a.shared_values(), b.shared_values()));
    }

    #[test]
    fn mem_bytes_counts_shared_payload_once() {
        let a = Instance::dense(vec![0.0; 256], Label::None);
        let solo = a.mem_bytes();
        assert!(solo >= std::mem::size_of::<Instance>() + 256 * 4);
        let b = a.clone();
        // each holder is charged half; the pair sums to one payload
        let shared = a.mem_bytes();
        assert!(shared < solo);
        assert_eq!(
            a.mem_bytes() + b.mem_bytes(),
            2 * std::mem::size_of::<Instance>() + a.values.payload_bytes() / 2 * 2
        );
        drop(b);
        assert_eq!(a.mem_bytes(), solo, "sole owner is charged in full again");
    }
}
