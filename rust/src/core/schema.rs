//! Stream schema: attribute kinds and target description.
//!
//! SAMOA follows MOA/Weka's `InstancesHeader`; we keep a lean equivalent.
//! Numeric attributes are observed through equal-width histograms
//! (`core::observers`), so the schema also records the global bin count,
//! which must match the compile-time `V` of the XLA info-gain artifact.

/// Kind of a single attribute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AttributeKind {
    /// Categorical with `n_values` distinct values (0..n_values).
    Categorical { n_values: u32 },
    /// Real-valued; observed via histogram binning.
    Numeric,
}

/// Prediction target.
#[derive(Clone, Debug, PartialEq)]
pub enum TargetKind {
    /// Classification into `n_classes` classes.
    Class { n_classes: u32 },
    /// Regression with (approximately) known label range, used for
    /// normalized MAE/RMSE reporting as in the paper's Figs 14-16.
    Numeric { min: f64, max: f64 },
}

/// Schema shared by a stream and the models consuming it.
#[derive(Clone, Debug)]
pub struct Schema {
    pub attributes: Vec<AttributeKind>,
    pub target: TargetKind,
    /// Histogram bins used for numeric attributes (must be <= the XLA
    /// artifact's V dimension; see runtime::shapes).
    pub numeric_bins: u32,
    pub name: String,
}

impl Schema {
    pub fn classification(
        name: &str,
        attributes: Vec<AttributeKind>,
        n_classes: u32,
    ) -> Self {
        Schema {
            attributes,
            target: TargetKind::Class { n_classes },
            numeric_bins: 16,
            name: name.to_string(),
        }
    }

    pub fn regression(name: &str, attributes: Vec<AttributeKind>, min: f64, max: f64) -> Self {
        Schema {
            attributes,
            target: TargetKind::Numeric { min, max },
            numeric_bins: 16,
            name: name.to_string(),
        }
    }

    /// Convenience: `n` numeric attributes.
    pub fn all_numeric(n: usize) -> Vec<AttributeKind> {
        vec![AttributeKind::Numeric; n]
    }

    /// Convenience: `n` categorical attributes with `v` values each.
    pub fn all_categorical(n: usize, v: u32) -> Vec<AttributeKind> {
        vec![AttributeKind::Categorical { n_values: v }; n]
    }

    pub fn n_attributes(&self) -> usize {
        self.attributes.len()
    }

    pub fn n_classes(&self) -> u32 {
        match self.target {
            TargetKind::Class { n_classes } => n_classes,
            TargetKind::Numeric { .. } => 0,
        }
    }

    pub fn is_regression(&self) -> bool {
        matches!(self.target, TargetKind::Numeric { .. })
    }

    /// Number of observable values for attribute `i` (bins for numeric).
    pub fn arity(&self, i: usize) -> u32 {
        match self.attributes[i] {
            AttributeKind::Categorical { n_values } => n_values,
            AttributeKind::Numeric => self.numeric_bins,
        }
    }

    /// Schema rewriting (preprocessing pipelines): same target and bin
    /// configuration, new name and attribute layout. Transforms that
    /// re-project the attribute space ([`crate::preprocess`]) derive their
    /// output schema with this.
    pub fn with_attributes(&self, name: &str, attributes: Vec<AttributeKind>) -> Schema {
        Schema {
            attributes,
            target: self.target.clone(),
            numeric_bins: self.numeric_bins,
            name: name.to_string(),
        }
    }

    /// Range of the label values (for normalized regression error).
    pub fn label_range(&self) -> f64 {
        match self.target {
            TargetKind::Numeric { min, max } => (max - min).max(1e-12),
            TargetKind::Class { .. } => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_of_numeric_is_bins() {
        let s = Schema::classification("t", Schema::all_numeric(3), 2);
        assert_eq!(s.arity(0), 16);
    }

    #[test]
    fn arity_of_categorical() {
        let s = Schema::classification("t", Schema::all_categorical(2, 5), 2);
        assert_eq!(s.arity(1), 5);
    }

    #[test]
    fn label_range_regression() {
        let s = Schema::regression("r", Schema::all_numeric(1), -2.0, 8.0);
        assert_eq!(s.label_range(), 10.0);
        assert!(s.is_regression());
    }
}
