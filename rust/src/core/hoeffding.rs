//! The Hoeffding bound — the statistical heart of VFDT/VHT (paper §6):
//!
//! ε = sqrt( R² ln(1/δ) / 2n )
//!
//! guarantees that when the observed gain difference ΔG between the best
//! and second-best attribute exceeds ε, the best attribute is truly best
//! with probability ≥ 1 − δ.

/// Hoeffding bound for criterion range `r`, confidence `delta`, `n` obs.
#[inline]
pub fn hoeffding_bound(r: f64, delta: f64, n: f64) -> f64 {
    ((r * r * (1.0 / delta).ln()) / (2.0 * n.max(1.0))).sqrt()
}

/// Range R of information gain with `n_classes` (log2 C bits).
#[inline]
pub fn infogain_range(n_classes: u32) -> f64 {
    (n_classes.max(2) as f64).log2()
}

/// Split decision given the two best scores (paper Alg. 4, line 5):
/// split if ΔG > ε, or tie-break if ε < τ.
#[inline]
pub fn should_split(best: f64, second: f64, epsilon: f64, tau: f64) -> bool {
    let dg = best - second;
    dg > epsilon || epsilon < tau
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_shrinks_with_n() {
        let e1 = hoeffding_bound(1.0, 1e-7, 200.0);
        let e2 = hoeffding_bound(1.0, 1e-7, 20_000.0);
        assert!(e2 < e1);
        assert!((e1 / e2 - 10.0).abs() < 1e-9); // 1/sqrt(n) scaling
    }

    #[test]
    fn bound_grows_with_range() {
        assert!(hoeffding_bound(3.0, 1e-7, 100.0) > hoeffding_bound(1.0, 1e-7, 100.0));
    }

    #[test]
    fn range_of_binary_is_one_bit() {
        assert_eq!(infogain_range(2), 1.0);
        assert!((infogain_range(8) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn split_decision_cases() {
        assert!(should_split(0.5, 0.1, 0.2, 0.05)); // clear winner
        assert!(!should_split(0.5, 0.45, 0.2, 0.05)); // too close, ε big
        assert!(should_split(0.5, 0.49, 0.04, 0.05)); // tie-break: ε < τ
    }
}
