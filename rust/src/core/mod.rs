//! Core ML substrate: instance & schema types, attribute observers
//! (the `n_ijk` counters of the paper), split criteria and the Hoeffding
//! bound. Everything above (trees, rules, processors) builds on these.

pub mod schema;
pub mod instance;
pub mod observers;
pub mod criterion;
pub mod hoeffding;
pub mod model;

pub use instance::Instance;
pub use schema::{AttributeKind, Schema, TargetKind};
