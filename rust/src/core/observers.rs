//! Attribute observers: the `n_ijk` sufficient statistics of the paper.
//!
//! Every attribute — categorical or numeric — is observed as a `[V, C]`
//! counter block (`V` = arity or histogram bins, `C` = classes). This
//! uniformity is what lets one XLA/Pallas kernel evaluate the split
//! criterion for any attribute mix (DESIGN.md §6), and it mirrors the
//! "local statistics as a big table indexed by (leaf, attribute)" picture
//! of the paper.
//!
//! Numeric attributes use an equal-width histogram whose range is frozen
//! after a warm-up sample (values outside are clamped to edge bins) — the
//! standard discretized-observer substitution for MOA's Gaussian observer,
//! documented in DESIGN.md §3.

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;

/// Counter block for one attribute at one leaf/rule: flat `[V, C]` f32.
#[derive(Clone, Debug)]
pub struct CounterBlock {
    counts: Vec<f32>,
    v: u32,
    c: u32,
}

impl CounterBlock {
    pub fn new(v: u32, c: u32) -> Self {
        CounterBlock { counts: vec![0.0; (v * c) as usize], v, c }
    }

    #[inline]
    pub fn add(&mut self, value_bin: u32, class: u32, weight: f32) {
        debug_assert!(value_bin < self.v && class < self.c);
        self.counts[(value_bin * self.c + class) as usize] += weight;
    }

    #[inline]
    pub fn get(&self, value_bin: u32, class: u32) -> f32 {
        self.counts[(value_bin * self.c + class) as usize]
    }

    pub fn v(&self) -> u32 {
        self.v
    }

    pub fn c(&self) -> u32 {
        self.c
    }

    pub fn raw(&self) -> &[f32] {
        &self.counts
    }

    pub fn total(&self) -> f32 {
        self.counts.iter().sum()
    }

    /// Class marginals: sum over values → `[C]`.
    pub fn class_counts(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.c as usize];
        for v in 0..self.v as usize {
            for c in 0..self.c as usize {
                out[c] += self.counts[v * self.c as usize + c];
            }
        }
        out
    }

    /// Copy into a padded `[v_pad, c_pad]` destination slice (row-major),
    /// used when marshalling into the fixed-shape XLA artifact input.
    pub fn copy_padded(&self, dst: &mut [f32], v_pad: usize, c_pad: usize) {
        debug_assert!(dst.len() >= v_pad * c_pad);
        debug_assert!(self.v as usize <= v_pad && self.c as usize <= c_pad);
        for v in 0..self.v as usize {
            let src = &self.counts[v * self.c as usize..(v + 1) * self.c as usize];
            dst[v * c_pad..v * c_pad + self.c as usize].copy_from_slice(src);
        }
    }
}

impl MemSize for CounterBlock {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + vec_flat_bytes(&self.counts)
    }
}

/// Maps raw numeric values to histogram bins with a frozen equal-width
/// range learned from the first `warmup` observations.
#[derive(Clone, Debug)]
pub struct Binner {
    bins: u32,
    warmup: u32,
    seen: u32,
    min: f64,
    max: f64,
    frozen: bool,
    buffer: Vec<f32>,
}

impl Binner {
    pub fn new(bins: u32) -> Self {
        Binner {
            bins,
            warmup: 100,
            seen: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            frozen: false,
            buffer: Vec::new(),
        }
    }

    /// Observe a value and return its bin.
    #[inline]
    pub fn observe(&mut self, x: f32) -> u32 {
        if !self.frozen {
            self.min = self.min.min(x as f64);
            self.max = self.max.max(x as f64);
            self.seen += 1;
            self.buffer.push(x);
            if self.seen >= self.warmup {
                self.freeze();
            }
            // during warm-up use the running range
        }
        self.bin_of(x)
    }

    fn freeze(&mut self) {
        if self.max <= self.min {
            self.max = self.min + 1.0;
        }
        self.frozen = true;
        self.buffer.clear();
        self.buffer.shrink_to_fit();
    }

    /// Bin of a value under the current range (clamped to edge bins).
    #[inline]
    pub fn bin_of(&self, x: f32) -> u32 {
        if !self.min.is_finite() || self.max <= self.min {
            return 0;
        }
        let t = ((x as f64 - self.min) / (self.max - self.min)) * self.bins as f64;
        (t.floor().max(0.0) as u32).min(self.bins - 1)
    }

    /// Value threshold at the upper edge of `bin` — used to express a
    /// learned split/feature in original units.
    pub fn threshold(&self, bin: u32) -> f64 {
        self.min + (self.max - self.min) * (bin + 1) as f64 / self.bins as f64
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen
    }
}

impl MemSize for Binner {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + vec_flat_bytes(&self.buffer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_block_add_get() {
        let mut b = CounterBlock::new(4, 3);
        b.add(2, 1, 1.0);
        b.add(2, 1, 0.5);
        assert_eq!(b.get(2, 1), 1.5);
        assert_eq!(b.total(), 1.5);
    }

    #[test]
    fn class_counts_marginal() {
        let mut b = CounterBlock::new(2, 2);
        b.add(0, 0, 1.0);
        b.add(1, 0, 2.0);
        b.add(1, 1, 3.0);
        assert_eq!(b.class_counts(), vec![3.0, 3.0]);
    }

    #[test]
    fn copy_padded_layout() {
        let mut b = CounterBlock::new(2, 2);
        b.add(0, 1, 5.0);
        b.add(1, 0, 7.0);
        let mut dst = vec![0.0; 4 * 3]; // pad to [4,3]
        b.copy_padded(&mut dst, 4, 3);
        assert_eq!(dst[1], 5.0); // (v=0,c=1)
        assert_eq!(dst[3], 7.0); // (v=1,c=0)
        assert_eq!(dst.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn binner_freezes_and_clamps() {
        let mut b = Binner::new(16);
        for i in 0..100 {
            b.observe(i as f32);
        }
        assert!(b.is_frozen());
        assert_eq!(b.bin_of(-100.0), 0);
        assert_eq!(b.bin_of(1e9), 15);
        let mid = b.bin_of(49.5);
        assert!(mid > 4 && mid < 12, "mid={mid}");
    }

    #[test]
    fn binner_monotone() {
        let mut b = Binner::new(8);
        for i in 0..200 {
            b.observe((i % 100) as f32);
        }
        let mut last = 0;
        for x in [0.0f32, 20.0, 40.0, 60.0, 80.0, 99.0] {
            let bin = b.bin_of(x);
            assert!(bin >= last);
            last = bin;
        }
    }

    #[test]
    fn binner_constant_values_single_bin() {
        let mut b = Binner::new(16);
        for _ in 0..150 {
            b.observe(5.0);
        }
        assert_eq!(b.bin_of(5.0), 0);
    }
}
