//! The [`Processor`] trait — the container for user algorithm code — and
//! the [`Ctx`] handed to it for emitting events downstream.

use super::builder::StreamId;
use super::event::Event;

/// Emission buffer + identity information passed to `Processor::process`.
///
/// Emissions are buffered and routed by the engine *after* the call
/// returns; a processor never blocks inside `process` (the threaded
/// engine applies backpressure at the routing step).
pub struct Ctx {
    /// Which instance of the logical processor this is (0..parallelism).
    pub instance: usize,
    /// Parallelism of this logical processor.
    pub parallelism: usize,
    pub(crate) out: Vec<(StreamId, u64, Event)>,
}

impl Ctx {
    pub(crate) fn new(instance: usize, parallelism: usize) -> Self {
        Ctx { instance, parallelism, out: Vec::new() }
    }

    /// Emit `event` on `stream`. `key` is used by key-grouped streams to
    /// pick the destination instance (ignored by shuffle/all).
    #[inline]
    pub fn emit(&mut self, stream: StreamId, key: u64, event: Event) {
        self.out.push((stream, key, event));
    }

    /// Emit with no meaningful key (shuffle / all / parallelism-1 streams).
    #[inline]
    pub fn emit_any(&mut self, stream: StreamId, event: Event) {
        self.out.push((stream, 0, event));
    }

    pub(crate) fn take(&mut self) -> Vec<(StreamId, u64, Event)> {
        std::mem::take(&mut self.out)
    }
}

/// A node in the topology. One logical processor may be instantiated
/// `parallelism` times; each instance owns independent state.
pub trait Processor: Send {
    /// Handle one content event.
    fn process(&mut self, event: Event, ctx: &mut Ctx);

    /// Called once when the engine shuts the topology down; flush any
    /// buffered state (e.g. pending predictions).
    fn on_shutdown(&mut self, _ctx: &mut Ctx) {}

    /// Estimated model-state bytes (Tables 6-7).
    fn mem_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "processor"
    }

    /// Concrete-type escape hatch for state inspection (harness/tests):
    /// implementors return `Some(self)` to allow `downcast_ref`.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }

    /// Named scalar state counters, collected by engines after shutdown.
    /// Unlike [`Processor::as_any`] this crosses *process* boundaries:
    /// the cluster engine (`engine::cluster`) serializes these pairs from
    /// worker processes back to the coordinator, where `as_any`
    /// downcasting is impossible. Implement it on processors whose final
    /// state tests/experiments need (evaluators, stats aggregators, model
    /// aggregators); the default is no report.
    fn report(&self) -> Vec<(&'static str, f64)> {
        Vec::new()
    }

    /// Serialize this instance's recoverable state into a checkpoint
    /// frame (`engine::checkpoint` format). `None` — the default — marks
    /// a stateless (or non-recoverable) processor: the engines skip it
    /// during checkpoint rounds and a respawned replacement starts
    /// fresh, rebuilding from the replayed delta alone.
    ///
    /// Contract with [`Processor::restore`]: for every state reachable
    /// by `process`, `restore(snapshot())` on a freshly built instance
    /// must reproduce the captured state bit-exactly (the
    /// `checkpoint_roundtrip` suite pins this per impl).
    fn snapshot(&self) -> Option<Vec<u8>> {
        None
    }

    /// Adopt a checkpoint frame previously produced by
    /// [`Processor::snapshot`] on an instance of the same concrete type
    /// and shape. Called on a freshly built instance before any replayed
    /// events. Errors abort the recovery (the engine surfaces them).
    fn restore(&mut self, _frame: &[u8]) -> crate::Result<()> {
        Ok(())
    }
}

/// Blanket helper so `Box<dyn Processor>` also implements `Processor`.
impl Processor for Box<dyn Processor> {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        (**self).process(event, ctx)
    }

    fn on_shutdown(&mut self, ctx: &mut Ctx) {
        (**self).on_shutdown(ctx)
    }

    fn mem_bytes(&self) -> usize {
        (**self).mem_bytes()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        (**self).as_any()
    }

    fn report(&self) -> Vec<(&'static str, f64)> {
        (**self).report()
    }

    fn snapshot(&self) -> Option<Vec<u8>> {
        (**self).snapshot()
    }

    fn restore(&mut self, frame: &[u8]) -> crate::Result<()> {
        (**self).restore(frame)
    }
}
