//! Tasks — execution entities (paper §4: "a Topology is instantiated
//! inside a Task to be run"). A task supplies the topology, the source
//! stream of instances, and knows which stream carries source events.

use super::builder::{StreamId, Topology};
use crate::streams::StreamSource;

/// A runnable unit: topology + instance source + entry stream.
pub struct Task {
    pub topology: Topology,
    pub source: Box<dyn StreamSource>,
    /// Stream on which the engine injects `Event::Instance`.
    pub entry: StreamId,
    /// Stop after this many source instances (0 = until exhausted).
    pub max_instances: u64,
}
