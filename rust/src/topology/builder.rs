//! `TopologyBuilder` — connects user processors and streams and performs
//! the bookkeeping (ids, parallelism, routing tables) the engines need.
//!
//! Mirrors the paper's §4 code snippet:
//! ```ignore
//! let mut b = TopologyBuilder::new();
//! let ma = b.add_processor("model-aggregator", 1, |_| Box::new(...));
//! let ls = b.add_processor("local-statistics", p, |i| Box::new(...));
//! let attr = b.stream(Some(ma), ls, Grouping::Key);
//! ```

use super::processor::Processor;
use super::stream::Grouping;

/// Logical processor handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProcessorId(pub usize);

/// Stream handle (index into the topology's stream table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamId(pub usize);

/// A logical processor: `parallelism` instances created by `factory`.
pub struct ProcessorDef {
    pub name: String,
    pub parallelism: usize,
    pub factory: Box<dyn Fn(usize) -> Box<dyn Processor>>,
}

/// A stream: routing policy + endpoints.
#[derive(Clone, Debug)]
pub struct StreamDef {
    pub name: String,
    /// `None` when events are injected by the engine (source stream).
    pub from: Option<ProcessorId>,
    pub to: ProcessorId,
    pub grouping: Grouping,
    /// Extra delivery delay in *source instances* applied by the local
    /// engine — models the MA↔LS feedback latency of a real DSPE
    /// deterministically (see `engine::local`). Ignored by the threaded
    /// engine, where queues create delay naturally.
    pub delay: usize,
}

/// An assembled topology, ready for an engine to materialize.
pub struct Topology {
    pub name: String,
    pub processors: Vec<ProcessorDef>,
    pub streams: Vec<StreamDef>,
}

impl Topology {
    pub fn total_instances(&self) -> usize {
        self.processors.iter().map(|p| p.parallelism).sum()
    }
}

/// Builder with the bookkeeping of the paper's TopologyBuilder.
pub struct TopologyBuilder {
    name: String,
    processors: Vec<ProcessorDef>,
    streams: Vec<StreamDef>,
}

impl TopologyBuilder {
    pub fn new(name: &str) -> Self {
        TopologyBuilder { name: name.to_string(), processors: Vec::new(), streams: Vec::new() }
    }

    /// Register a logical processor with `parallelism` instances.
    pub fn add_processor<F>(&mut self, name: &str, parallelism: usize, factory: F) -> ProcessorId
    where
        F: Fn(usize) -> Box<dyn Processor> + 'static,
    {
        assert!(parallelism >= 1, "parallelism must be >= 1");
        self.processors.push(ProcessorDef {
            name: name.to_string(),
            parallelism,
            factory: Box::new(factory),
        });
        ProcessorId(self.processors.len() - 1)
    }

    /// Create a stream from `from` (or the engine source if `None`) to `to`.
    pub fn stream(
        &mut self,
        name: &str,
        from: Option<ProcessorId>,
        to: ProcessorId,
        grouping: Grouping,
    ) -> StreamId {
        self.stream_delayed(name, from, to, grouping, 0)
    }

    /// Like [`Self::stream`] but with a local-engine delivery delay.
    pub fn stream_delayed(
        &mut self,
        name: &str,
        from: Option<ProcessorId>,
        to: ProcessorId,
        grouping: Grouping,
        delay: usize,
    ) -> StreamId {
        assert!(to.0 < self.processors.len(), "unknown destination processor");
        if let Some(f) = from {
            assert!(f.0 < self.processors.len(), "unknown source processor");
        }
        self.streams.push(StreamDef {
            name: name.to_string(),
            from,
            to,
            grouping,
            delay,
        });
        StreamId(self.streams.len() - 1)
    }

    pub fn build(self) -> Topology {
        Topology { name: self.name, processors: self.processors, streams: self.streams }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::event::Event;
    use crate::topology::processor::Ctx;

    struct Nop;
    impl Processor for Nop {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {}
    }

    #[test]
    fn builds_graph() {
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 1, |_| Box::new(Nop));
        let c = b.add_processor("c", 4, |_| Box::new(Nop));
        let s = b.stream("a->c", Some(a), c, Grouping::Key);
        let t = b.build();
        assert_eq!(t.processors.len(), 2);
        assert_eq!(t.streams[s.0].to, c);
        assert_eq!(t.total_instances(), 5);
    }

    #[test]
    #[should_panic]
    fn zero_parallelism_panics() {
        let mut b = TopologyBuilder::new("t");
        b.add_processor("a", 0, |_| Box::new(Nop));
    }
}
