//! Wire codec for [`Event`] — the serialization layer of the cluster
//! engine (`engine::cluster`).
//!
//! Until this module existed every byte in the crate moved through
//! in-process channels and `Event::wire_bytes` merely *estimated* what a
//! real DSPE would serialize. The codec makes that number physical: every
//! `Event` variant round-trips through a serde-free, length-prefixed
//! frame encoding, so the cluster engine ships real bytes over real
//! sockets and the measured frame sizes can be compared against the
//! `wire_bytes()` estimate and the simtime cost model.
//!
//! # Frame format
//!
//! A frame is `len: u32` (little-endian, byte count of everything after
//! the prefix) followed by `kind: u8` and a kind-specific body. Event
//! bodies are `tag: u8` (one tag per `Event` variant, in declaration
//! order) followed by the variant's fields in declaration order:
//!
//! * integers and floats are fixed-width little-endian (`f32`/`f64` via
//!   `to_le_bytes`, so NaN payload bits survive — the NaN-*tagged* sparse
//!   stats encoding of `preprocess::wire` rides through `StatsDelta`
//!   payloads bit-exactly; this module generalizes that format's
//!   "no-serde, exact-bits" philosophy to every event),
//! * `Vec<T>` is `len: u32` then the elements,
//! * enums (`Label`, `Output`, `Values`, `Op`, `Option`) are a one-byte
//!   discriminant then the payload of the active arm.
//!
//! Decoding is bounds-checked everywhere ([`Reader`]): truncated input,
//! trailing garbage inside a counted region, unknown tags and unknown
//! discriminants all return `Err`, never panic — a corrupt or hostile
//! peer cannot take down a worker.

use std::sync::Arc;

use crate::core::instance::{Instance, Label, Values};
use crate::regressors::rule::{Feature, HeadSnapshot, Op, RuleSpec};
use crate::Result;

use super::event::{Event, Output};

/// Upper bound a reader accepts for one frame's length prefix. Far above
/// any legitimate event (the largest payloads are stats vectors of a few
/// thousand f64s) while small enough that a corrupt length cannot ask the
/// receiver to allocate gigabytes.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

// ---------------------------------------------------------------- writing

#[inline]
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

#[inline]
pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        put_f32(out, *v);
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        put_f64(out, *v);
    }
}

fn put_label(out: &mut Vec<u8>, label: &Label) {
    match label {
        Label::Class(c) => {
            put_u8(out, 0);
            put_u32(out, *c);
        }
        Label::Numeric(y) => {
            put_u8(out, 1);
            put_f64(out, *y);
        }
        Label::None => put_u8(out, 2),
    }
}

fn put_output(out: &mut Vec<u8>, output: &Output) {
    match output {
        Output::Class(c) => {
            put_u8(out, 0);
            put_u32(out, *c);
        }
        Output::Numeric(y) => {
            put_u8(out, 1);
            put_f64(out, *y);
        }
        Output::None => put_u8(out, 2),
    }
}

fn put_instance(out: &mut Vec<u8>, inst: &Instance) {
    match inst.values() {
        Values::Dense(v) => {
            put_u8(out, 0);
            put_f32s(out, v);
        }
        Values::Sparse { indices, values, n_attributes } => {
            put_u8(out, 1);
            put_u32(out, indices.len() as u32);
            for i in indices {
                put_u32(out, *i);
            }
            for v in values {
                put_f32(out, *v);
            }
            put_u32(out, *n_attributes);
        }
    }
    put_label(out, &inst.label);
    put_f32(out, inst.weight);
}

fn put_feature(out: &mut Vec<u8>, f: &Feature) {
    put_u32(out, f.attr);
    put_u8(
        out,
        match f.op {
            Op::Le => 0,
            Op::Gt => 1,
            Op::Eq => 2,
        },
    );
    put_f64(out, f.threshold);
}

fn put_head(out: &mut Vec<u8>, head: &HeadSnapshot) {
    put_f64(out, head.mean);
    match &head.weights {
        Some(w) => {
            put_u8(out, 1);
            put_f64s(out, w);
        }
        None => put_u8(out, 0),
    }
}

/// Append the tagged body of `event` to `out` (no length prefix — the
/// frame layer of `engine::cluster` adds it around the whole frame).
pub fn encode_event(event: &Event, out: &mut Vec<u8>) {
    match event {
        Event::Instance { id, inst } => {
            put_u8(out, 1);
            put_u64(out, *id);
            put_instance(out, inst);
        }
        Event::Prediction { id, truth, output } => {
            put_u8(out, 2);
            put_u64(out, *id);
            put_label(out, truth);
            put_output(out, output);
        }
        Event::Shutdown => put_u8(out, 3),
        Event::StatsDelta { stage, shard, round, payload } => {
            put_u8(out, 4);
            put_u32(out, *stage);
            put_u32(out, *shard);
            put_u64(out, *round);
            put_f64s(out, payload);
        }
        Event::StatsGlobal { stage, payload } => {
            put_u8(out, 5);
            put_u32(out, *stage);
            put_f64s(out, payload);
        }
        Event::Attribute { leaf, attr, value, class, weight } => {
            put_u8(out, 6);
            put_u64(out, *leaf);
            put_u32(out, *attr);
            put_f32(out, *value);
            put_u32(out, *class);
            put_f32(out, *weight);
        }
        Event::AttributeBatch { leaf, class, weight, attrs } => {
            put_u8(out, 7);
            put_u64(out, *leaf);
            put_u32(out, *class);
            put_f32(out, *weight);
            put_u32(out, attrs.len() as u32);
            for (a, v) in attrs.iter() {
                put_u32(out, *a);
                put_u8(out, *v);
            }
        }
        Event::Compute { leaf, seq, n_l, class_counts } => {
            put_u8(out, 8);
            put_u64(out, *leaf);
            put_u32(out, *seq);
            put_f64(out, *n_l);
            put_f32s(out, class_counts);
        }
        Event::LocalResult { leaf, seq, best_attr, best, second_attr, second, best_dist } => {
            put_u8(out, 9);
            put_u64(out, *leaf);
            put_u32(out, *seq);
            put_u32(out, *best_attr);
            put_f64(out, *best);
            put_u32(out, *second_attr);
            put_f64(out, *second);
            put_f32s(out, best_dist);
        }
        Event::DropLeaf { leaf } => {
            put_u8(out, 10);
            put_u64(out, *leaf);
        }
        Event::RuleInstance { rule, inst } => {
            put_u8(out, 11);
            put_u32(out, *rule);
            put_instance(out, inst);
        }
        Event::NewRule { rule, spec } => {
            put_u8(out, 12);
            put_u32(out, *rule);
            put_u32(out, spec.features.len() as u32);
            for f in &spec.features {
                put_feature(out, f);
            }
            put_head(out, &spec.head);
        }
        Event::RuleFeature { rule, feature, head } => {
            put_u8(out, 13);
            put_u32(out, *rule);
            put_feature(out, feature);
            put_head(out, head);
        }
        Event::RuleHead { rule, head } => {
            put_u8(out, 14);
            put_u32(out, *rule);
            put_head(out, head);
        }
        Event::RuleRemoved { rule } => {
            put_u8(out, 15);
            put_u32(out, *rule);
        }
        Event::ClusterAssign { idx, dist2, inst } => {
            put_u8(out, 16);
            put_u32(out, *idx);
            put_f64(out, *dist2);
            put_instance(out, inst);
        }
        Event::CentroidSnapshot { version, k, d, centers, weights } => {
            put_u8(out, 17);
            put_u64(out, *version);
            put_u32(out, *k);
            put_u32(out, *d);
            put_f32s(out, centers);
            put_f32s(out, weights);
        }
    }
}

/// Encode `event` as a standalone byte vector (tests/benches convenience).
pub fn encode_event_vec(event: &Event) -> Vec<u8> {
    let mut out = Vec::with_capacity(event.wire_bytes() + 8);
    encode_event(event, &mut out);
    out
}

// ---------------------------------------------------------------- reading

/// Bounds-checked cursor over a received frame body. Every getter
/// returns `Err` instead of panicking when the input is truncated, so a
/// corrupt frame is rejected, not fatal.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes consumed so far.
    pub fn consumed(&self) -> usize {
        self.pos
    }

    /// Bytes left unread.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            crate::bail!(
                "codec: truncated frame (need {n} bytes at offset {}, have {})",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// A counted run of raw bytes (string payloads of the cluster
    /// protocol's report frames).
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u32` length prefix, validated against the bytes actually left
    /// (`elem_bytes` per element) so a corrupt count fails here instead
    /// of over-allocating.
    fn len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes.max(1)) > self.remaining() {
            crate::bail!("codec: length {n} exceeds frame remainder {}", self.remaining());
        }
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    fn label(&mut self) -> Result<Label> {
        Ok(match self.u8()? {
            0 => Label::Class(self.u32()?),
            1 => Label::Numeric(self.f64()?),
            2 => Label::None,
            k => crate::bail!("codec: unknown label kind {k}"),
        })
    }

    fn output(&mut self) -> Result<Output> {
        Ok(match self.u8()? {
            0 => Output::Class(self.u32()?),
            1 => Output::Numeric(self.f64()?),
            2 => Output::None,
            k => crate::bail!("codec: unknown output kind {k}"),
        })
    }

    fn instance(&mut self) -> Result<Instance> {
        let mut inst = match self.u8()? {
            0 => {
                let v = self.f32s()?;
                Instance::dense(v, Label::None)
            }
            1 => {
                let n = self.len(8)?; // each entry: u32 index + f32 value
                let mut indices = Vec::with_capacity(n);
                for _ in 0..n {
                    indices.push(self.u32()?);
                }
                let mut values = Vec::with_capacity(n);
                for _ in 0..n {
                    values.push(self.f32()?);
                }
                let n_attributes = self.u32()?;
                Instance::sparse(indices, values, n_attributes, Label::None)
            }
            k => crate::bail!("codec: unknown values kind {k}"),
        };
        inst.label = self.label()?;
        inst.weight = self.f32()?;
        Ok(inst)
    }

    fn feature(&mut self) -> Result<Feature> {
        let attr = self.u32()?;
        let op = match self.u8()? {
            0 => Op::Le,
            1 => Op::Gt,
            2 => Op::Eq,
            k => crate::bail!("codec: unknown op {k}"),
        };
        let threshold = self.f64()?;
        Ok(Feature { attr, op, threshold })
    }

    fn head(&mut self) -> Result<HeadSnapshot> {
        let mean = self.f64()?;
        let weights = match self.u8()? {
            0 => None,
            1 => Some(self.f64s()?),
            k => crate::bail!("codec: unknown option flag {k}"),
        };
        Ok(HeadSnapshot { mean, weights })
    }

    /// Decode one tagged event body from the cursor.
    pub fn event(&mut self) -> Result<Event> {
        Ok(match self.u8()? {
            1 => {
                let id = self.u64()?;
                let inst = self.instance()?;
                Event::Instance { id, inst }
            }
            2 => {
                let id = self.u64()?;
                let truth = self.label()?;
                let output = self.output()?;
                Event::Prediction { id, truth, output }
            }
            3 => Event::Shutdown,
            4 => {
                let stage = self.u32()?;
                let shard = self.u32()?;
                let round = self.u64()?;
                let payload = Arc::new(self.f64s()?);
                Event::StatsDelta { stage, shard, round, payload }
            }
            5 => {
                let stage = self.u32()?;
                let payload = Arc::new(self.f64s()?);
                Event::StatsGlobal { stage, payload }
            }
            6 => {
                let leaf = self.u64()?;
                let attr = self.u32()?;
                let value = self.f32()?;
                let class = self.u32()?;
                let weight = self.f32()?;
                Event::Attribute { leaf, attr, value, class, weight }
            }
            7 => {
                let leaf = self.u64()?;
                let class = self.u32()?;
                let weight = self.f32()?;
                let n = self.len(5)?; // u32 attr + u8 value per entry
                let mut attrs = Vec::with_capacity(n);
                for _ in 0..n {
                    let a = self.u32()?;
                    let v = self.u8()?;
                    attrs.push((a, v));
                }
                Event::AttributeBatch { leaf, class, weight, attrs: Arc::new(attrs) }
            }
            8 => {
                let leaf = self.u64()?;
                let seq = self.u32()?;
                let n_l = self.f64()?;
                let class_counts = Arc::new(self.f32s()?);
                Event::Compute { leaf, seq, n_l, class_counts }
            }
            9 => {
                let leaf = self.u64()?;
                let seq = self.u32()?;
                let best_attr = self.u32()?;
                let best = self.f64()?;
                let second_attr = self.u32()?;
                let second = self.f64()?;
                let best_dist = Arc::new(self.f32s()?);
                Event::LocalResult { leaf, seq, best_attr, best, second_attr, second, best_dist }
            }
            10 => Event::DropLeaf { leaf: self.u64()? },
            11 => {
                let rule = self.u32()?;
                let inst = self.instance()?;
                Event::RuleInstance { rule, inst }
            }
            12 => {
                let rule = self.u32()?;
                let n = self.len(13)?; // u32 attr + u8 op + f64 threshold
                let mut features = Vec::with_capacity(n);
                for _ in 0..n {
                    features.push(self.feature()?);
                }
                let head = self.head()?;
                Event::NewRule { rule, spec: Arc::new(RuleSpec { features, head }) }
            }
            13 => {
                let rule = self.u32()?;
                let feature = self.feature()?;
                let head = Arc::new(self.head()?);
                Event::RuleFeature { rule, feature, head }
            }
            14 => {
                let rule = self.u32()?;
                let head = Arc::new(self.head()?);
                Event::RuleHead { rule, head }
            }
            15 => Event::RuleRemoved { rule: self.u32()? },
            16 => {
                let idx = self.u32()?;
                let dist2 = self.f64()?;
                let inst = self.instance()?;
                Event::ClusterAssign { idx, dist2, inst }
            }
            17 => {
                let version = self.u64()?;
                let k = self.u32()?;
                let d = self.u32()?;
                let centers = Arc::new(self.f32s()?);
                let weights = Arc::new(self.f32s()?);
                Event::CentroidSnapshot { version, k, d, centers, weights }
            }
            t => crate::bail!("codec: unknown event tag {t}"),
        })
    }
}

/// Decode one event from the start of `buf`; returns the event and the
/// number of bytes consumed.
pub fn decode_event(buf: &[u8]) -> Result<(Event, usize)> {
    let mut r = Reader::new(buf);
    let e = r.event()?;
    Ok((e, r.consumed()))
}

// --------------------------------------------------------- peer frames
//
// The cluster engine's peer data plane (worker↔worker links) reuses the
// `[len: u32 LE][kind: u8][seq: u64 LE]…` frame shape of the
// coordinator lanes. The payload-bearing kinds live here (rather than
// as private constants in `engine::cluster`) so their encode/decode is
// unit-testable without sockets: a peer frame arrives from another
// *process* and must survive truncation and corruption exactly like an
// event body.

/// Coordinator → worker: routing table + peer mesh setup (first frame
/// of a peer-mode run).
pub const FRAME_ROUTES: u8 = 11;
/// Coordinator → worker: slot schedule tokens (deterministic peer mode;
/// out-of-band, `wseq` field is 0 and unused).
pub const FRAME_PEER_SCHED: u8 = 12;
/// Worker → worker: one peer-shipped delivery. The `wseq` slot of the
/// frame layout carries the per-(sender, dest) link sequence number.
pub const FRAME_PEER: u8 = 13;
/// Worker → coordinator: reply to a peer delivery (relaxed/`fast` mode
/// only; deterministic mode replies with the ordinary emissions kind
/// keyed by the coordinator-assigned slot).
pub const FRAME_PEER_EMS: u8 = 14;
/// Worker → coordinator (control lane, right after the handshake): the
/// address of this worker's peer listener (subprocess mode).
pub const FRAME_PEER_ADDR: u8 = 15;
/// Coordinator → worker: a peer was respawned after a death — stop
/// shipping to it (out-of-band, like `FRAME_PEER_SCHED`).
pub const FRAME_PEER_DOWN: u8 = 16;
/// Coordinator → worker: a windowed source-injection frame carrying a
/// run of consecutive data deliveries for this worker in one round trip
/// (pipelined injection; consumes exactly one `wseq` slot).
pub const FRAME_INJECT: u8 = 17;
/// Worker → coordinator: reply to a `FRAME_INJECT` frame — one emission
/// group per injected event, in delivery order. Each group is encoded
/// exactly like the body of an ordinary emissions reply (`[count: u32]`
/// followed by flat or tagged entries, depending on peer mode), so the
/// coordinator routes the batch bit-identically to the equivalent
/// sequence of per-event replies.
pub const FRAME_INJECT_EMS: u8 = 18;

/// Encode one worker→worker peer delivery frame body:
/// `[FRAME_PEER][lseq: u64][pid: u16][iid: u16][event]`.
pub fn encode_peer_frame(lseq: u64, pid: u16, iid: u16, event: &Event) -> Vec<u8> {
    let mut b = Vec::with_capacity(16 + event.wire_bytes());
    put_u8(&mut b, FRAME_PEER);
    put_u64(&mut b, lseq);
    put_u16(&mut b, pid);
    put_u16(&mut b, iid);
    encode_event(event, &mut b);
    b
}

/// Decode a peer delivery frame body. Rejects a wrong kind byte,
/// truncation anywhere, and trailing garbage after the event.
pub fn decode_peer_frame(buf: &[u8]) -> Result<(u64, u16, u16, Event)> {
    let mut r = Reader::new(buf);
    let kind = r.u8()?;
    crate::ensure!(kind == FRAME_PEER, "peer frame: wrong kind {kind}");
    let lseq = r.u64()?;
    let pid = r.u16()?;
    let iid = r.u16()?;
    let event = r.event()?;
    crate::ensure!(r.remaining() == 0, "peer frame: {} trailing bytes", r.remaining());
    Ok((lseq, pid, iid, event))
}

/// Encode a schedule-token frame body:
/// `[FRAME_PEER_SCHED][0: u64][n: u32][(slot: u64, sender: u8) × n]`.
/// Tokens tell the receiving worker which of its upcoming delivery
/// slots are filled by peer frames (and from which sender) instead of
/// coordinator frames.
pub fn encode_peer_sched(tokens: &[(u64, u8)]) -> Vec<u8> {
    let mut b = Vec::with_capacity(13 + 9 * tokens.len());
    put_u8(&mut b, FRAME_PEER_SCHED);
    put_u64(&mut b, 0);
    put_u32(&mut b, tokens.len() as u32);
    for &(slot, sender) in tokens {
        put_u64(&mut b, slot);
        put_u8(&mut b, sender);
    }
    b
}

/// Decode a schedule-token frame body.
pub fn decode_peer_sched(buf: &[u8]) -> Result<Vec<(u64, u8)>> {
    let mut r = Reader::new(buf);
    let kind = r.u8()?;
    crate::ensure!(kind == FRAME_PEER_SCHED, "peer sched: wrong kind {kind}");
    let _zero = r.u64()?;
    let n = r.len(9)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push((r.u64()?, r.u8()?));
    }
    crate::ensure!(r.remaining() == 0, "peer sched: {} trailing bytes", r.remaining());
    Ok(out)
}

/// Encode a windowed source-injection frame body:
/// `[FRAME_INJECT][wseq: u64][n: u32][(pid: u16, iid: u16, event) × n]`.
/// One frame carries a run of consecutive data deliveries bound for the
/// same worker, in global delivery order; the worker processes them in
/// order and answers with a single [`FRAME_INJECT_EMS`] reply.
pub fn encode_inject_frame(wseq: u64, events: &[(u16, u16, Event)]) -> Vec<u8> {
    let mut b =
        Vec::with_capacity(13 + events.iter().map(|(_, _, e)| 4 + e.wire_bytes()).sum::<usize>());
    put_u8(&mut b, FRAME_INJECT);
    put_u64(&mut b, wseq);
    put_u32(&mut b, events.len() as u32);
    for (pid, iid, e) in events {
        put_u16(&mut b, *pid);
        put_u16(&mut b, *iid);
        encode_event(e, &mut b);
    }
    b
}

/// Decode a windowed source-injection frame body. Rejects a wrong kind
/// byte, truncation anywhere, and trailing garbage after the last event.
pub fn decode_inject_frame(buf: &[u8]) -> Result<(u64, Vec<(u16, u16, Event)>)> {
    let mut r = Reader::new(buf);
    let kind = r.u8()?;
    crate::ensure!(kind == FRAME_INJECT, "inject frame: wrong kind {kind}");
    let wseq = r.u64()?;
    let n = r.len(5)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push((r.u16()?, r.u16()?, r.event()?));
    }
    crate::ensure!(r.remaining() == 0, "inject frame: {} trailing bytes", r.remaining());
    Ok((wseq, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(e: &Event) -> Event {
        let bytes = encode_event_vec(e);
        let (decoded, used) = decode_event(&bytes).expect("decode");
        assert_eq!(used, bytes.len(), "whole buffer consumed for {e:?}");
        decoded
    }

    /// Event has no PartialEq (Arc payloads); Debug formatting is a
    /// faithful structural fingerprint including exact float bits for
    /// finite values — NaN bit patterns are asserted separately.
    fn assert_same(a: &Event, b: &Event) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn roundtrip_core_variants() {
        let dense = Event::Instance {
            id: 7,
            inst: Instance::dense(vec![1.5, -2.25, 0.0], Label::Class(3)),
        };
        assert_same(&dense, &roundtrip(&dense));

        let mut weighted = Instance::sparse(vec![2, 9], vec![0.5, -4.0], 16, Label::Numeric(1.25));
        weighted.weight = 0.375;
        let sparse = Event::Instance { id: u64::MAX, inst: weighted };
        assert_same(&sparse, &roundtrip(&sparse));

        let pred = Event::Prediction { id: 1, truth: Label::Class(2), output: Output::None };
        assert_same(&pred, &roundtrip(&pred));
        assert_same(&Event::Shutdown, &roundtrip(&Event::Shutdown));
    }

    #[test]
    fn roundtrip_preserves_nan_tagged_payload_bits() {
        // the preprocess sparse encoding stores a tag NaN + mask words as
        // f64 bit patterns; the codec must not canonicalize them
        let tag = f64::from_bits(0x7FF8_0000_0000_0001);
        let e = Event::StatsDelta {
            stage: 2,
            shard: 1,
            round: 42,
            payload: Arc::new(vec![tag, 3.5, f64::from_bits(0x7FF8_DEAD_BEEF_0001)]),
        };
        let bytes = encode_event_vec(&e);
        let (d, _) = decode_event(&bytes).unwrap();
        match (e, d) {
            (Event::StatsDelta { payload: a, .. }, Event::StatsDelta { payload: b, .. }) => {
                let a: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn rejects_truncation_and_unknown_tags() {
        let e = Event::Compute { leaf: 5, seq: 1, n_l: 9.0, class_counts: Arc::new(vec![1.0]) };
        let bytes = encode_event_vec(&e);
        for cut in 0..bytes.len() {
            assert!(decode_event(&bytes[..cut]).is_err(), "cut at {cut} must fail");
        }
        assert!(decode_event(&[99]).is_err(), "unknown tag");
        assert!(decode_event(&[]).is_err(), "empty buffer");
    }

    #[test]
    fn rejects_oversized_length_prefix() {
        // StatsGlobal claiming u32::MAX payload elements in a tiny buffer
        let mut bytes = vec![5u8];
        put_u32(&mut bytes, 0);
        put_u32(&mut bytes, u32::MAX);
        assert!(decode_event(&bytes).is_err());
    }

    #[test]
    fn inject_frame_roundtrip() {
        let events = vec![
            (3u16, 1u16, Event::Instance {
                id: 9,
                inst: Instance::dense(vec![0.5, -1.0], Label::Class(1)),
            }),
            (3u16, 0u16, Event::Instance {
                id: 10,
                inst: Instance::sparse(vec![1, 4], vec![2.0, -0.5], 8, Label::None),
            }),
            (0u16, 2u16, Event::Shutdown),
        ];
        let frame = encode_inject_frame(41, &events);
        let (wseq, decoded) = decode_inject_frame(&frame).expect("decode inject");
        assert_eq!(wseq, 41);
        assert_eq!(decoded.len(), events.len());
        for ((ap, ai, ae), (bp, bi, be)) in events.iter().zip(&decoded) {
            assert_eq!((ap, ai), (bp, bi));
            assert_same(ae, be);
        }
    }

    #[test]
    fn inject_frame_rejects_corruption() {
        let events = vec![(1u16, 0u16, Event::Instance {
            id: 3,
            inst: Instance::dense(vec![1.0], Label::None),
        })];
        let frame = encode_inject_frame(7, &events);
        for cut in 0..frame.len() {
            assert!(decode_inject_frame(&frame[..cut]).is_err(), "cut at {cut} must fail");
        }
        let mut wrong_kind = frame.clone();
        wrong_kind[0] = FRAME_PEER;
        assert!(decode_inject_frame(&wrong_kind).is_err(), "wrong kind");
        let mut trailing = frame;
        trailing.push(0);
        assert!(decode_inject_frame(&trailing).is_err(), "trailing byte");
    }
}
