//! Stream groupings (paper §4/§6.2): how events on a stream are routed to
//! the destination processor's parallel instances.

/// Routing policy of a stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grouping {
    /// Hash the emission key to a destination instance. VHT uses a
    /// composite key (leaf id, attribute id); AMRules keys by rule id.
    Key,
    /// Round-robin across instances (paper: horizontal parallelism).
    Shuffle,
    /// Broadcast to every instance (paper: `compute`/`drop` events,
    /// HAMR's new-rule announcements).
    All,
    /// The emission key *is* the destination instance (mod parallelism).
    /// Used by senders that pre-compute routing to batch several keyed
    /// messages per destination (VHT's per-LS attribute batches).
    Direct,
}

impl Grouping {
    /// Destination instance(s) for an event with `key`, given the
    /// destination parallelism and a per-stream round-robin cursor.
    #[inline]
    pub fn route(&self, key: u64, parallelism: usize, rr: &mut usize) -> Route {
        match self {
            Grouping::Key => Route::One(hash64(key) as usize % parallelism),
            Grouping::Shuffle => {
                let i = *rr % parallelism;
                *rr = rr.wrapping_add(1);
                Route::One(i)
            }
            Grouping::All => Route::All,
            Grouping::Direct => Route::One(key as usize % parallelism),
        }
    }
}

/// Result of routing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    One(usize),
    All,
}

/// Fast 64-bit mix (SplitMix64 finalizer) — stable across runs, so
/// key-grouped experiments are reproducible.
#[inline]
pub fn hash64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Composite key (leaf id, attribute id) used by VHT's attribute stream.
#[inline]
pub fn leaf_attr_key(leaf: u64, attr: u32) -> u64 {
    leaf.wrapping_mul(0x100000001B3) ^ attr as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_routing_is_deterministic() {
        let mut rr = 0;
        let a = Grouping::Key.route(42, 4, &mut rr);
        let b = Grouping::Key.route(42, 4, &mut rr);
        assert_eq!(a, b);
    }

    #[test]
    fn key_routing_spreads() {
        let mut rr = 0;
        let mut seen = [false; 8];
        for k in 0..1000u64 {
            if let Route::One(i) = Grouping::Key.route(k, 8, &mut rr) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_round_robins() {
        let mut rr = 0;
        let r: Vec<_> = (0..4)
            .map(|_| Grouping::Shuffle.route(0, 2, &mut rr))
            .collect();
        assert_eq!(r, vec![Route::One(0), Route::One(1), Route::One(0), Route::One(1)]);
    }

    #[test]
    fn all_broadcasts() {
        let mut rr = 0;
        assert_eq!(Grouping::All.route(9, 4, &mut rr), Route::All);
    }

    #[test]
    fn leaf_attr_key_distinguishes() {
        assert_ne!(leaf_attr_key(1, 2), leaf_attr_key(2, 1));
        assert_ne!(leaf_attr_key(1, 2), leaf_attr_key(1, 3));
    }
}
