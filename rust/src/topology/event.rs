//! Content events — every message type exchanged in any SAMOA topology.
//!
//! The VHT variants implement Table 2 of the paper verbatim
//! (`instance`, `attribute`, `compute`, `local-result`, `drop`); the
//! AMRules and CluStream variants implement the messages described in
//! §7.1–7.2 and §5 respectively.
//!
//! # Zero-copy clones
//!
//! Every variant that carries a heap payload ships it behind an `Arc`
//! (instances share their `Values` internally, see
//! [`crate::core::instance`]), so **`Event::clone` never allocates** —
//! an All-grouped broadcast at parallelism `p` is `p` pointer bumps, not
//! `p` deep copies. [`Event::wire_bytes`] still prices the *full*
//! payload per delivery: sharing is an in-process optimization, the
//! simulated-cluster cost model (`engine::simtime`) charges what a real
//! DSPE would serialize on every hop. [`Event::deep_clone`] reproduces
//! the pre-refactor per-destination copy (bench baselines only).

use std::sync::Arc;

use crate::core::instance::{Instance, Label};
use crate::regressors::rule::{Feature, HeadSnapshot, RuleSpec};

/// Model output attached to a prediction event.
#[derive(Clone, Debug, PartialEq)]
pub enum Output {
    Class(u32),
    Numeric(f64),
    /// No prediction possible yet (empty model).
    None,
}

/// All content events.
#[derive(Clone, Debug)]
pub enum Event {
    // ---------------------------------------------------------- generic
    /// A (possibly labeled) instance from the source S.
    Instance { id: u64, inst: Instance },
    /// Model prediction, flowing to the evaluator.
    Prediction { id: u64, truth: Label, output: Output },
    /// Engine-injected shutdown marker (flushes buffered state).
    Shutdown,

    // ------------------------------------------- preprocess delta-sync
    /// Mergeable-state increment of pipeline stage `stage` from one
    /// shard: `PipelineProcessor` → `StatsSyncProcessor`, key-grouped by
    /// stage id (see `preprocess::sync`). `shard` is the emitting
    /// pipeline instance and `round` its per-stage emission sequence
    /// number, so the aggregator can keep sync rounds exact (one delta
    /// per shard per round) under shard skew and drift-gated shards that
    /// legitimately skip rounds. The payload may be the dense or the
    /// NaN-tagged sparse encoding (see `preprocess::wire`).
    StatsDelta { stage: u32, shard: u32, round: u64, payload: Arc<Vec<f64>> },
    /// Merged global state of stage `stage` broadcast back:
    /// `StatsSyncProcessor` → all pipeline shards (All grouping).
    StatsGlobal { stage: u32, payload: Arc<Vec<f64>> },

    // ------------------------------------------------- VHT (Table 2)
    /// One attribute of a training instance: MA → LS, key-grouped by
    /// (leaf id, attribute id).
    Attribute { leaf: u64, attr: u32, value: f32, class: u32, weight: f32 },
    /// Attribute events of one instance destined to the *same* LS
    /// instance, grouped by the MA (Direct grouping). Semantically
    /// identical to the per-attribute events; one message per LS per
    /// instance instead of one per attribute (§Perf optimization; the
    /// wire size still counts every attribute).
    AttributeBatch { leaf: u64, class: u32, weight: f32, attrs: Arc<Vec<(u32, u8)>> },
    /// Ask all LS to evaluate the split criterion for `leaf`: MA → all LS.
    /// `class_counts` (leaf class marginals) lets LS derive absence rows
    /// for sparse presence observers; empty in dense mode.
    Compute { leaf: u64, seq: u32, n_l: f64, class_counts: Arc<Vec<f32>> },
    /// Local top-2 attributes by criterion: LS → MA. `best_dist` carries
    /// the winning attribute's `[arity × class]` counts so the MA can seed
    /// child leaves (Alg. 4 line 8, "derived sufficient statistic").
    LocalResult {
        leaf: u64,
        seq: u32,
        best_attr: u32,
        best: f64,
        second_attr: u32,
        second: f64,
        best_dist: Arc<Vec<f32>>,
    },
    /// Release leaf state after a split: MA → all LS.
    DropLeaf { leaf: u64 },

    // ------------------------------------------------- AMRules (§7)
    /// Instance covered by `rule`: model aggregator → learner (key-grouped
    /// by rule id).
    RuleInstance { rule: u32, inst: Instance },
    /// Default rule expanded into a new rule: default-rule learner → all
    /// model aggregators (broadcast) + owning learner.
    NewRule { rule: u32, spec: Arc<RuleSpec> },
    /// A learner expanded a rule with a new feature: learner → all MAs
    /// (carries a fresh head snapshot so MA predictions track the learner).
    RuleFeature { rule: u32, feature: Feature, head: Arc<HeadSnapshot> },
    /// Periodic head refresh: learner → all MAs.
    RuleHead { rule: u32, head: Arc<HeadSnapshot> },
    /// Drift detected, rule evicted: learner → all MAs.
    RuleRemoved { rule: u32 },

    // ------------------------------------------------- CluStream
    /// Point routed to the micro-cluster aggregator with its tentative
    /// nearest-centroid assignment (computed worker-side on a snapshot).
    ClusterAssign { idx: u32, dist2: f64, inst: Instance },
    /// Periodic centroid snapshot: aggregator → all workers (broadcast).
    CentroidSnapshot {
        version: u64,
        k: u32,
        d: u32,
        centers: Arc<Vec<f32>>,
        weights: Arc<Vec<f32>>,
    },
}

impl Event {
    /// Approximate serialized size — the cost model of `engine::simtime`
    /// and the quantity on the x-axis of Fig. 13. Counted per logical
    /// delivery (a `p`-way broadcast is `p × wire_bytes`), independent of
    /// in-process Arc sharing.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Event::Instance { inst, .. } => 8 + inst.wire_bytes(),
            Event::Prediction { .. } => 8 + 16 + 9,
            Event::Shutdown => 1,
            Event::StatsDelta { payload, .. } => 4 + 4 + 8 + 8 * payload.len(),
            Event::StatsGlobal { payload, .. } => 4 + 8 * payload.len(),
            Event::Attribute { .. } => 8 + 4 + 4 + 4 + 4,
            Event::AttributeBatch { attrs, .. } => 8 + 4 + 4 + 5 * attrs.len(),
            Event::Compute { class_counts, .. } => 8 + 4 + 8 + 4 * class_counts.len(),
            Event::LocalResult { best_dist, .. } => 8 + 4 + 2 * (4 + 8) + 4 * best_dist.len(),
            Event::DropLeaf { .. } => 8,
            Event::RuleInstance { inst, .. } => 4 + inst.wire_bytes(),
            Event::NewRule { spec, .. } => 4 + 16 * spec.features.len() + 16,
            Event::RuleFeature { .. } => 4 + 16 + 16,
            Event::RuleHead { head, .. } => {
                4 + 8 + head.weights.as_ref().map_or(0, |w| 8 * w.len())
            }
            Event::RuleRemoved { .. } => 4,
            Event::ClusterAssign { inst, .. } => 12 + inst.wire_bytes(),
            Event::CentroidSnapshot { centers, weights, .. } => {
                8 + 8 + 4 * centers.len() + 4 * weights.len()
            }
        }
    }

    /// True for control-plane events that must not be subject to data-path
    /// backpressure (they close the MA↔LS feedback loop; see
    /// `engine::threaded` on deadlock avoidance).
    pub fn is_control(&self) -> bool {
        matches!(
            self,
            Event::Compute { .. }
                | Event::LocalResult { .. }
                | Event::DropLeaf { .. }
                | Event::NewRule { .. }
                | Event::RuleFeature { .. }
                | Event::RuleHead { .. }
                | Event::RuleRemoved { .. }
                | Event::CentroidSnapshot { .. }
                | Event::StatsDelta { .. }
                | Event::StatsGlobal { .. }
                | Event::Shutdown
        )
    }

    /// Clone for one broadcast delivery: the alloc-free shared clone
    /// normally, the pre-refactor deep copy when the engine's
    /// `deep_copy_broadcast` bench-baseline knob is set. Single home for
    /// the policy so the engines cannot diverge.
    #[inline]
    pub fn broadcast_clone(&self, deep: bool) -> Self {
        if deep {
            self.deep_clone()
        } else {
            self.clone()
        }
    }

    /// Pre-refactor clone semantics: deep-copies every heap payload so
    /// each destination owns private memory. Only the `engine_throughput`
    /// bench uses this (as the "before" baseline of the zero-copy data
    /// plane); production routing uses `clone()`, which is alloc-free.
    pub fn deep_clone(&self) -> Self {
        match self {
            Event::Instance { id, inst } => {
                Event::Instance { id: *id, inst: inst.deep_clone() }
            }
            Event::RuleInstance { rule, inst } => {
                Event::RuleInstance { rule: *rule, inst: inst.deep_clone() }
            }
            Event::ClusterAssign { idx, dist2, inst } => {
                Event::ClusterAssign { idx: *idx, dist2: *dist2, inst: inst.deep_clone() }
            }
            Event::StatsDelta { stage, shard, round, payload } => Event::StatsDelta {
                stage: *stage,
                shard: *shard,
                round: *round,
                payload: Arc::new((**payload).clone()),
            },
            Event::StatsGlobal { stage, payload } => {
                Event::StatsGlobal { stage: *stage, payload: Arc::new((**payload).clone()) }
            }
            Event::AttributeBatch { leaf, class, weight, attrs } => Event::AttributeBatch {
                leaf: *leaf,
                class: *class,
                weight: *weight,
                attrs: Arc::new((**attrs).clone()),
            },
            Event::Compute { leaf, seq, n_l, class_counts } => Event::Compute {
                leaf: *leaf,
                seq: *seq,
                n_l: *n_l,
                class_counts: Arc::new((**class_counts).clone()),
            },
            Event::LocalResult { leaf, seq, best_attr, best, second_attr, second, best_dist } => {
                Event::LocalResult {
                    leaf: *leaf,
                    seq: *seq,
                    best_attr: *best_attr,
                    best: *best,
                    second_attr: *second_attr,
                    second: *second,
                    best_dist: Arc::new((**best_dist).clone()),
                }
            }
            Event::NewRule { rule, spec } => {
                Event::NewRule { rule: *rule, spec: Arc::new((**spec).clone()) }
            }
            Event::RuleFeature { rule, feature, head } => Event::RuleFeature {
                rule: *rule,
                feature: *feature,
                head: Arc::new((**head).clone()),
            },
            Event::RuleHead { rule, head } => {
                Event::RuleHead { rule: *rule, head: Arc::new((**head).clone()) }
            }
            Event::CentroidSnapshot { version, k, d, centers, weights } => {
                Event::CentroidSnapshot {
                    version: *version,
                    k: *k,
                    d: *d,
                    centers: Arc::new((**centers).clone()),
                    weights: Arc::new((**weights).clone()),
                }
            }
            // payload-free variants: plain clone is already a deep copy
            other => other.clone(),
        }
    }
}

/// Recycling pool for event micro-batch buffers (`Vec<Event>`): the
/// threaded engine's data plane moves events in batches, and without
/// reuse every flush allocates a fresh `Vec` that the consumer frees
/// after draining — one allocator round-trip per batch, forever. The
/// arena closes the loop: consumers return drained buffers, senders
/// take them back, and steady-state batching becomes allocation-free
/// (the ROADMAP's "AttributeBatch arena" data-plane follow-up: the
/// attribute batches ride inside these buffers).
///
/// The pool is bounded (`max_pooled`) so a transient burst cannot pin
/// memory forever, and buffers are recycled with their capacity intact.
/// Tiny buffers (capacity below [`BatchArena::MIN_CAPACITY`]) are not
/// pooled: at batch size 1 the per-event path must not pay a global
/// lock round-trip that costs more than the allocation it saves.
/// `allocations()` / `reuses()` expose the hit rate for benches.
pub struct BatchArena {
    pool: std::sync::Mutex<Vec<Vec<Event>>>,
    max_pooled: usize,
    allocations: std::sync::atomic::AtomicU64,
    reuses: std::sync::atomic::AtomicU64,
}

impl BatchArena {
    /// Buffers below this capacity are dropped instead of pooled (the
    /// lock round-trip would exceed the saved allocation).
    pub const MIN_CAPACITY: usize = 8;

    pub fn new(max_pooled: usize) -> Self {
        BatchArena {
            pool: std::sync::Mutex::new(Vec::new()),
            max_pooled,
            allocations: std::sync::atomic::AtomicU64::new(0),
            reuses: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// An empty buffer: recycled when the pool has one, fresh otherwise.
    pub fn take(&self) -> Vec<Event> {
        use std::sync::atomic::Ordering::Relaxed;
        if let Some(buf) = self.pool.lock().unwrap().pop() {
            self.reuses.fetch_add(1, Relaxed);
            return buf;
        }
        self.allocations.fetch_add(1, Relaxed);
        Vec::new()
    }

    /// Return a drained buffer (cleared here; capacity kept). Buffers
    /// below [`Self::MIN_CAPACITY`] or beyond the pool bound are simply
    /// dropped — no lock is taken for them.
    pub fn put(&self, mut buf: Vec<Event>) {
        buf.clear();
        if buf.capacity() < Self::MIN_CAPACITY {
            return;
        }
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < self.max_pooled {
            pool.push(buf);
        }
    }

    /// Fresh `Vec` allocations handed out by [`take`](Self::take).
    pub fn allocations(&self) -> u64 {
        self.allocations.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Recycled buffers handed out by [`take`](Self::take).
    pub fn reuses(&self) -> u64 {
        self.reuses.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribute_event_is_small() {
        let e = Event::Attribute { leaf: 1, attr: 2, value: 0.5, class: 1, weight: 1.0 };
        assert!(e.wire_bytes() <= 32);
    }

    #[test]
    fn instance_event_scales_with_density() {
        let dense = Event::Instance {
            id: 0,
            inst: Instance::dense(vec![0.0; 100], Label::Class(0)),
        };
        let sparse = Event::Instance {
            id: 0,
            inst: Instance::sparse(vec![1, 5], vec![1.0, 2.0], 100, Label::Class(0)),
        };
        assert!(sparse.wire_bytes() < dense.wire_bytes());
    }

    #[test]
    fn control_classification() {
        assert!(Event::Compute {
            leaf: 0,
            seq: 0,
            n_l: 0.0,
            class_counts: Arc::new(vec![])
        }
        .is_control());
        assert!(!Event::Attribute { leaf: 0, attr: 0, value: 0.0, class: 0, weight: 1.0 }
            .is_control());
    }

    /// The zero-copy contract: cloning a payload-bearing event shares the
    /// payload allocation; deep_clone does not.
    #[test]
    fn clone_shares_payloads_deep_clone_copies() {
        let inst = Instance::dense(vec![0.0; 64], Label::Class(0));
        let e = Event::Instance { id: 1, inst };
        let c = e.clone();
        match (&e, &c) {
            (Event::Instance { inst: a, .. }, Event::Instance { inst: b, .. }) => {
                assert!(Arc::ptr_eq(a.shared_values(), b.shared_values()));
            }
            _ => unreachable!(),
        }
        let d = e.deep_clone();
        match (&e, &d) {
            (Event::Instance { inst: a, .. }, Event::Instance { inst: b, .. }) => {
                assert!(!Arc::ptr_eq(a.shared_values(), b.shared_values()));
            }
            _ => unreachable!(),
        }

        let cc = Arc::new(vec![1.0f32; 8]);
        let e = Event::Compute { leaf: 0, seq: 0, n_l: 1.0, class_counts: Arc::clone(&cc) };
        let c = e.clone();
        match &c {
            Event::Compute { class_counts, .. } => assert!(Arc::ptr_eq(class_counts, &cc)),
            _ => unreachable!(),
        }
        match e.deep_clone() {
            Event::Compute { class_counts, .. } => assert!(!Arc::ptr_eq(&class_counts, &cc)),
            _ => unreachable!(),
        }

        // wire size is a per-delivery quantity: unaffected by sharing
        assert_eq!(e.wire_bytes(), e.clone().wire_bytes());
        assert_eq!(e.wire_bytes(), e.deep_clone().wire_bytes());
    }

    /// The arena recycles capacity: a returned buffer comes back cleared
    /// but with its allocation, and the pool bound caps retention.
    #[test]
    fn batch_arena_recycles_capacity() {
        let arena = BatchArena::new(1);
        let mut a = arena.take();
        a.reserve(64);
        let cap = a.capacity();
        a.push(Event::Shutdown);
        arena.put(a);
        let b = arena.take();
        assert!(b.is_empty());
        assert!(b.capacity() >= cap.min(64));
        assert_eq!(arena.reuses(), 1);
        // bound: with max_pooled = 1 the pool keeps one buffer; a second
        // returned buffer is dropped rather than retained
        let mut c = arena.take();
        c.reserve(8);
        assert_eq!(arena.allocations(), 2); // a and c were fresh
        arena.put(b);
        arena.put(c); // pool already holds b: dropped
        let _first = arena.take(); // reuses b
        let _second = arena.take(); // pool empty again: fresh
        assert_eq!(arena.reuses(), 2);
        assert_eq!(arena.allocations(), 3);
    }
}
