//! Content events — every message type exchanged in any SAMOA topology.
//!
//! The VHT variants implement Table 2 of the paper verbatim
//! (`instance`, `attribute`, `compute`, `local-result`, `drop`); the
//! AMRules and CluStream variants implement the messages described in
//! §7.1–7.2 and §5 respectively.

use std::sync::Arc;

use crate::core::instance::{Instance, Label};
use crate::regressors::rule::{Feature, RuleSpec};

/// Model output attached to a prediction event.
#[derive(Clone, Debug, PartialEq)]
pub enum Output {
    Class(u32),
    Numeric(f64),
    /// No prediction possible yet (empty model).
    None,
}

/// All content events.
#[derive(Clone, Debug)]
pub enum Event {
    // ---------------------------------------------------------- generic
    /// A (possibly labeled) instance from the source S.
    Instance { id: u64, inst: Instance },
    /// Model prediction, flowing to the evaluator.
    Prediction { id: u64, truth: Label, output: Output },
    /// Engine-injected shutdown marker (flushes buffered state).
    Shutdown,

    // ------------------------------------------- preprocess delta-sync
    /// Mergeable-state increment of pipeline stage `stage` from one
    /// shard: `PipelineProcessor` → `StatsSyncProcessor`, key-grouped by
    /// stage id (see `preprocess::sync`).
    StatsDelta { stage: u32, payload: Arc<Vec<f64>> },
    /// Merged global state of stage `stage` broadcast back:
    /// `StatsSyncProcessor` → all pipeline shards (All grouping).
    StatsGlobal { stage: u32, payload: Arc<Vec<f64>> },

    // ------------------------------------------------- VHT (Table 2)
    /// One attribute of a training instance: MA → LS, key-grouped by
    /// (leaf id, attribute id).
    Attribute { leaf: u64, attr: u32, value: f32, class: u32, weight: f32 },
    /// Attribute events of one instance destined to the *same* LS
    /// instance, grouped by the MA (Direct grouping). Semantically
    /// identical to the per-attribute events; one message per LS per
    /// instance instead of one per attribute (§Perf optimization; the
    /// wire size still counts every attribute).
    AttributeBatch { leaf: u64, class: u32, weight: f32, attrs: Vec<(u32, u8)> },
    /// Ask all LS to evaluate the split criterion for `leaf`: MA → all LS.
    /// `class_counts` (leaf class marginals) lets LS derive absence rows
    /// for sparse presence observers; empty in dense mode.
    Compute { leaf: u64, seq: u32, n_l: f64, class_counts: Vec<f32> },
    /// Local top-2 attributes by criterion: LS → MA. `best_dist` carries
    /// the winning attribute's `[arity × class]` counts so the MA can seed
    /// child leaves (Alg. 4 line 8, "derived sufficient statistic").
    LocalResult {
        leaf: u64,
        seq: u32,
        best_attr: u32,
        best: f64,
        second_attr: u32,
        second: f64,
        best_dist: Vec<f32>,
    },
    /// Release leaf state after a split: MA → all LS.
    DropLeaf { leaf: u64 },

    // ------------------------------------------------- AMRules (§7)
    /// Instance covered by `rule`: model aggregator → learner (key-grouped
    /// by rule id).
    RuleInstance { rule: u32, inst: Instance },
    /// Default rule expanded into a new rule: default-rule learner → all
    /// model aggregators (broadcast) + owning learner.
    NewRule { rule: u32, spec: RuleSpec },
    /// A learner expanded a rule with a new feature: learner → all MAs
    /// (carries a fresh head snapshot so MA predictions track the learner).
    RuleFeature { rule: u32, feature: Feature, head: crate::regressors::rule::HeadSnapshot },
    /// Periodic head refresh: learner → all MAs.
    RuleHead { rule: u32, head: crate::regressors::rule::HeadSnapshot },
    /// Drift detected, rule evicted: learner → all MAs.
    RuleRemoved { rule: u32 },

    // ------------------------------------------------- CluStream
    /// Point routed to the micro-cluster aggregator with its tentative
    /// nearest-centroid assignment (computed worker-side on a snapshot).
    ClusterAssign { idx: u32, dist2: f64, inst: Instance },
    /// Periodic centroid snapshot: aggregator → all workers (broadcast).
    CentroidSnapshot {
        version: u64,
        k: u32,
        d: u32,
        centers: Arc<Vec<f32>>,
        weights: Arc<Vec<f32>>,
    },
}

impl Event {
    /// Approximate serialized size — the cost model of `engine::simtime`
    /// and the quantity on the x-axis of Fig. 13.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Event::Instance { inst, .. } => 8 + inst.wire_bytes(),
            Event::Prediction { .. } => 8 + 16 + 9,
            Event::Shutdown => 1,
            Event::StatsDelta { payload, .. } | Event::StatsGlobal { payload, .. } => {
                4 + 8 * payload.len()
            }
            Event::Attribute { .. } => 8 + 4 + 4 + 4 + 4,
            Event::AttributeBatch { attrs, .. } => 8 + 4 + 4 + 5 * attrs.len(),
            Event::Compute { class_counts, .. } => 8 + 4 + 8 + 4 * class_counts.len(),
            Event::LocalResult { best_dist, .. } => 8 + 4 + 2 * (4 + 8) + 4 * best_dist.len(),
            Event::DropLeaf { .. } => 8,
            Event::RuleInstance { inst, .. } => 4 + inst.wire_bytes(),
            Event::NewRule { spec, .. } => 4 + 16 * spec.features.len() + 16,
            Event::RuleFeature { .. } => 4 + 16 + 16,
            Event::RuleHead { head, .. } => {
                4 + 8 + head.weights.as_ref().map_or(0, |w| 8 * w.len())
            }
            Event::RuleRemoved { .. } => 4,
            Event::ClusterAssign { inst, .. } => 12 + inst.wire_bytes(),
            Event::CentroidSnapshot { centers, weights, .. } => {
                8 + 8 + 4 * centers.len() + 4 * weights.len()
            }
        }
    }

    /// True for control-plane events that must not be subject to data-path
    /// backpressure (they close the MA↔LS feedback loop; see
    /// `engine::threaded` on deadlock avoidance).
    pub fn is_control(&self) -> bool {
        matches!(
            self,
            Event::Compute { .. }
                | Event::LocalResult { .. }
                | Event::DropLeaf { .. }
                | Event::NewRule { .. }
                | Event::RuleFeature { .. }
                | Event::RuleHead { .. }
                | Event::RuleRemoved { .. }
                | Event::CentroidSnapshot { .. }
                | Event::StatsDelta { .. }
                | Event::StatsGlobal { .. }
                | Event::Shutdown
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribute_event_is_small() {
        let e = Event::Attribute { leaf: 1, attr: 2, value: 0.5, class: 1, weight: 1.0 };
        assert!(e.wire_bytes() <= 32);
    }

    #[test]
    fn instance_event_scales_with_density() {
        let dense = Event::Instance {
            id: 0,
            inst: Instance::dense(vec![0.0; 100], Label::Class(0)),
        };
        let sparse = Event::Instance {
            id: 0,
            inst: Instance::sparse(vec![1, 5], vec![1.0, 2.0], 100, Label::Class(0)),
        };
        assert!(sparse.wire_bytes() < dense.wire_bytes());
    }

    #[test]
    fn control_classification() {
        assert!(Event::Compute { leaf: 0, seq: 0, n_l: 0.0, class_counts: vec![] }.is_control());
        assert!(!Event::Attribute { leaf: 0, attr: 0, value: 0.0, class: 0, weight: 1.0 }
            .is_control());
    }
}
