//! The SAMOA abstraction layer (paper §4): an algorithm is a directed graph
//! of [`Processor`]s connected by [`Stream`]s carrying [`Event`]s
//! (content events), assembled by a [`TopologyBuilder`] and executed inside
//! a [`task::Task`] by one of the engines in [`crate::engine`].
//!
//! Differences from the Java original, by design:
//! * `ContentEvent` is a closed enum ([`Event`]) instead of an open
//!   interface — no boxing/downcasting on the hot path.
//! * `ProcessingItem` (the paper's hidden physical wrapper of a Processor)
//!   corresponds to one *instance* of a logical processor: the engines
//!   materialize `parallelism` instances per processor and route to them
//!   per the stream's [`Grouping`].
//!
//! # The zero-copy data plane
//!
//! Every heap payload an event can carry — an instance's attribute
//! `Values`, VHT attribute batches and `compute`/`local-result`
//! distributions, AMRules rule specs and head snapshots, CluStream
//! centroid snapshots, stats-sync payloads — lives behind an `Arc`.
//! Consequences, relied on throughout the engines and algorithms:
//!
//! * **`Event::clone` never allocates.** An `All`-grouped broadcast at
//!   parallelism `p` is `p` pointer bumps (and the engines move, rather
//!   than clone, the original to the last destination), so fan-out cost
//!   is independent of payload size.
//! * **Mutation is copy-on-write.** Consumers that need to mutate a
//!   shared payload go through an explicit step
//!   ([`crate::core::Instance::values_mut`], `Arc::try_unwrap`-or-clone
//!   at the AMRules aggregators), so a broadcast can never alias writes
//!   across destinations.
//! * **Accounting is unchanged.** [`Event::wire_bytes`] prices the full
//!   payload *per logical delivery* — a `p`-way broadcast costs
//!   `p × wire_bytes` in `EngineMetrics`, exactly what a real DSPE would
//!   serialize (the paper's cost model; sharing is an in-process
//!   optimization only). Model-state reports split shared payloads over
//!   their holders so each is counted once (see `common::memsize`).
//! * `Event::deep_clone` reproduces the pre-refactor per-destination
//!   deep copy; it exists solely as the `engine_throughput` bench
//!   baseline.

pub mod event;
pub mod processor;
pub mod stream;
pub mod builder;
pub mod codec;
pub mod task;

pub use builder::{ProcessorId, StreamId, Topology, TopologyBuilder};
pub use event::{BatchArena, Event, Output};
pub use processor::{Ctx, Processor};
pub use stream::Grouping;
