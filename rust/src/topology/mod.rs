//! The SAMOA abstraction layer (paper §4): an algorithm is a directed graph
//! of [`Processor`]s connected by [`Stream`]s carrying [`Event`]s
//! (content events), assembled by a [`TopologyBuilder`] and executed inside
//! a [`task::Task`] by one of the engines in [`crate::engine`].
//!
//! Differences from the Java original, by design:
//! * `ContentEvent` is a closed enum ([`Event`]) instead of an open
//!   interface — no boxing/downcasting on the hot path.
//! * `ProcessingItem` (the paper's hidden physical wrapper of a Processor)
//!   corresponds to one *instance* of a logical processor: the engines
//!   materialize `parallelism` instances per processor and route to them
//!   per the stream's [`Grouping`].

pub mod event;
pub mod processor;
pub mod stream;
pub mod builder;
pub mod task;

pub use builder::{ProcessorId, StreamId, Topology, TopologyBuilder};
pub use event::{Event, Output};
pub use processor::{Ctx, Processor};
pub use stream::Grouping;
