//! Running-moment scalers: z-score ([`StandardScaler`]) and range
//! ([`MinMaxScaler`]) normalization with online statistics — no fit phase,
//! statistics accumulate as the stream flows (update-then-transform).
//!
//! Both scalers keep **mergeable** statistics ([`Moments`] /
//! [`Ranges`], see [`super::merge::MergeableState`]): a *view* state used
//! to transform, plus a *pending* increment accumulated since the last
//! stats-sync emission. Under `p > 1` pipeline shards the delta-sync
//! protocol ([`super::sync`]) periodically ships the pending increment to
//! an aggregator and replaces the view with the merged global state, so
//! every shard normalizes with (near-)identical statistics.
//!
//! Sparse handling: centering would densify, so sparse instances are only
//! *divided* (by the running σ / range); stored zeros stay zero and absent
//! attributes stay absent. Statistics over sparse input are computed from
//! stored values only (absence is "not observed", not "zero" — matching
//! the presence semantics of the sparse VHT observers).

use crate::common::memsize::vec_flat_bytes;
use crate::core::instance::Values;
use crate::core::{AttributeKind, Instance, Schema};

use super::merge::MergeableState;
use super::{wire, Transform};

/// Per-attribute Welford moments (count / mean / sum of squared
/// deviations) with the Chan et al. parallel merge.
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: Vec<f64>,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl Moments {
    pub fn with_dim(d: usize) -> Self {
        Moments { n: vec![0.0; d], mean: vec![0.0; d], m2: vec![0.0; d] }
    }

    pub fn dim(&self) -> usize {
        self.n.len()
    }

    #[inline]
    fn add(&mut self, j: usize, x: f64) {
        self.n[j] += 1.0;
        let d = x - self.mean[j];
        self.mean[j] += d / self.n[j];
        self.m2[j] += d * (x - self.mean[j]);
    }

    /// Chan parallel update of a single column (shared by full-state
    /// merge and sparse-payload merge).
    #[inline]
    fn merge_col(&mut self, j: usize, nb: f64, mean_b: f64, m2_b: f64) {
        if nb == 0.0 {
            return;
        }
        let na = self.n[j];
        if na == 0.0 {
            self.n[j] = nb;
            self.mean[j] = mean_b;
            self.m2[j] = m2_b;
            return;
        }
        // Chan's parallel update: exact in ℝ, commutative/associative
        // up to f64 rounding.
        let n = na + nb;
        let d = mean_b - self.mean[j];
        self.mean[j] += d * nb / n;
        self.m2[j] += m2_b + d * d * na * nb / n;
        self.n[j] = n;
    }

    /// Sparse encoding of only the columns that saw observations:
    /// `[NaN, d, mask…, (n, mean, m2) per set column]` (see
    /// [`super::wire`]).
    pub fn sparse_delta(&self) -> Vec<f64> {
        let d = self.dim();
        let changed: Vec<bool> = self.n.iter().map(|&n| n > 0.0).collect();
        let m = changed.iter().filter(|&&c| c).count();
        let mut out = Vec::with_capacity(2 + wire::mask_words(d) + 3 * m);
        out.push(f64::NAN);
        out.push(d as f64);
        wire::encode_mask(&mut out, &changed);
        for j in 0..d {
            if changed[j] {
                out.push(self.n[j]);
                out.push(self.mean[j]);
                out.push(self.m2[j]);
            }
        }
        out
    }

    /// Fold a delta payload (dense or sparse) into this state. Returns
    /// `false` (leaving the state unchanged) on a shape mismatch.
    pub fn merge_payload(&mut self, payload: &[f64]) -> bool {
        if wire::is_sparse(payload) {
            if payload.len() < 2 || payload[1] as usize != self.dim() {
                return false;
            }
            let d = self.dim();
            let words = wire::mask_words(d);
            let Some(cols) = wire::decode_mask(&payload[2..], d) else { return false };
            let body = &payload[2 + words..];
            if body.len() != 3 * cols.len() {
                return false;
            }
            for (i, &j) in cols.iter().enumerate() {
                self.merge_col(j, body[3 * i], body[3 * i + 1], body[3 * i + 2]);
            }
            return true;
        }
        if payload.len() != 3 * self.dim() {
            return false;
        }
        let d = self.dim();
        for j in 0..d {
            self.merge_col(j, payload[j], payload[d + j], payload[2 * d + j]);
        }
        true
    }

    pub fn count(&self, j: usize) -> f64 {
        self.n[j]
    }

    pub fn mean(&self, j: usize) -> f64 {
        self.mean[j]
    }

    /// Population standard deviation (0 below 2 observations).
    pub fn sd(&self, j: usize) -> f64 {
        if self.n[j] < 2.0 {
            return 0.0;
        }
        (self.m2[j] / self.n[j]).sqrt()
    }

    fn bytes(&self) -> usize {
        vec_flat_bytes(&self.n) + vec_flat_bytes(&self.mean) + vec_flat_bytes(&self.m2)
    }
}

impl MergeableState for Moments {
    fn merge(&mut self, other: &Self) {
        if other.dim() == 0 {
            return;
        }
        if self.dim() == 0 {
            *self = other.clone();
            return;
        }
        debug_assert_eq!(self.dim(), other.dim(), "Moments dim mismatch");
        for j in 0..self.dim().min(other.dim()) {
            self.merge_col(j, other.n[j], other.mean[j], other.m2[j]);
        }
    }

    fn delta(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(3 * self.dim());
        out.extend_from_slice(&self.n);
        out.extend_from_slice(&self.mean);
        out.extend_from_slice(&self.m2);
        out
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        if wire::is_sparse(payload) {
            // sparse rebuild: unset columns are the empty (identity) state
            if payload.len() < 2 {
                return;
            }
            let mut fresh = Moments::with_dim(payload[1] as usize);
            if fresh.merge_payload(payload) {
                *self = fresh;
            }
            return;
        }
        if payload.len() % 3 != 0 {
            return;
        }
        let d = payload.len() / 3;
        self.n = payload[..d].to_vec();
        self.mean = payload[d..2 * d].to_vec();
        self.m2 = payload[2 * d..].to_vec();
    }

    fn reset(&mut self) {
        self.n.fill(0.0);
        self.mean.fill(0.0);
        self.m2.fill(0.0);
    }
}

/// Per-attribute running min/max. Merge is elementwise min/max — exact,
/// commutative, associative and idempotent.
#[derive(Clone, Debug, Default)]
pub struct Ranges {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Ranges {
    pub fn with_dim(d: usize) -> Self {
        Ranges { lo: vec![f64::INFINITY; d], hi: vec![f64::NEG_INFINITY; d] }
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    #[inline]
    fn add(&mut self, j: usize, x: f64) {
        if x < self.lo[j] {
            self.lo[j] = x;
        }
        if x > self.hi[j] {
            self.hi[j] = x;
        }
    }

    pub fn lo(&self, j: usize) -> f64 {
        self.lo[j]
    }

    pub fn hi(&self, j: usize) -> f64 {
        self.hi[j]
    }

    #[inline]
    fn merge_col(&mut self, j: usize, lo: f64, hi: f64) {
        self.lo[j] = self.lo[j].min(lo);
        self.hi[j] = self.hi[j].max(hi);
    }

    /// Sparse encoding of only the observed columns:
    /// `[NaN, d, mask…, (lo, hi) per set column]`.
    pub fn sparse_delta(&self) -> Vec<f64> {
        let d = self.dim();
        let changed: Vec<bool> = (0..d).map(|j| self.lo[j] <= self.hi[j]).collect();
        let m = changed.iter().filter(|&&c| c).count();
        let mut out = Vec::with_capacity(2 + wire::mask_words(d) + 2 * m);
        out.push(f64::NAN);
        out.push(d as f64);
        wire::encode_mask(&mut out, &changed);
        for j in 0..d {
            if changed[j] {
                out.push(self.lo[j]);
                out.push(self.hi[j]);
            }
        }
        out
    }

    /// Fold a delta payload (dense or sparse) into this state. Returns
    /// `false` (state unchanged) on a shape mismatch.
    pub fn merge_payload(&mut self, payload: &[f64]) -> bool {
        if wire::is_sparse(payload) {
            if payload.len() < 2 || payload[1] as usize != self.dim() {
                return false;
            }
            let d = self.dim();
            let words = wire::mask_words(d);
            let Some(cols) = wire::decode_mask(&payload[2..], d) else { return false };
            let body = &payload[2 + words..];
            if body.len() != 2 * cols.len() {
                return false;
            }
            for (i, &j) in cols.iter().enumerate() {
                self.merge_col(j, body[2 * i], body[2 * i + 1]);
            }
            return true;
        }
        if payload.len() != 2 * self.dim() {
            return false;
        }
        let d = self.dim();
        for j in 0..d {
            self.merge_col(j, payload[j], payload[d + j]);
        }
        true
    }

    fn bytes(&self) -> usize {
        vec_flat_bytes(&self.lo) + vec_flat_bytes(&self.hi)
    }
}

impl MergeableState for Ranges {
    fn merge(&mut self, other: &Self) {
        if other.dim() == 0 {
            return;
        }
        if self.dim() == 0 {
            *self = other.clone();
            return;
        }
        debug_assert_eq!(self.dim(), other.dim(), "Ranges dim mismatch");
        for j in 0..self.dim().min(other.dim()) {
            self.merge_col(j, other.lo[j], other.hi[j]);
        }
    }

    fn delta(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(2 * self.dim());
        out.extend_from_slice(&self.lo);
        out.extend_from_slice(&self.hi);
        out
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        if wire::is_sparse(payload) {
            if payload.len() < 2 {
                return;
            }
            let mut fresh = Ranges::with_dim(payload[1] as usize);
            if fresh.merge_payload(payload) {
                *self = fresh;
            }
            return;
        }
        if payload.len() % 2 != 0 {
            return;
        }
        let d = payload.len() / 2;
        self.lo = payload[..d].to_vec();
        self.hi = payload[d..].to_vec();
    }

    fn reset(&mut self) {
        self.lo.fill(f64::INFINITY);
        self.hi.fill(f64::NEG_INFINITY);
    }
}

/// Welford z-score scaler for numeric attributes; categorical attributes
/// pass through untouched.
pub struct StandardScaler {
    /// Statistics used to transform (global ⊕ pending after a sync).
    view: Moments,
    /// Increment since the last `stats_delta` emission.
    pending: Moments,
    /// Which attributes are numeric under the bound schema.
    numeric: Vec<bool>,
    /// Compute the drift signal per instance (off = zero hot-path cost).
    track_signal: bool,
    /// Mean |z|/3 (clamped) of the last transformed instance — the
    /// drift-gate signal: sits near 0.27 while the stream fits the
    /// running moments, rises when it stops fitting.
    last_signal: Option<f64>,
}

impl StandardScaler {
    pub fn new() -> Self {
        StandardScaler {
            view: Moments::default(),
            pending: Moments::default(),
            numeric: Vec::new(),
            track_signal: false,
            last_signal: None,
        }
    }

    #[inline]
    fn update(&mut self, j: usize, x: f64) {
        self.view.add(j, x);
        self.pending.add(j, x);
    }

    /// Current running mean of attribute `j` (diagnostics/tests).
    pub fn mean(&self, j: usize) -> f64 {
        self.view.mean(j)
    }

    /// The transform-side statistics (diagnostics/tests).
    pub fn moments(&self) -> &Moments {
        &self.view
    }
}

impl Default for StandardScaler {
    fn default() -> Self {
        Self::new()
    }
}

impl MergeableState for StandardScaler {
    fn merge(&mut self, other: &Self) {
        self.view.merge(&other.view);
    }

    fn delta(&self) -> Vec<f64> {
        self.view.delta()
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        self.view.apply_delta(payload);
    }

    fn reset(&mut self) {
        self.view.reset();
        self.pending.reset();
    }
}

impl Transform for StandardScaler {
    fn bind(&mut self, input: &Schema) -> Schema {
        let d = input.n_attributes();
        self.view = Moments::with_dim(d);
        self.pending = Moments::with_dim(d);
        self.numeric =
            input.attributes.iter().map(|a| matches!(a, AttributeKind::Numeric)).collect();
        let mut out = input.clone();
        out.name = format!("{}|scale", input.name);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        let (mut sig_sum, mut sig_n) = (0.0f64, 0u32);
        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let sd = self.view.sd(j);
                    let z = if sd > 1e-12 { (x - self.view.mean(j)) / sd } else { 0.0 };
                    if self.track_signal {
                        sig_sum += (z.abs() / 3.0).min(1.0);
                        sig_n += 1;
                    }
                    *val = if sd > 1e-12 { z as f32 } else { 0.0 };
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let sd = self.view.sd(j);
                    if sd > 1e-12 {
                        if self.track_signal {
                            sig_sum += ((x / sd).abs() / 3.0).min(1.0);
                            sig_n += 1;
                        }
                        *val = (x / sd) as f32; // no centering: keep sparsity
                    }
                }
            }
        }
        if sig_n > 0 {
            self.last_signal = Some(sig_sum / sig_n as f64);
        }
        Some(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        let payload = super::wire::pick_smaller(self.pending.delta(), self.pending.sparse_delta());
        self.pending.reset();
        Some(payload)
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        let payload = self.pending.delta();
        self.pending.reset();
        Some(payload)
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        // merge_payload shape-guards: a foreign/truncated payload (dense
        // or sparse) must not corrupt state
        self.view.merge_payload(payload);
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        Some(self.view.delta())
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        if payload.len() != 3 * self.pending.dim() {
            return;
        }
        let mut global = Moments::default();
        global.apply_delta(payload);
        // keep the not-yet-shipped local increment on top of the global
        global.merge(&self.pending);
        self.view = global;
    }

    fn track_drift_signal(&mut self, on: bool) {
        self.track_signal = on;
    }

    fn drift_signal(&mut self) -> Option<f64> {
        self.last_signal.take()
    }

    fn name(&self) -> &'static str {
        "standard-scaler"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.view.bytes()
            + self.pending.bytes()
            + self.numeric.capacity()
    }
}

/// Running min/max scaler: numeric attributes mapped into `[0, 1]`
/// (dense) or scaled by the running range without shifting (sparse).
pub struct MinMaxScaler {
    view: Ranges,
    pending: Ranges,
    numeric: Vec<bool>,
    /// Compute the drift signal per instance (off = zero hot-path cost).
    track_signal: bool,
    /// Mean normalized position of the last instance — uniform-ish in
    /// expectation while the range fits; drifts toward 0/1 when the
    /// stream leaves the learned range.
    last_signal: Option<f64>,
}

impl MinMaxScaler {
    pub fn new() -> Self {
        MinMaxScaler {
            view: Ranges::default(),
            pending: Ranges::default(),
            numeric: Vec::new(),
            track_signal: false,
            last_signal: None,
        }
    }

    #[inline]
    fn update(&mut self, j: usize, x: f64) {
        self.view.add(j, x);
        self.pending.add(j, x);
    }

    #[inline]
    fn range(&self, j: usize) -> f64 {
        self.view.hi(j) - self.view.lo(j)
    }

    /// The transform-side statistics (diagnostics/tests).
    pub fn ranges(&self) -> &Ranges {
        &self.view
    }
}

impl Default for MinMaxScaler {
    fn default() -> Self {
        Self::new()
    }
}

impl MergeableState for MinMaxScaler {
    fn merge(&mut self, other: &Self) {
        self.view.merge(&other.view);
    }

    fn delta(&self) -> Vec<f64> {
        self.view.delta()
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        self.view.apply_delta(payload);
    }

    fn reset(&mut self) {
        self.view.reset();
        self.pending.reset();
    }
}

impl Transform for MinMaxScaler {
    fn bind(&mut self, input: &Schema) -> Schema {
        let d = input.n_attributes();
        self.view = Ranges::with_dim(d);
        self.pending = Ranges::with_dim(d);
        self.numeric =
            input.attributes.iter().map(|a| matches!(a, AttributeKind::Numeric)).collect();
        let mut out = input.clone();
        out.name = format!("{}|minmax", input.name);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        let (mut sig_sum, mut sig_n) = (0.0f64, 0u32);
        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let r = self.range(j);
                    let y = if r > 1e-12 { (x - self.view.lo(j)) / r } else { 0.0 };
                    if self.track_signal {
                        sig_sum += y;
                        sig_n += 1;
                    }
                    *val = y as f32;
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    // scale by the larger magnitude bound: stays in [-1, 1]
                    let m = self.view.lo(j).abs().max(self.view.hi(j).abs());
                    if m > 1e-12 {
                        *val = (x / m) as f32;
                        if self.track_signal {
                            sig_sum += (x / m).abs();
                            sig_n += 1;
                        }
                    }
                }
            }
        }
        if sig_n > 0 {
            self.last_signal = Some(sig_sum / sig_n as f64);
        }
        Some(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        let payload = super::wire::pick_smaller(self.pending.delta(), self.pending.sparse_delta());
        self.pending.reset();
        Some(payload)
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        let payload = self.pending.delta();
        self.pending.reset();
        Some(payload)
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        // merge_payload shape-guards both the dense and the sparse form
        self.view.merge_payload(payload);
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        Some(self.view.delta())
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        if payload.len() != 2 * self.pending.dim() {
            return;
        }
        let mut global = Ranges::default();
        global.apply_delta(payload);
        global.merge(&self.pending);
        self.view = global;
    }

    fn track_drift_signal(&mut self, on: bool) {
        self.track_signal = on;
    }

    fn drift_signal(&mut self) -> Option<f64> {
        self.last_signal.take()
    }

    fn name(&self) -> &'static str {
        "minmax-scaler"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.view.bytes()
            + self.pending.bytes()
            + self.numeric.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    #[test]
    fn standard_scaler_converges_to_zero_mean_unit_var() {
        let schema = Schema::classification("t", Schema::all_numeric(2), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(5);
        let (mut sum, mut sumsq, mut n) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..20_000 {
            let x = 10.0 + 3.0 * rng.gaussian();
            let out = s
                .transform(Instance::dense(vec![x as f32, 1.0], Label::Class(0)))
                .unwrap();
            let z = out.value(0) as f64;
            sum += z;
            sumsq += z * z;
            n += 1.0;
        }
        let mean = sum / n;
        let var = sumsq / n - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
        // running mean tracked the true location
        assert!((s.mean(0) - 10.0).abs() < 0.1);
    }

    #[test]
    fn constant_attribute_maps_to_zero() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        for _ in 0..100 {
            let out = s.transform(Instance::dense(vec![4.2], Label::None)).unwrap();
            assert_eq!(out.value(0), 0.0);
        }
    }

    #[test]
    fn minmax_lands_in_unit_interval() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = MinMaxScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(6);
        for _ in 0..5000 {
            let x = -50.0 + 100.0 * rng.f64();
            let out = s.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let y = out.value(0);
            assert!((0.0..=1.0).contains(&y), "y={y}");
        }
    }

    #[test]
    fn categorical_attributes_untouched() {
        let schema = Schema::classification("t", Schema::all_categorical(1, 5), 2);
        let mut s = StandardScaler::new();
        let out_schema = s.bind(&schema);
        assert_eq!(out_schema.attributes, schema.attributes);
        let out = s.transform(Instance::dense(vec![3.0], Label::None)).unwrap();
        assert_eq!(out.value(0), 3.0);
    }

    #[test]
    fn sparse_scaling_preserves_structure() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(7);
        for _ in 0..500 {
            let v = 1.0 + rng.f32();
            let out = s
                .transform(Instance::sparse(vec![3, 9], vec![v, v], 100, Label::None))
                .unwrap();
            assert_eq!(out.n_stored(), 2, "sparsity must be preserved");
            assert_eq!(out.n_attributes(), 100);
        }
    }

    #[test]
    fn chan_merge_equals_single_pass() {
        let mut rng = Rng::new(9);
        let (mut a, mut b, mut all) =
            (Moments::with_dim(1), Moments::with_dim(1), Moments::with_dim(1));
        for i in 0..5000 {
            let x = rng.gaussian() * 2.0 + 0.5;
            if i % 2 == 0 {
                a.add(0, x);
            } else {
                b.add(0, x);
            }
            all.add(0, x);
        }
        a.merge(&b);
        assert!((a.count(0) - all.count(0)).abs() < 1e-9);
        assert!((a.mean(0) - all.mean(0)).abs() < 1e-9);
        assert!((a.sd(0) - all.sd(0)).abs() < 1e-9);
    }

    #[test]
    fn pending_delta_resets_and_round_trips() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        for i in 0..10 {
            s.transform(Instance::dense(vec![i as f32], Label::None)).unwrap();
        }
        let d1 = s.stats_delta().unwrap();
        assert_eq!(d1[0], 10.0, "pending count shipped");
        let d2 = s.stats_delta().unwrap();
        assert_eq!(d2[0], 0.0, "pending reset after emit");
        // snapshot round trip through another scaler
        let mut t = StandardScaler::new();
        t.bind(&schema);
        t.stats_merge(&s.stats_snapshot().unwrap());
        assert!((t.mean(0) - s.mean(0)).abs() < 1e-12);
    }

    /// Sparse deltas carry exactly the changed columns and merge to the
    /// same state as the dense form.
    #[test]
    fn sparse_delta_merges_like_dense() {
        let mut m = Moments::with_dim(64);
        for j in [3usize, 17, 40] {
            for i in 0..20 {
                m.add(j, i as f64 * 0.5 + j as f64);
            }
        }
        let sparse = m.sparse_delta();
        let dense = m.delta();
        assert!(crate::preprocess::wire::is_sparse(&sparse));
        assert!(sparse.len() < dense.len(), "3/64 changed columns must compress");

        let (mut a, mut b) = (Moments::with_dim(64), Moments::with_dim(64));
        for j in 0..64 {
            a.add(j, 1.0);
            b.add(j, 1.0);
        }
        assert!(a.merge_payload(&dense));
        assert!(b.merge_payload(&sparse));
        assert!(crate::preprocess::merge::payloads_close(&a.delta(), &b.delta(), 1e-12));

        // apply_delta rebuilds from the sparse form too
        let mut c = Moments::default();
        c.apply_delta(&sparse);
        assert!(crate::preprocess::merge::payloads_close(&c.delta(), &m.delta(), 1e-12));
    }

    #[test]
    fn sparse_ranges_merge_like_dense() {
        let mut r = Ranges::with_dim(32);
        r.add(5, -2.0);
        r.add(5, 7.0);
        r.add(30, 1.0);
        let sparse = r.sparse_delta();
        assert!(sparse.len() < r.delta().len());
        let (mut a, mut b) = (Ranges::with_dim(32), Ranges::with_dim(32));
        a.add(5, 0.0);
        b.add(5, 0.0);
        assert!(a.merge_payload(&r.delta()));
        assert!(b.merge_payload(&sparse));
        assert_eq!(a.delta(), b.delta());
        let mut c = Ranges::default();
        c.apply_delta(&sparse);
        assert_eq!(c.delta(), r.delta());
    }

    /// Shape guards: foreign payloads leave state untouched.
    #[test]
    fn merge_payload_rejects_mismatched_shapes() {
        let mut m = Moments::with_dim(4);
        m.add(0, 1.0);
        let before = m.delta();
        assert!(!m.merge_payload(&[f64::NAN, 9.0, 0.0])); // wrong dim
        assert!(!m.merge_payload(&[1.0, 2.0])); // wrong dense length
        assert_eq!(m.delta(), before);
    }

    /// The drift signal tracks distribution shift: stationary data keeps
    /// mean |z|/3 low, an abrupt mean jump pushes it up.
    #[test]
    fn drift_signal_reacts_to_shift() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        Transform::track_drift_signal(&mut s, true);
        let mut rng = Rng::new(8);
        let mut stable = 0.0;
        for _ in 0..2000 {
            s.transform(Instance::dense(vec![rng.gaussian() as f32], Label::None)).unwrap();
            // take-semantics: each observed instance yields one sample
            stable = Transform::drift_signal(&mut s).unwrap();
            assert!(Transform::drift_signal(&mut s).is_none(), "signal must be taken once");
        }
        assert!(stable < 0.6, "stationary signal too high: {stable}");
        // abrupt +10σ shift: the first post-shift signals must exceed the
        // stationary level
        let shifted = {
            let mut peak: f64 = 0.0;
            for _ in 0..32 {
                s.transform(Instance::dense(vec![10.0 + rng.gaussian() as f32], Label::None))
                    .unwrap();
                peak = peak.max(Transform::drift_signal(&mut s).unwrap());
            }
            peak
        };
        assert!(shifted > stable, "signal did not react: {shifted} <= {stable}");
        // tracking off: no signal is produced
        Transform::track_drift_signal(&mut s, false);
        s.transform(Instance::dense(vec![0.0], Label::None)).unwrap();
        assert!(Transform::drift_signal(&mut s).is_none());
    }
}
