//! Running-moment scalers: z-score ([`StandardScaler`]) and range
//! ([`MinMaxScaler`]) normalization with online statistics — no fit phase,
//! statistics accumulate as the stream flows (update-then-transform).
//!
//! Sparse handling: centering would densify, so sparse instances are only
//! *divided* (by the running σ / range); stored zeros stay zero and absent
//! attributes stay absent. Statistics over sparse input are computed from
//! stored values only (absence is "not observed", not "zero" — matching
//! the presence semantics of the sparse VHT observers).

use crate::common::memsize::vec_flat_bytes;
use crate::core::instance::Values;
use crate::core::{AttributeKind, Instance, Schema};

use super::Transform;

/// Welford z-score scaler for numeric attributes; categorical attributes
/// pass through untouched.
pub struct StandardScaler {
    /// Per-attribute observation count / mean / sum of squared deviations.
    n: Vec<f64>,
    mean: Vec<f64>,
    m2: Vec<f64>,
    /// Which attributes are numeric under the bound schema.
    numeric: Vec<bool>,
}

impl StandardScaler {
    pub fn new() -> Self {
        StandardScaler { n: Vec::new(), mean: Vec::new(), m2: Vec::new(), numeric: Vec::new() }
    }

    #[inline]
    fn update(&mut self, j: usize, x: f64) {
        self.n[j] += 1.0;
        let d = x - self.mean[j];
        self.mean[j] += d / self.n[j];
        self.m2[j] += d * (x - self.mean[j]);
    }

    #[inline]
    fn sd(&self, j: usize) -> f64 {
        if self.n[j] < 2.0 {
            return 0.0;
        }
        (self.m2[j] / self.n[j]).sqrt()
    }

    /// Current running mean of attribute `j` (diagnostics/tests).
    pub fn mean(&self, j: usize) -> f64 {
        self.mean[j]
    }
}

impl Default for StandardScaler {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform for StandardScaler {
    fn bind(&mut self, input: &Schema) -> Schema {
        let d = input.n_attributes();
        self.n = vec![0.0; d];
        self.mean = vec![0.0; d];
        self.m2 = vec![0.0; d];
        self.numeric =
            input.attributes.iter().map(|a| matches!(a, AttributeKind::Numeric)).collect();
        let mut out = input.clone();
        out.name = format!("{}|scale", input.name);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        match &mut inst.values {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let sd = self.sd(j);
                    *val = if sd > 1e-12 { ((x - self.mean[j]) / sd) as f32 } else { 0.0 };
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let sd = self.sd(j);
                    if sd > 1e-12 {
                        *val = (x / sd) as f32; // no centering: keep sparsity
                    }
                }
            }
        }
        Some(inst)
    }

    fn name(&self) -> &'static str {
        "standard-scaler"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + vec_flat_bytes(&self.n)
            + vec_flat_bytes(&self.mean)
            + vec_flat_bytes(&self.m2)
            + self.numeric.capacity()
    }
}

/// Running min/max scaler: numeric attributes mapped into `[0, 1]`
/// (dense) or scaled by the running range without shifting (sparse).
pub struct MinMaxScaler {
    lo: Vec<f64>,
    hi: Vec<f64>,
    numeric: Vec<bool>,
}

impl MinMaxScaler {
    pub fn new() -> Self {
        MinMaxScaler { lo: Vec::new(), hi: Vec::new(), numeric: Vec::new() }
    }

    #[inline]
    fn update(&mut self, j: usize, x: f64) {
        if x < self.lo[j] {
            self.lo[j] = x;
        }
        if x > self.hi[j] {
            self.hi[j] = x;
        }
    }

    #[inline]
    fn range(&self, j: usize) -> f64 {
        self.hi[j] - self.lo[j]
    }
}

impl Default for MinMaxScaler {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform for MinMaxScaler {
    fn bind(&mut self, input: &Schema) -> Schema {
        let d = input.n_attributes();
        self.lo = vec![f64::INFINITY; d];
        self.hi = vec![f64::NEG_INFINITY; d];
        self.numeric =
            input.attributes.iter().map(|a| matches!(a, AttributeKind::Numeric)).collect();
        let mut out = input.clone();
        out.name = format!("{}|minmax", input.name);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        match &mut inst.values {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    let r = self.range(j);
                    *val = if r > 1e-12 { ((x - self.lo[j]) / r) as f32 } else { 0.0 };
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    if !self.numeric[j] {
                        continue;
                    }
                    let x = *val as f64;
                    self.update(j, x);
                    // scale by the larger magnitude bound: stays in [-1, 1]
                    let m = self.lo[j].abs().max(self.hi[j].abs());
                    if m > 1e-12 {
                        *val = (x / m) as f32;
                    }
                }
            }
        }
        Some(inst)
    }

    fn name(&self) -> &'static str {
        "minmax-scaler"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + vec_flat_bytes(&self.lo)
            + vec_flat_bytes(&self.hi)
            + self.numeric.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    #[test]
    fn standard_scaler_converges_to_zero_mean_unit_var() {
        let schema = Schema::classification("t", Schema::all_numeric(2), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(5);
        let (mut sum, mut sumsq, mut n) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..20_000 {
            let x = 10.0 + 3.0 * rng.gaussian();
            let out = s
                .transform(Instance::dense(vec![x as f32, 1.0], Label::Class(0)))
                .unwrap();
            let z = out.value(0) as f64;
            sum += z;
            sumsq += z * z;
            n += 1.0;
        }
        let mean = sum / n;
        let var = sumsq / n - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
        // running mean tracked the true location
        assert!((s.mean(0) - 10.0).abs() < 0.1);
    }

    #[test]
    fn constant_attribute_maps_to_zero() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        for _ in 0..100 {
            let out = s.transform(Instance::dense(vec![4.2], Label::None)).unwrap();
            assert_eq!(out.value(0), 0.0);
        }
    }

    #[test]
    fn minmax_lands_in_unit_interval() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut s = MinMaxScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(6);
        for _ in 0..5000 {
            let x = -50.0 + 100.0 * rng.f64();
            let out = s.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let y = out.value(0);
            assert!((0.0..=1.0).contains(&y), "y={y}");
        }
    }

    #[test]
    fn categorical_attributes_untouched() {
        let schema = Schema::classification("t", Schema::all_categorical(1, 5), 2);
        let mut s = StandardScaler::new();
        let out_schema = s.bind(&schema);
        assert_eq!(out_schema.attributes, schema.attributes);
        let out = s.transform(Instance::dense(vec![3.0], Label::None)).unwrap();
        assert_eq!(out.value(0), 3.0);
    }

    #[test]
    fn sparse_scaling_preserves_structure() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut s = StandardScaler::new();
        s.bind(&schema);
        let mut rng = Rng::new(7);
        for _ in 0..500 {
            let v = 1.0 + rng.f32();
            let out = s
                .transform(Instance::sparse(vec![3, 9], vec![v, v], 100, Label::None))
                .unwrap();
            assert_eq!(out.n_stored(), 2, "sparsity must be preserved");
            assert_eq!(out.n_attributes(), 100);
        }
    }
}
