//! Sparse delta wire format — the compressed encodings that let the
//! delta-sync protocol ship only the statistics that *changed* since a
//! shard's last emission (the ROADMAP's "delta compression" follow-up;
//! Benczúr et al. 2018 argue distributed learners should communicate
//! only meaningfully-changed state).
//!
//! Payloads stay flat `Vec<f64>` (the `Event::StatsDelta` wire type).
//! A sparse payload is tagged by a leading **NaN** — no genuine dense
//! payload can start with one (counts are `>= 0`, `Ranges` lows start at
//! `+inf` and min/max against NaN never stores it), so decoders
//! dispatch on [`is_sparse`] without a format version field.
//!
//! Per-operator layouts (`d` = attribute count, `m` = changed count):
//!
//! | state | sparse layout | changed means |
//! |---|---|---|
//! | `Moments` | `[NaN, d, mask…, (n, mean, m2) × m]` | column saw an observation (`n > 0`) |
//! | `Ranges` | `[NaN, d, mask…, (lo, hi) × m]` | column saw an observation (`lo ≤ hi`) |
//! | `CountMinSketch` | `[NaN, w, depth, total, m, (cell, count) × m]` | counter cell is non-zero |
//! | `Discretizer` | presence flag per attribute (pre-existing) | summary saw an observation |
//! | `MisraGries` | dense form is already a changed-key set | — |
//!
//! The changed-column **bitmask** packs 32 column flags per f64 word
//! (32, not 64: every word stays exactly representable in the f64
//! mantissa, so the payload survives an f64 round trip bit-exactly).
//!
//! Emitters pick whichever of the dense/sparse form is smaller
//! ([`pick_smaller`]), so compression can never inflate a delta; the
//! engine's per-delivery byte metrics (`Event::wire_bytes` is
//! `O(payload len)`) make the saving directly measurable.

/// Bits packed per mask word (see module docs for why not 64).
pub const MASK_BITS: usize = 32;

/// `true` when `payload` is a NaN-tagged sparse encoding.
#[inline]
pub fn is_sparse(payload: &[f64]) -> bool {
    payload.first().is_some_and(|x| x.is_nan())
}

/// Number of mask words needed for `d` columns.
#[inline]
pub fn mask_words(d: usize) -> usize {
    d.div_ceil(MASK_BITS)
}

/// Append the changed-column bitmask for `changed` (one flag per column).
pub fn encode_mask(out: &mut Vec<f64>, changed: &[bool]) {
    let words = mask_words(changed.len());
    let base = out.len();
    out.resize(base + words, 0.0);
    for (j, &c) in changed.iter().enumerate() {
        if c {
            let w = base + j / MASK_BITS;
            out[w] = ((out[w] as u64) | (1u64 << (j % MASK_BITS))) as f64;
        }
    }
}

/// Decode a bitmask of `d` columns starting at `words`; returns the set
/// column indices in ascending order, or `None` if `words` is too short.
pub fn decode_mask(words: &[f64], d: usize) -> Option<Vec<usize>> {
    let need = mask_words(d);
    if words.len() < need {
        return None;
    }
    let mut cols = Vec::new();
    for j in 0..d {
        let w = words[j / MASK_BITS] as u64;
        if w & (1u64 << (j % MASK_BITS)) != 0 {
            cols.push(j);
        }
    }
    Some(cols)
}

/// The adaptive choice: whichever encoding is shorter wins (ties go
/// dense — it is the simpler decode path).
pub fn pick_smaller(dense: Vec<f64>, sparse: Vec<f64>) -> Vec<f64> {
    if sparse.len() < dense.len() {
        sparse
    } else {
        dense
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_round_trips() {
        for d in [1usize, 31, 32, 33, 64, 100] {
            let changed: Vec<bool> = (0..d).map(|j| j % 3 == 0 || j == d - 1).collect();
            let mut out = Vec::new();
            encode_mask(&mut out, &changed);
            assert_eq!(out.len(), mask_words(d));
            let cols = decode_mask(&out, d).unwrap();
            let want: Vec<usize> = (0..d).filter(|&j| changed[j]).collect();
            assert_eq!(cols, want, "d={d}");
        }
    }

    #[test]
    fn mask_words_survive_f64_exactly() {
        // all 32 bits set is still an exactly-representable integer
        let changed = vec![true; 32];
        let mut out = Vec::new();
        encode_mask(&mut out, &changed);
        assert_eq!(out[0] as u64, u32::MAX as u64);
        assert_eq!(decode_mask(&out, 32).unwrap().len(), 32);
    }

    #[test]
    fn sparse_tag_detection() {
        assert!(is_sparse(&[f64::NAN, 1.0]));
        assert!(!is_sparse(&[0.0, 1.0]));
        assert!(!is_sparse(&[]));
        assert!(!is_sparse(&[f64::INFINITY]));
    }

    #[test]
    fn pick_smaller_prefers_dense_on_tie() {
        assert_eq!(pick_smaller(vec![1.0, 2.0], vec![f64::NAN, 9.0]), vec![1.0, 2.0]);
        assert!(is_sparse(&pick_smaller(vec![1.0, 2.0, 3.0], vec![f64::NAN, 9.0])));
    }
}
