//! Topology integration: run a preprocessing [`Pipeline`] as a
//! [`Processor`] node, parallelizable like any other SAMOA processor.
//! Stateful operators keep mergeable statistics, and with a sync interval
//! configured the shards converge to *shared* statistics through the
//! delta-sync loop ([`super::sync::StatsSyncProcessor`]): shard → (Key)
//! aggregator → (All broadcast) shards.
//!
//! [`build_prequential_topology`] (classifier head, no sync — the PR-1
//! shape) and [`build_prequential_topology_head`] (classifier *or*
//! regressor head, optional sync) assemble the full prequential task:
//! `source → pipeline×p [⇄ stats-sync] → learner → evaluator`.

use crate::core::model::{Classifier, Regressor};
use crate::core::Schema;
use crate::topology::{
    Ctx, Event, Grouping, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

use super::pipeline::Pipeline;
use super::sync::StatsSyncProcessor;
use super::Transform;

/// One pipeline instance inside a topology: transforms every
/// `Event::Instance` and forwards survivors downstream, preserving ids
/// (so downstream key-groupings and the evaluator still line up).
///
/// With [`PipelineProcessor::with_sync`], every `interval` locally
/// processed instances the shard emits its stages' pending state deltas
/// (`Event::StatsDelta`, keyed by stage) and adopts the aggregator's
/// merged broadcasts (`Event::StatsGlobal`).
pub struct PipelineProcessor {
    pipeline: Pipeline,
    out: StreamId,
    /// (interval, delta stream) when delta-sync is enabled.
    sync: Option<(u64, StreamId)>,
    /// Instances processed since the last delta emission.
    since_sync: u64,
}

impl PipelineProcessor {
    /// Bind `pipeline` (unbound) to `input` and forward transformed
    /// instances on `out`.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId) -> Self {
        pipeline.bind(input);
        PipelineProcessor { pipeline, out, sync: None, since_sync: 0 }
    }

    /// Enable delta-sync: emit pending state deltas on `delta_stream`
    /// every `interval` locally processed instances.
    pub fn with_sync(mut self, interval: u64, delta_stream: StreamId) -> Self {
        self.sync = Some((interval.max(1), delta_stream));
        self
    }

    pub fn output_schema(&self) -> &Schema {
        self.pipeline.output_schema()
    }

    /// The bound pipeline (state inspection in tests/harnesses).
    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Ship every stage's pending increment on `delta_stream`.
    fn emit_deltas(&mut self, delta_stream: StreamId, ctx: &mut Ctx) {
        for (stage, payload) in self.pipeline.stats_deltas() {
            ctx.emit(
                delta_stream,
                stage as u64,
                Event::StatsDelta { stage: stage as u32, payload: std::sync::Arc::new(payload) },
            );
        }
        self.since_sync = 0;
    }
}

impl Processor for PipelineProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { id, inst } => {
                if let Some(out) = self.pipeline.transform(inst) {
                    ctx.emit(self.out, id, Event::Instance { id, inst: out });
                }
                self.since_sync += 1;
                if let Some((interval, delta_stream)) = self.sync {
                    if self.since_sync >= interval {
                        self.emit_deltas(delta_stream, ctx);
                    }
                }
            }
            Event::StatsGlobal { stage, payload } => {
                self.pipeline.stats_apply(stage as usize, &payload);
            }
            _ => {}
        }
    }

    /// Flush the un-shipped pending increment so short runs (or
    /// `interval > n/p`) still reach the aggregator. Reliable under the
    /// local engine (the flush drains before processors are collected);
    /// best-effort under the threaded engine, where the aggregator may
    /// already be shutting down.
    fn on_shutdown(&mut self, ctx: &mut Ctx) {
        if let Some((_, delta_stream)) = self.sync {
            if self.since_sync > 0 {
                self.emit_deltas(delta_stream, ctx);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.pipeline.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "pipeline"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

/// Which learner rides behind the pipeline shards: a sequential
/// classifier ([`crate::evaluation::prequential::ClassifierProcessor`])
/// or a sequential regressor such as AMRules
/// ([`crate::evaluation::prequential::RegressorProcessor`]).
pub enum LearnerHead {
    Classifier(Box<dyn Fn(&Schema) -> Box<dyn Classifier>>),
    Regressor(Box<dyn Fn(&Schema) -> Box<dyn Regressor>>),
}

/// Stream/processor handles of the prequential preprocessing topologies.
/// Stream ids are fixed by declaration order: 0 entry, 1 instances,
/// 2 prediction, then (sync only) 3 delta, 4 global.
#[derive(Clone, Copy, Debug)]
pub struct PreprocessHandles {
    pub entry: StreamId,
    /// pipeline → learner (transformed instances).
    pub instances: StreamId,
    /// learner → evaluator.
    pub prediction: StreamId,
    pub pipeline: ProcessorId,
    pub learner: ProcessorId,
    pub evaluator: ProcessorId,
    /// shards → aggregator state deltas (sync topologies only).
    pub delta: Option<StreamId>,
    /// aggregator → shards merged broadcasts (sync topologies only).
    pub global: Option<StreamId>,
    pub stats: Option<ProcessorId>,
}

/// Assemble `source → pipeline×p → learner → evaluator` with a
/// classifier head and no stats-sync (the PR-1 shape; see
/// [`build_prequential_topology_head`] for the full knobs).
pub fn build_prequential_topology(
    schema: &Schema,
    parallelism: usize,
    pipeline_factory: impl Fn(usize) -> Pipeline + Clone + 'static,
    classifier_factory: impl Fn(&Schema) -> Box<dyn Classifier> + 'static,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    build_prequential_topology_head(
        schema,
        parallelism,
        None,
        pipeline_factory,
        LearnerHead::Classifier(Box::new(classifier_factory)),
        evaluator,
    )
}

/// Assemble the prequential preprocessing topology with a selectable
/// learner head and optional delta-sync:
///
/// ```text
/// source → pipeline×p → learner(classifier|regressor) → evaluator
///              ⇅ (sync_interval: Key-grouped deltas / All broadcasts)
///          stats-sync
/// ```
///
/// `pipeline_factory` is called once per pipeline shard (each owns
/// independent operator state) and once more for the aggregator's master
/// state container; `sync_interval` is the per-shard emission period in
/// instances (`None` = isolated shard statistics, the PR-1 behavior).
pub fn build_prequential_topology_head(
    schema: &Schema,
    parallelism: usize,
    sync_interval: Option<u64>,
    pipeline_factory: impl Fn(usize) -> Pipeline + Clone + 'static,
    head: LearnerHead,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    let mut b = TopologyBuilder::new("preprocess-prequential");
    let instances = StreamId(1);
    let prediction = StreamId(2);
    let delta = StreamId(3);
    let global = StreamId(4);

    // probe bind: the learner consumes the pipeline's output schema
    let mut probe = pipeline_factory(usize::MAX);
    let out_schema = probe.bind(schema);

    let in_schema = schema.clone();
    let pf = pipeline_factory.clone();
    let pipe = b.add_processor("pipeline", parallelism, move |i| {
        let p = PipelineProcessor::new(pf(i), &in_schema, instances);
        Box::new(match sync_interval {
            Some(interval) => p.with_sync(interval, delta),
            None => p,
        })
    });
    // the factory stays inside the closure so the topology is re-runnable
    // (engines re-invoke every processor factory per run)
    let learner = match head {
        LearnerHead::Classifier(f) => {
            let s = out_schema.clone();
            b.add_processor("learner", 1, move |_| {
                Box::new(crate::evaluation::prequential::ClassifierProcessor::new(
                    f(&s),
                    prediction,
                ))
            })
        }
        LearnerHead::Regressor(f) => {
            let s = out_schema.clone();
            b.add_processor("learner", 1, move |_| {
                Box::new(crate::evaluation::prequential::RegressorProcessor::new(
                    f(&s),
                    prediction,
                ))
            })
        }
    };
    let eval = b.add_processor("evaluator", 1, evaluator);
    let stats = sync_interval.map(|_| {
        let s = schema.clone();
        let pf = pipeline_factory.clone();
        b.add_processor("stats-sync", 1, move |_| {
            // one sync round = one delta from each of the `parallelism`
            // shards; the aggregator broadcasts once per stage per round
            Box::new(StatsSyncProcessor::new(pf(usize::MAX), &s, global, parallelism))
        })
    });

    let entry = b.stream("instance", None, pipe, Grouping::Shuffle);
    let s_inst = b.stream("transformed", Some(pipe), learner, Grouping::Shuffle);
    let s_pred = b.stream("prediction", Some(learner), eval, Grouping::Shuffle);
    debug_assert_eq!(s_inst, instances);
    debug_assert_eq!(s_pred, prediction);
    let (s_delta, s_global) = match stats {
        Some(stats) => {
            let d = b.stream("stats-delta", Some(pipe), stats, Grouping::Key);
            let g = b.stream("stats-global", Some(stats), pipe, Grouping::All);
            debug_assert_eq!(d, delta);
            debug_assert_eq!(g, global);
            (Some(d), Some(g))
        }
        None => (None, None),
    };

    (
        b.build(),
        PreprocessHandles {
            entry,
            instances,
            prediction,
            pipeline: pipe,
            learner,
            evaluator: eval,
            delta: s_delta,
            global: s_global,
            stats,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::engine::LocalEngine;
    use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use crate::preprocess::{Discretizer, StandardScaler};
    use crate::streams::waveform::WaveformGenerator;
    use crate::streams::StreamSource;
    use std::sync::Arc;

    #[test]
    fn topology_runs_and_predicts() {
        let mut stream = WaveformGenerator::classification(21);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 1000);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_prequential_topology(
            &schema,
            2,
            |_| Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
            |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let source = (0..3000u64)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        assert_eq!(m.source_instances, 3000);
        // every instance produced exactly one transformed event and one
        // prediction (no filter in this pipeline)
        assert_eq!(m.streams[handles.instances.0].events, 3000);
        assert_eq!(m.streams[handles.prediction.0].events, 3000);
        // waveform has strong signal: must beat majority-class guessing
        assert!(sink.accuracy() > 0.5, "accuracy={}", sink.accuracy());
    }

    #[test]
    fn sync_topology_emits_deltas_and_broadcasts() {
        let mut stream = WaveformGenerator::classification(5);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 1000);
        let sink2 = Arc::clone(&sink);
        let p = 4usize;
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            Some(64),
            |_| Pipeline::new().then(StandardScaler::new()),
            LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn crate::core::model::Classifier> {
                Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
            })),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let n = 2048u64;
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        assert_eq!(m.source_instances, n);
        assert_eq!(m.streams[handles.prediction.0].events, n);
        // each shard sees n/p instances and emits a delta every 64:
        // (n/p/64) emissions per shard, one stateful stage
        let expected_deltas = (n as usize / p / 64 * p) as u64;
        assert_eq!(m.streams[handles.delta.unwrap().0].events, expected_deltas);
        // coalesced broadcasts: ONE snapshot per stage per round of p
        // deltas, delivered to all p shards — so total global deliveries
        // equal total deltas (deltas/p rounds × p destinations), not
        // deltas × p as the pre-coalescing protocol paid
        assert_eq!(m.streams[handles.global.unwrap().0].events, expected_deltas);
    }

    /// Shutdown stragglers: with `n` NOT divisible by interval × p, some
    /// shards flush a final pending delta from `on_shutdown`; the local
    /// engine drains those into the aggregator BEFORE the aggregator's
    /// own `on_shutdown`, which then broadcasts the partial round once.
    #[test]
    fn shutdown_flush_broadcasts_partial_round() {
        let mut stream = WaveformGenerator::classification(5);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 10_000);
        let sink2 = Arc::clone(&sink);
        let p = 4usize;
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            Some(64),
            |_| Pipeline::new().then(StandardScaler::new()),
            LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn crate::core::model::Classifier> {
                Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
            })),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        // 2050 = 4 × 512 + 2: shards 0/1 see 513 instances (8 emissions +
        // 1 shutdown-flush delta), shards 2/3 see 512 (8 emissions, no
        // flush) — one stateful stage
        let n = 2050u64;
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let deltas = m.streams[handles.delta.unwrap().0].events;
        let globals = m.streams[handles.global.unwrap().0].events;
        assert_eq!(deltas, 34, "8 regular emissions × 4 shards + 2 shutdown flushes");
        // 8 complete rounds (32 deliveries) + ONE partial-round flush
        // broadcast at aggregator shutdown (4 deliveries)
        assert_eq!(globals, 36, "partial round must be flushed exactly once");
    }
}
