//! Topology integration: run a preprocessing [`Pipeline`] as a
//! [`Processor`] node, parallelizable like any other SAMOA processor —
//! shuffle-group the inbound stream for stateless pipelines (hashing) or
//! key-group by instance id when per-key statistics matter. Stateful
//! operators keep *per-instance-local* statistics, mirroring how the
//! paper's local statistics processors shard state.

use crate::core::model::Classifier;
use crate::core::Schema;
use crate::topology::{
    Ctx, Event, Grouping, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

use super::pipeline::Pipeline;
use super::Transform;

/// One pipeline instance inside a topology: transforms every
/// `Event::Instance` and forwards survivors downstream, preserving ids
/// (so downstream key-groupings and the evaluator still line up).
pub struct PipelineProcessor {
    pipeline: Pipeline,
    out: StreamId,
}

impl PipelineProcessor {
    /// Bind `pipeline` (unbound) to `input` and forward transformed
    /// instances on `out`.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId) -> Self {
        pipeline.bind(input);
        PipelineProcessor { pipeline, out }
    }

    pub fn output_schema(&self) -> &Schema {
        self.pipeline.output_schema()
    }
}

impl Processor for PipelineProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, inst } = event {
            if let Some(out) = self.pipeline.transform(inst) {
                ctx.emit(self.out, id, Event::Instance { id, inst: out });
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.pipeline.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "pipeline"
    }
}

/// Stream/processor handles of [`build_prequential_topology`]. Stream ids
/// are fixed by declaration order: 0 entry, 1 instances, 2 prediction.
#[derive(Clone, Copy, Debug)]
pub struct PreprocessHandles {
    pub entry: StreamId,
    /// pipeline → learner (transformed instances).
    pub instances: StreamId,
    /// learner → evaluator.
    pub prediction: StreamId,
    pub pipeline: ProcessorId,
    pub learner: ProcessorId,
    pub evaluator: ProcessorId,
}

/// Assemble `source → pipeline×p → learner → evaluator`: the prequential
/// classification task over a preprocessed stream, runnable on every
/// engine. `pipeline_factory` is called once per pipeline instance (each
/// owns independent operator state); the learner is a single test-then-
/// train [`crate::evaluation::prequential::ClassifierProcessor`] fed by
/// `classifier_factory` with the pipeline's *output* schema.
pub fn build_prequential_topology(
    schema: &Schema,
    parallelism: usize,
    pipeline_factory: impl Fn(usize) -> Pipeline + 'static,
    classifier_factory: impl Fn(&Schema) -> Box<dyn Classifier> + 'static,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    let mut b = TopologyBuilder::new("preprocess-prequential");
    let instances = StreamId(1);
    let prediction = StreamId(2);

    // probe bind: the learner consumes the pipeline's output schema
    let mut probe = pipeline_factory(usize::MAX);
    let out_schema = probe.bind(schema);

    let in_schema = schema.clone();
    let pipe = b.add_processor("pipeline", parallelism, move |i| {
        Box::new(PipelineProcessor::new(pipeline_factory(i), &in_schema, instances))
    });
    // the factory stays inside the closure so the topology is re-runnable
    // (engines re-invoke every processor factory per run)
    let learner = b.add_processor("learner", 1, move |_| {
        Box::new(crate::evaluation::prequential::ClassifierProcessor::new(
            classifier_factory(&out_schema),
            prediction,
        ))
    });
    let eval = b.add_processor("evaluator", 1, evaluator);

    let entry = b.stream("instance", None, pipe, Grouping::Shuffle);
    let s_inst = b.stream("transformed", Some(pipe), learner, Grouping::Shuffle);
    let s_pred = b.stream("prediction", Some(learner), eval, Grouping::Shuffle);
    debug_assert_eq!(s_inst, instances);
    debug_assert_eq!(s_pred, prediction);

    (
        b.build(),
        PreprocessHandles {
            entry,
            instances,
            prediction,
            pipeline: pipe,
            learner,
            evaluator: eval,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::engine::LocalEngine;
    use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use crate::preprocess::{Discretizer, StandardScaler};
    use crate::streams::waveform::WaveformGenerator;
    use crate::streams::StreamSource;
    use std::sync::Arc;

    #[test]
    fn topology_runs_and_predicts() {
        let mut stream = WaveformGenerator::classification(21);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 1000);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_prequential_topology(
            &schema,
            2,
            |_| Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
            |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let source = (0..3000u64)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        assert_eq!(m.source_instances, 3000);
        // every instance produced exactly one transformed event and one
        // prediction (no filter in this pipeline)
        assert_eq!(m.streams[handles.instances.0].events, 3000);
        assert_eq!(m.streams[handles.prediction.0].events, 3000);
        // waveform has strong signal: must beat majority-class guessing
        assert!(sink.accuracy() > 0.5, "accuracy={}", sink.accuracy());
    }
}
