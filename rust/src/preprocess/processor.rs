//! Topology integration: run a preprocessing [`Pipeline`] as a
//! [`Processor`] node, parallelizable like any other SAMOA processor.
//! Stateful operators keep mergeable statistics, and with a sync policy
//! configured the shards converge to *shared* statistics through the
//! delta-sync loop ([`super::sync::StatsSyncProcessor`]): shard → (Key)
//! aggregator → (All broadcast) shards.
//!
//! Emission is governed by a [`SyncPolicy`]: the classic fixed count
//! (`Count`), an ADWIN drift gate per stage with a max-staleness
//! backstop (`Drift` — the default: communicate when the statistics
//! meaningfully change, per Benczúr et al. 2018 / DPASF), or both
//! (`Hybrid`).
//!
//! [`build_prequential_topology`] (classifier head, no sync — the PR-1
//! shape) and [`build_prequential_topology_head`] (classifier *or*
//! regressor head, optional sync) assemble the full prequential task:
//! `source → pipeline×p [⇄ stats-sync] → learner → evaluator`.

use crate::core::model::{Classifier, Regressor};
use crate::core::Schema;
use crate::drift::adwin::Adwin;
use crate::drift::ChangeDetector;
use crate::topology::{
    Ctx, Event, Grouping, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

use super::pipeline::Pipeline;
use super::sync::StatsSyncProcessor;
use super::Transform;

/// When does a pipeline shard ship its pending statistics deltas?
///
/// State machine per stateful stage (see `README.md` for the protocol
/// around it):
///
/// ```text
///             instance processed (staleness += 1, gate fed)
///           ┌────────────────────────────────────────────┐
///           ▼                                            │
///   ACCUMULATING ──[policy trigger]──▶ EMIT StatsDelta ──┘
///        │                              (round += 1, staleness = 0)
///        └──[StatsGlobal arrives]──▶ view = global ⊕ pending
/// ```
///
/// Triggers per policy:
/// * `Count(n)` — staleness reaches `n` (the PR-2 fixed interval);
/// * `Drift` — the stage's ADWIN gate (fed the stage's
///   [`Transform::drift_signal`]) detects change, or staleness reaches
///   `max_staleness` (backstop, so a quiet stage still reconciles);
/// * `Hybrid` — any stage's gate fires (all stages flush together,
///   keeping rounds aligned) or staleness reaches `interval`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncPolicy {
    /// Emit every `n` locally processed instances.
    Count(u64),
    /// Emit a stage's delta when its ADWIN(`delta`) gate fires; backstop
    /// emission after `max_staleness` instances without one.
    Drift { delta: f64, max_staleness: u64 },
    /// Coordinated flush of every stage when any gate fires, plus the
    /// fixed `interval` cadence.
    Hybrid { interval: u64, delta: f64 },
}

impl Default for SyncPolicy {
    /// Drift-gated with a generous backstop — the adaptive default that
    /// replaces the fixed count.
    fn default() -> Self {
        SyncPolicy::Drift { delta: 0.002, max_staleness: 1024 }
    }
}

impl SyncPolicy {
    /// Parse a CLI spec: a bare number is `Count(n)` (`0` = `None`, sync
    /// off), `drift[:staleness[:delta]]`, `hybrid[:interval[:delta]]`.
    pub fn parse(spec: &str) -> crate::Result<Option<SyncPolicy>> {
        let mut parts = spec.split(':');
        let head = parts.next().unwrap_or("");
        let num = |s: Option<&str>, default: u64| -> crate::Result<u64> {
            match s {
                Some(v) => v
                    .parse::<u64>()
                    .map_err(|_| crate::anyhow!("bad number '{v}' in sync spec '{spec}'")),
                None => Ok(default),
            }
        };
        let fnum = |s: Option<&str>, default: f64| -> crate::Result<f64> {
            match s {
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|_| crate::anyhow!("bad number '{v}' in sync spec '{spec}'")),
                None => Ok(default),
            }
        };
        let parsed = match head {
            "off" | "0" => None,
            "drift" => Some(SyncPolicy::Drift {
                max_staleness: num(parts.next(), 1024)?.max(1),
                delta: fnum(parts.next(), 0.002)?,
            }),
            "hybrid" => Some(SyncPolicy::Hybrid {
                interval: num(parts.next(), 256)?.max(1),
                delta: fnum(parts.next(), 0.002)?,
            }),
            n => match n.parse::<u64>() {
                Ok(0) => None,
                Ok(n) => Some(SyncPolicy::Count(n)),
                Err(_) => crate::bail!(
                    "bad sync spec '{spec}' (want N | off | drift[:staleness[:delta]] | \
                     hybrid[:interval[:delta]])"
                ),
            },
        };
        // a leftover segment means the user asked for a knob that does
        // not exist — fail fast instead of silently dropping it
        if let Some(extra) = parts.next() {
            crate::bail!("trailing segment '{extra}' in sync spec '{spec}'");
        }
        Ok(parsed)
    }

    /// ADWIN confidence, when the policy uses a gate.
    fn gate_delta(&self) -> Option<f64> {
        match *self {
            SyncPolicy::Count(_) => None,
            SyncPolicy::Drift { delta, .. } | SyncPolicy::Hybrid { delta, .. } => Some(delta),
        }
    }
}

/// Per-shard sync machinery: one slot per stateful pipeline stage.
struct SyncState {
    policy: SyncPolicy,
    stream: StreamId,
    /// Ship the adaptive sparse delta encoding (`false` = dense
    /// baseline, bench comparisons only).
    compress: bool,
    /// Stateful stage indices (slots are parallel to this).
    stages: Vec<usize>,
    gates: Vec<Option<Adwin>>,
    /// Instances since the slot's last emission.
    staleness: Vec<u64>,
    /// Gate fired since the slot's last emission.
    fired: Vec<bool>,
    /// Per-stage round id: the shard's emission sequence number, carried
    /// on every `StatsDelta` so the aggregator keeps rounds exact.
    rounds: Vec<u64>,
    /// Diagnostics: deltas emitted / gate detections.
    emissions: u64,
    gate_fires: u64,
}

impl SyncState {
    fn new(policy: SyncPolicy, stream: StreamId, pipeline: &Pipeline) -> Self {
        let stages = pipeline.stateful_stages();
        let gates = stages
            .iter()
            .map(|_| policy.gate_delta().map(Adwin::new))
            .collect();
        SyncState {
            policy,
            stream,
            compress: true,
            staleness: vec![0; stages.len()],
            fired: vec![false; stages.len()],
            rounds: vec![0; stages.len()],
            gates,
            stages,
            emissions: 0,
            gate_fires: 0,
        }
    }
}

/// One pipeline instance inside a topology: transforms every
/// `Event::Instance` and forwards survivors downstream, preserving ids
/// (so downstream key-groupings and the evaluator still line up).
///
/// With [`PipelineProcessor::with_sync`], the shard emits its stages'
/// pending state deltas (`Event::StatsDelta`, keyed by stage, stamped
/// with the shard id and a per-stage round id) per the configured
/// [`SyncPolicy`], and adopts the aggregator's merged broadcasts
/// (`Event::StatsGlobal`).
pub struct PipelineProcessor {
    pipeline: Pipeline,
    out: StreamId,
    sync: Option<SyncState>,
}

impl PipelineProcessor {
    /// Bind `pipeline` (unbound) to `input` and forward transformed
    /// instances on `out`.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId) -> Self {
        pipeline.bind(input);
        PipelineProcessor { pipeline, out, sync: None }
    }

    /// Enable delta-sync under `policy`, emitting deltas on
    /// `delta_stream`. Gated policies (`Drift`/`Hybrid`) also switch on
    /// per-instance drift-signal tracking in the pipeline's operators;
    /// `Count` leaves it off, so the fixed-interval hot path pays
    /// nothing for signals no gate will read.
    pub fn with_sync(mut self, policy: SyncPolicy, delta_stream: StreamId) -> Self {
        let policy = match policy {
            SyncPolicy::Count(n) => SyncPolicy::Count(n.max(1)),
            p => p,
        };
        if policy.gate_delta().is_some() {
            self.pipeline.track_drift_signal(true);
        }
        self.sync = Some(SyncState::new(policy, delta_stream, &self.pipeline));
        self
    }

    /// Bench baseline: ship dense deltas instead of the adaptive sparse
    /// encoding (measures what compression saves).
    pub fn with_dense_deltas(mut self) -> Self {
        if let Some(sync) = self.sync.as_mut() {
            sync.compress = false;
        }
        self
    }

    pub fn output_schema(&self) -> &Schema {
        self.pipeline.output_schema()
    }

    /// The bound pipeline (state inspection in tests/harnesses).
    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Deltas emitted so far (diagnostics/tests).
    pub fn sync_emissions(&self) -> u64 {
        self.sync.as_ref().map_or(0, |s| s.emissions)
    }

    /// Drift-gate detections so far (diagnostics/tests).
    pub fn gate_fires(&self) -> u64 {
        self.sync.as_ref().map_or(0, |s| s.gate_fires)
    }

    /// Ship slot `slot`'s pending increment on the delta stream.
    fn emit_slot(pipeline: &mut Pipeline, sync: &mut SyncState, slot: usize, ctx: &mut Ctx) {
        let stage = sync.stages[slot];
        if let Some(payload) = pipeline.stats_delta_stage(stage, sync.compress) {
            let round = sync.rounds[slot];
            sync.rounds[slot] += 1;
            sync.emissions += 1;
            ctx.emit(
                sync.stream,
                stage as u64,
                Event::StatsDelta {
                    stage: stage as u32,
                    shard: ctx.instance as u32,
                    round,
                    payload: std::sync::Arc::new(payload),
                },
            );
        }
        sync.staleness[slot] = 0;
        sync.fired[slot] = false;
    }

    /// Post-instance sync step: feed the gates and emit per policy.
    fn sync_tick(&mut self, ctx: &mut Ctx) {
        let Some(sync) = self.sync.as_mut() else { return };
        for slot in 0..sync.stages.len() {
            sync.staleness[slot] += 1;
            if let Some(gate) = sync.gates[slot].as_mut() {
                if let Some(sig) = self.pipeline.drift_signal(sync.stages[slot]) {
                    gate.add(sig);
                    if gate.detected() {
                        sync.fired[slot] = true;
                        sync.gate_fires += 1;
                    }
                }
            }
        }
        match sync.policy {
            SyncPolicy::Count(n) => {
                for slot in 0..sync.stages.len() {
                    if sync.staleness[slot] >= n {
                        Self::emit_slot(&mut self.pipeline, sync, slot, ctx);
                    }
                }
            }
            SyncPolicy::Drift { max_staleness, .. } => {
                for slot in 0..sync.stages.len() {
                    if sync.fired[slot] || sync.staleness[slot] >= max_staleness {
                        Self::emit_slot(&mut self.pipeline, sync, slot, ctx);
                    }
                }
            }
            SyncPolicy::Hybrid { interval, .. } => {
                let any = (0..sync.stages.len())
                    .any(|s| sync.fired[s] || sync.staleness[s] >= interval);
                if any {
                    for slot in 0..sync.stages.len() {
                        Self::emit_slot(&mut self.pipeline, sync, slot, ctx);
                    }
                }
            }
        }
    }
}

impl Processor for PipelineProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { id, inst } => {
                if let Some(out) = self.pipeline.transform(inst) {
                    ctx.emit(self.out, id, Event::Instance { id, inst: out });
                }
                self.sync_tick(ctx);
            }
            Event::StatsGlobal { stage, payload } => {
                self.pipeline.stats_apply(stage as usize, &payload);
            }
            _ => {}
        }
    }

    /// Flush un-shipped pending increments so short runs (and quiet
    /// drift-gated stages) still reach the aggregator. Reliable under
    /// the local engine (the flush drains before processors are
    /// collected); best-effort under the threaded engine, where the
    /// aggregator may already be shutting down.
    fn on_shutdown(&mut self, ctx: &mut Ctx) {
        if let Some(sync) = self.sync.as_mut() {
            for slot in 0..sync.stages.len() {
                if sync.staleness[slot] > 0 {
                    Self::emit_slot(&mut self.pipeline, sync, slot, ctx);
                }
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.pipeline.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "pipeline"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    /// Checkpoint frame layout (tags per `engine::checkpoint`):
    ///
    /// * `stage` (one section per stateful stage) — the stage's full
    ///   `stats_snapshot` vector; `restore` adopts it via `stats_apply`
    ///   on the freshly built (empty-pending) pipeline, which is exact.
    /// * `TAG_META_BASE` — `[emissions, gate_fires]`.
    /// * `TAG_META_BASE + 1 + slot` — `[staleness, round, fired]` per
    ///   sync slot, so a restored shard resumes its emission cadence and
    ///   round ids where the checkpoint cut them.
    ///
    /// ADWIN gate windows are *not* captured: a restored gate restarts
    /// empty, which can only delay (never corrupt) the next drift-gated
    /// emission — the max-staleness backstop still bounds it.
    fn snapshot(&self) -> Option<Vec<u8>> {
        use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
        let mut sections: Vec<(u32, Vec<f64>)> = self
            .pipeline
            .stateful_stages()
            .into_iter()
            .map(|stage| {
                (stage as u32, self.pipeline.stats_snapshot(stage).unwrap_or_default())
            })
            .collect();
        if let Some(sync) = self.sync.as_ref() {
            sections.push((TAG_META_BASE, vec![sync.emissions as f64, sync.gate_fires as f64]));
            for slot in 0..sync.stages.len() {
                sections.push((
                    TAG_META_BASE + 1 + slot as u32,
                    vec![
                        sync.staleness[slot] as f64,
                        sync.rounds[slot] as f64,
                        if sync.fired[slot] { 1.0 } else { 0.0 },
                    ],
                ));
            }
        }
        Some(encode_frame(&sections))
    }

    fn restore(&mut self, frame: &[u8]) -> crate::Result<()> {
        use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
        let sections = decode_frame(frame)?;
        for stage in self.pipeline.stateful_stages() {
            let Some(payload) = section(&sections, stage as u32) else {
                crate::bail!("pipeline restore: missing stage {stage} section");
            };
            self.pipeline.stats_apply(stage, payload);
        }
        if let Some(sync) = self.sync.as_mut() {
            if let Some(meta) = section(&sections, TAG_META_BASE) {
                crate::ensure!(meta.len() == 2, "pipeline restore: bad sync meta section");
                sync.emissions = meta[0] as u64;
                sync.gate_fires = meta[1] as u64;
            }
            for slot in 0..sync.stages.len() {
                if let Some(s) = section(&sections, TAG_META_BASE + 1 + slot as u32) {
                    crate::ensure!(s.len() == 3, "pipeline restore: bad sync slot section");
                    sync.staleness[slot] = s[0] as u64;
                    sync.rounds[slot] = s[1] as u64;
                    sync.fired[slot] = s[2] != 0.0;
                }
            }
        }
        Ok(())
    }
}

/// Which learner rides behind the pipeline shards: a sequential
/// classifier ([`crate::evaluation::prequential::ClassifierProcessor`])
/// or a sequential regressor such as AMRules
/// ([`crate::evaluation::prequential::RegressorProcessor`]).
pub enum LearnerHead {
    Classifier(Box<dyn Fn(&Schema) -> Box<dyn Classifier>>),
    Regressor(Box<dyn Fn(&Schema) -> Box<dyn Regressor>>),
}

/// Stream/processor handles of the prequential preprocessing topologies.
/// Stream ids are fixed by declaration order: 0 entry, 1 instances,
/// 2 prediction, then (sync only) 3 delta, 4 global.
#[derive(Clone, Copy, Debug)]
pub struct PreprocessHandles {
    pub entry: StreamId,
    /// pipeline → learner (transformed instances).
    pub instances: StreamId,
    /// learner → evaluator.
    pub prediction: StreamId,
    pub pipeline: ProcessorId,
    pub learner: ProcessorId,
    pub evaluator: ProcessorId,
    /// shards → aggregator state deltas (sync topologies only).
    pub delta: Option<StreamId>,
    /// aggregator → shards merged broadcasts (sync topologies only).
    pub global: Option<StreamId>,
    pub stats: Option<ProcessorId>,
}

/// Assemble `source → pipeline×p → learner → evaluator` with a
/// classifier head and no stats-sync (the PR-1 shape; see
/// [`build_prequential_topology_head`] for the full knobs).
pub fn build_prequential_topology(
    schema: &Schema,
    parallelism: usize,
    pipeline_factory: impl Fn(usize) -> Pipeline + Clone + 'static,
    classifier_factory: impl Fn(&Schema) -> Box<dyn Classifier> + 'static,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    build_prequential_topology_head(
        schema,
        parallelism,
        None,
        pipeline_factory,
        LearnerHead::Classifier(Box::new(classifier_factory)),
        evaluator,
    )
}

/// [`build_prequential_topology_sync`] with compressed deltas (the
/// production encoding).
pub fn build_prequential_topology_head(
    schema: &Schema,
    parallelism: usize,
    sync: Option<SyncPolicy>,
    pipeline_factory: impl Fn(usize) -> Pipeline + Clone + 'static,
    head: LearnerHead,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    build_prequential_topology_sync(
        schema,
        parallelism,
        sync,
        true,
        pipeline_factory,
        head,
        evaluator,
    )
}

/// Assemble the prequential preprocessing topology with a selectable
/// learner head and optional delta-sync:
///
/// ```text
/// source → pipeline×p → learner(classifier|regressor) → evaluator
///              ⇅ (SyncPolicy: Key-grouped deltas / All broadcasts)
///          stats-sync
/// ```
///
/// `pipeline_factory` is called once per pipeline shard (each owns
/// independent operator state) and once more for the aggregator's master
/// state container; `sync` selects the emission policy (`None` =
/// isolated shard statistics, the PR-1 behavior); `compress = false`
/// ships dense deltas (bench baseline).
pub fn build_prequential_topology_sync(
    schema: &Schema,
    parallelism: usize,
    sync: Option<SyncPolicy>,
    compress: bool,
    pipeline_factory: impl Fn(usize) -> Pipeline + Clone + 'static,
    head: LearnerHead,
    evaluator: impl Fn(usize) -> Box<dyn Processor> + 'static,
) -> (Topology, PreprocessHandles) {
    let mut b = TopologyBuilder::new("preprocess-prequential");
    let instances = StreamId(1);
    let prediction = StreamId(2);
    let delta = StreamId(3);
    let global = StreamId(4);

    // probe bind: the learner consumes the pipeline's output schema
    let mut probe = pipeline_factory(usize::MAX);
    let out_schema = probe.bind(schema);

    let in_schema = schema.clone();
    let pf = pipeline_factory.clone();
    let pipe = b.add_processor("pipeline", parallelism, move |i| {
        let p = PipelineProcessor::new(pf(i), &in_schema, instances);
        Box::new(match sync {
            Some(policy) => {
                let p = p.with_sync(policy, delta);
                if compress {
                    p
                } else {
                    p.with_dense_deltas()
                }
            }
            None => p,
        })
    });
    // the factory stays inside the closure so the topology is re-runnable
    // (engines re-invoke every processor factory per run)
    let learner = match head {
        LearnerHead::Classifier(f) => {
            let s = out_schema.clone();
            b.add_processor("learner", 1, move |_| {
                Box::new(crate::evaluation::prequential::ClassifierProcessor::new(
                    f(&s),
                    prediction,
                ))
            })
        }
        LearnerHead::Regressor(f) => {
            let s = out_schema.clone();
            b.add_processor("learner", 1, move |_| {
                Box::new(crate::evaluation::prequential::RegressorProcessor::new(
                    f(&s),
                    prediction,
                ))
            })
        }
    };
    let eval = b.add_processor("evaluator", 1, evaluator);
    let stats = sync.map(|_| {
        let s = schema.clone();
        let pf = pipeline_factory.clone();
        b.add_processor("stats-sync", 1, move |_| {
            // one sync round = one delta from each of the `parallelism`
            // shards; the aggregator broadcasts once per stage per round
            Box::new(StatsSyncProcessor::new(pf(usize::MAX), &s, global, parallelism))
        })
    });

    let entry = b.stream("instance", None, pipe, Grouping::Shuffle);
    let s_inst = b.stream("transformed", Some(pipe), learner, Grouping::Shuffle);
    let s_pred = b.stream("prediction", Some(learner), eval, Grouping::Shuffle);
    debug_assert_eq!(s_inst, instances);
    debug_assert_eq!(s_pred, prediction);
    let (s_delta, s_global) = match stats {
        Some(stats) => {
            let d = b.stream("stats-delta", Some(pipe), stats, Grouping::Key);
            let g = b.stream("stats-global", Some(stats), pipe, Grouping::All);
            debug_assert_eq!(d, delta);
            debug_assert_eq!(g, global);
            (Some(d), Some(g))
        }
        None => (None, None),
    };

    (
        b.build(),
        PreprocessHandles {
            entry,
            instances,
            prediction,
            pipeline: pipe,
            learner,
            evaluator: eval,
            delta: s_delta,
            global: s_global,
            stats,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::core::model::Classifier;
    use crate::engine::LocalEngine;
    use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use crate::preprocess::{Discretizer, StandardScaler};
    use crate::streams::waveform::WaveformGenerator;
    use crate::streams::StreamSource;
    use std::sync::Arc;

    fn ht_head() -> LearnerHead {
        LearnerHead::Classifier(Box::new(|s: &Schema| {
            Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())) as Box<dyn Classifier>
        }))
    }

    #[test]
    fn sync_policy_parse_forms_and_rejections() {
        assert_eq!(SyncPolicy::parse("off").unwrap(), None);
        assert_eq!(SyncPolicy::parse("0").unwrap(), None);
        assert_eq!(SyncPolicy::parse("256").unwrap(), Some(SyncPolicy::Count(256)));
        assert!(matches!(
            SyncPolicy::parse("drift").unwrap(),
            Some(SyncPolicy::Drift { max_staleness: 1024, .. })
        ));
        assert!(matches!(
            SyncPolicy::parse("drift:512:0.01").unwrap(),
            Some(SyncPolicy::Drift { max_staleness: 512, .. })
        ));
        assert!(matches!(
            SyncPolicy::parse("hybrid:128").unwrap(),
            Some(SyncPolicy::Hybrid { interval: 128, .. })
        ));
        assert!(SyncPolicy::parse("bogus").is_err());
        assert!(SyncPolicy::parse("drift:x").is_err());
        // trailing segments are knobs that don't exist: fail fast
        assert!(SyncPolicy::parse("drift:512:0.01:junk").is_err());
        assert!(SyncPolicy::parse("256:junk").is_err());
    }

    #[test]
    fn topology_runs_and_predicts() {
        let mut stream = WaveformGenerator::classification(21);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 1000);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_prequential_topology(
            &schema,
            2,
            |_| Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
            |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let source = (0..3000u64)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        assert_eq!(m.source_instances, 3000);
        // every instance produced exactly one transformed event and one
        // prediction (no filter in this pipeline)
        assert_eq!(m.streams[handles.instances.0].events, 3000);
        assert_eq!(m.streams[handles.prediction.0].events, 3000);
        // waveform has strong signal: must beat majority-class guessing
        assert!(sink.accuracy() > 0.5, "accuracy={}", sink.accuracy());
    }

    #[test]
    fn sync_topology_emits_deltas_and_broadcasts() {
        let mut stream = WaveformGenerator::classification(5);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 1000);
        let sink2 = Arc::clone(&sink);
        let p = 4usize;
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            Some(SyncPolicy::Count(64)),
            |_| Pipeline::new().then(StandardScaler::new()),
            ht_head(),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let n = 2048u64;
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        assert_eq!(m.source_instances, n);
        assert_eq!(m.streams[handles.prediction.0].events, n);
        // each shard sees n/p instances and emits a delta every 64:
        // (n/p/64) emissions per shard, one stateful stage
        let expected_deltas = (n as usize / p / 64 * p) as u64;
        assert_eq!(m.streams[handles.delta.unwrap().0].events, expected_deltas);
        // coalesced broadcasts: ONE snapshot per stage per round of p
        // deltas, delivered to all p shards — so total global deliveries
        // equal total deltas (deltas/p rounds × p destinations), not
        // deltas × p as the pre-coalescing protocol paid
        assert_eq!(m.streams[handles.global.unwrap().0].events, expected_deltas);
    }

    /// Shutdown stragglers: with `n` NOT divisible by interval × p, some
    /// shards flush a final pending delta from `on_shutdown`; the local
    /// engine drains those into the aggregator BEFORE the aggregator's
    /// own `on_shutdown`, which then broadcasts the partial round once.
    #[test]
    fn shutdown_flush_broadcasts_partial_round() {
        let mut stream = WaveformGenerator::classification(5);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 10_000);
        let sink2 = Arc::clone(&sink);
        let p = 4usize;
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            Some(SyncPolicy::Count(64)),
            |_| Pipeline::new().then(StandardScaler::new()),
            ht_head(),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        // 2050 = 4 × 512 + 2: shards 0/1 see 513 instances (8 emissions +
        // 1 shutdown-flush delta), shards 2/3 see 512 (8 emissions, no
        // flush) — one stateful stage
        let n = 2050u64;
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let deltas = m.streams[handles.delta.unwrap().0].events;
        let globals = m.streams[handles.global.unwrap().0].events;
        assert_eq!(deltas, 34, "8 regular emissions × 4 shards + 2 shutdown flushes");
        // 8 complete rounds (32 deliveries) + ONE partial-round flush
        // broadcast at aggregator shutdown (4 deliveries)
        assert_eq!(globals, 36, "partial round must be flushed exactly once");
    }

    /// Drift policy on a stationary stream: the gate stays silent, so
    /// only the max-staleness backstop (and the shutdown flush) emits —
    /// far fewer deltas than a tight fixed count would pay.
    #[test]
    fn drift_policy_backstop_bounds_staleness() {
        let mut stream = WaveformGenerator::classification(11);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, 10_000);
        let sink2 = Arc::clone(&sink);
        let p = 2usize;
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            Some(SyncPolicy::Drift { delta: 0.002, max_staleness: 512 }),
            |_| Pipeline::new().then(StandardScaler::new()),
            ht_head(),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let n = 4096u64;
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let deltas = m.streams[handles.delta.unwrap().0].events;
        // backstop floor: each shard must emit at least every 512
        // instances (2048 seen per shard → ≥ 4 each), and gate fires can
        // only add to that; a Count(64) policy would emit 64 total
        assert!(deltas >= 8, "backstop did not fire: {deltas} deltas");
        assert!(
            deltas < 64,
            "drift policy emitted as much as a tight fixed count: {deltas}"
        );
        assert!(m.streams[handles.global.unwrap().0].events > 0);
    }
}
