//! Streaming preprocessing & feature pipelines — the missing layer DPASF
//! (García-Gil et al. 2018) identifies in distributed stream-ML stacks.
//!
//! A [`Transform`] is a schema-in → schema-out operator over instances;
//! [`Pipeline`] chains transforms and rewrites the schema end-to-end. Every
//! pipeline is usable two ways:
//!
//! * **standalone** — [`TransformedStream`] wraps any
//!   [`crate::streams::StreamSource`], so the sequential prequential
//!   drivers (and `samoa run --pipeline ...`) see a preprocessed stream;
//! * **as a topology node** — [`processor::PipelineProcessor`] runs the
//!   same pipeline as a parallelizable [`crate::topology::Processor`]
//!   under the local, threaded and simtime engines, composing with VHT,
//!   the AMRules ensembles and CluStream.
//!
//! Operators (all bounded-memory, one pass, following the sketch/summary
//! structures surveyed by Benczúr et al. 2018):
//!
//! | operator | state | effect |
//! |---|---|---|
//! | [`scalers::StandardScaler`] | running moments (Welford) | z-score numeric attributes |
//! | [`scalers::MinMaxScaler`] | running min/max | map numeric attributes to `[0, 1]` |
//! | [`discretize::Discretizer`] | PiD-style layer-1 histogram | equal-frequency bins → categorical |
//! | [`hasher::FeatureHasher`] | none | signed feature hashing, sparse→dense projection |
//! | [`topk::TopKFilter`] | Misra-Gries + CountMin | keep only heavy-hitter attributes |
//! | [`sketch`] | CountMin / Misra-Gries | the summaries backing the above |
//!
//! Every stateful operator's statistics are **mergeable**
//! ([`merge::MergeableState`]): under `p > 1` pipeline shards the
//! delta-sync protocol ([`sync::StatsSyncProcessor`]) periodically ships
//! each shard's pending state increment to an aggregator and broadcasts
//! the merged global state back, so all shards converge to shared
//! statistics — the same instance normalizes identically at `p = 1` and
//! `p = 64`. See `README.md` in this directory for the protocol.

pub mod merge;
pub mod wire;
pub mod sketch;
pub mod scalers;
pub mod discretize;
pub mod hasher;
pub mod topk;
pub mod pipeline;
pub mod processor;
pub mod sync;

pub use discretize::Discretizer;
pub use hasher::FeatureHasher;
pub use merge::MergeableState;
pub use pipeline::Pipeline;
pub use processor::{PipelineProcessor, SyncPolicy};
pub use scalers::{MinMaxScaler, StandardScaler};
pub use sketch::{CountMinSketch, MisraGries};
pub use sync::StatsSyncProcessor;
pub use topk::TopKFilter;

use crate::core::{Instance, Schema};
use crate::streams::StreamSource;

/// A streaming instance transform: bound to an input schema once, then
/// applied to every instance in arrival order. Stateful operators learn
/// *online* (update-then-transform), so no separate fit phase exists —
/// the first instances are transformed with whatever statistics have
/// accumulated so far, exactly like the models consuming them.
pub trait Transform: Send {
    /// Bind to `input`, allocate per-attribute state, and return the
    /// schema of the transformed stream. Called exactly once, before the
    /// first [`Transform::transform`].
    fn bind(&mut self, input: &Schema) -> Schema;

    /// Transform one instance. `None` drops the instance (filters).
    fn transform(&mut self, inst: Instance) -> Option<Instance>;

    fn name(&self) -> &'static str {
        "transform"
    }

    /// Estimated bytes of operator state (sketches, moments, cut points).
    fn mem_bytes(&self) -> usize {
        0
    }

    // --- delta-sync hooks (see `merge` / `sync`) -----------------------
    //
    // Stateless transforms keep the defaults (no sync traffic). Stateful
    // ones implement all four in terms of their `MergeableState`:
    // a shard ships `stats_delta` (the pending increment, then resets
    // it), the aggregator folds it in with `stats_merge` and broadcasts
    // `stats_snapshot`, and shards adopt it with `stats_apply` (global
    // merged with the still-pending local increment).

    /// Take the pending state increment accumulated since the last call,
    /// serialized as a flat payload, and reset it. `None` = stateless.
    /// Implementations ship the smaller of the dense and the sparse
    /// (changed-attributes-only, see [`wire`]) encoding, so short sync
    /// windows over wide schemas pay for what changed, not for the
    /// schema width.
    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        None
    }

    /// Like [`Transform::stats_delta`] but always the dense encoding —
    /// the bench baseline for measuring what compression saves
    /// ([`PipelineProcessor`]'s `with_dense_deltas`).
    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        self.stats_delta()
    }

    /// Aggregator side: fold a shard's delta payload into this
    /// operator's state (interpreted as the global master).
    fn stats_merge(&mut self, _payload: &[f64]) {}

    /// Serialize the full current state (the aggregator's broadcast
    /// snapshot; on shards, a diagnostic view). `None` = stateless.
    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        None
    }

    /// Shard side: replace the transform-side state with the broadcast
    /// global snapshot, keeping the not-yet-shipped pending increment.
    fn stats_apply(&mut self, _payload: &[f64]) {}

    /// Enable (or disable) drift-signal tracking. Off by default so the
    /// transform hot path pays nothing for the signal when no gate will
    /// ever read it (sync off, or `SyncPolicy::Count`);
    /// [`PipelineProcessor`] turns it on for the gated policies.
    fn track_drift_signal(&mut self, _on: bool) {}

    /// **Take** the bounded `[0, 1]` drift signal produced by the last
    /// [`Transform::transform`] call (clearing it), or `None` for
    /// stateless operators, when tracking is off, and when the last
    /// instance contributed no observation — so a gate is fed exactly
    /// one sample per real observation, never a stale repeat. Under
    /// `SyncPolicy::Drift` / `Hybrid` each pipeline shard feeds this
    /// into a per-stage ADWIN gate and emits a delta when the gate
    /// fires — so sync traffic tracks concept drift instead of a fixed
    /// count (the DPASF adaptive-statistics idea). The signal should
    /// sit near a stable level while the operator's statistics fit the
    /// stream and move when they stop fitting (e.g. the scaler's mean
    /// |z|).
    fn drift_signal(&mut self) -> Option<f64> {
        None
    }
}

/// Standalone adapter: any stream source, preprocessed. Filters (transforms
/// returning `None`) are skipped transparently, so downstream consumers
/// only ever see surviving instances.
pub struct TransformedStream<S: StreamSource> {
    source: S,
    pipeline: Pipeline,
    schema: Schema,
}

impl<S: StreamSource> TransformedStream<S> {
    /// Wrap `source`, binding `pipeline` to its schema.
    pub fn new(source: S, mut pipeline: Pipeline) -> Self {
        let schema = pipeline.bind(source.schema());
        TransformedStream { source, pipeline, schema }
    }

    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    pub fn into_inner(self) -> S {
        self.source
    }
}

impl<S: StreamSource> StreamSource for TransformedStream<S> {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        loop {
            let inst = self.source.next_instance()?;
            if let Some(out) = self.pipeline.transform(inst) {
                return Some(out);
            }
        }
    }

    fn len_hint(&self) -> Option<u64> {
        // Filters may drop instances, so the inner hint is an upper bound;
        // still useful for harness sizing.
        self.source.len_hint()
    }
}

/// Parse a comma-separated pipeline spec into a [`Pipeline`]:
/// `hash:64,scale,minmax,discretize:8,topk:32`. Numeric suffixes are
/// optional and fall back to per-operator defaults.
pub fn parse_pipeline(spec: &str) -> crate::Result<Pipeline> {
    let mut pipeline = Pipeline::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let (op, arg) = match tok.split_once(':') {
            Some((op, arg)) => (op, Some(arg)),
            None => (tok, None),
        };
        let num = |default: usize| -> crate::Result<usize> {
            match arg {
                Some(a) => a
                    .parse::<usize>()
                    .map_err(|_| crate::anyhow!("bad argument '{a}' in pipeline token '{tok}'")),
                None => Ok(default),
            }
        };
        // range checks here so a bad CLI spec reports a clean error
        // instead of tripping the constructors' asserts
        pipeline = match op {
            "scale" | "standard" => pipeline.then(StandardScaler::new()),
            "minmax" => pipeline.then(MinMaxScaler::new()),
            "discretize" | "bins" => {
                let k = num(8)?;
                if k < 2 {
                    crate::bail!("discretize needs at least 2 bins (got {k})");
                }
                pipeline.then(Discretizer::new(k as u32))
            }
            "hash" => {
                let d = num(64)?;
                if d < 1 {
                    crate::bail!("hash needs a dimension >= 1");
                }
                pipeline.then(FeatureHasher::new(d as u32))
            }
            "topk" => {
                let k = num(32)?;
                if k < 1 {
                    crate::bail!("topk needs k >= 1");
                }
                pipeline.then(TopKFilter::new(k))
            }
            other => crate::bail!(
                "unknown pipeline operator '{other}' (known: hash:D scale minmax discretize:K topk:K)"
            ),
        };
    }
    Ok(pipeline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Label;
    use crate::streams::waveform::WaveformGenerator;

    #[test]
    fn parse_builds_all_operators() {
        let p = parse_pipeline("hash:16,scale,minmax,discretize:4,topk:8").unwrap();
        assert_eq!(p.len(), 5);
        assert!(parse_pipeline("bogus").is_err());
        assert!(parse_pipeline("hash:x").is_err());
    }

    #[test]
    fn transformed_stream_rewrites_schema_and_flows() {
        let src = WaveformGenerator::classification(7);
        let mut ts = TransformedStream::new(src, parse_pipeline("hash:16,scale").unwrap());
        assert_eq!(ts.schema().n_attributes(), 16);
        assert_eq!(ts.schema().n_classes(), 3);
        for _ in 0..50 {
            let i = ts.next_instance().unwrap();
            assert_eq!(i.n_attributes(), 16);
            assert!(matches!(i.label, Label::Class(_)));
        }
    }

    #[test]
    fn empty_pipeline_is_identity() {
        let src = WaveformGenerator::new(3);
        let mut raw = WaveformGenerator::new(3);
        let mut ts = TransformedStream::new(src, Pipeline::new());
        for _ in 0..20 {
            assert_eq!(ts.next_instance().unwrap().values(), raw.next_instance().unwrap().values());
        }
    }
}
