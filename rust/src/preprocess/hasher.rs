//! Signed feature hashing (Weinberger et al.): project any attribute space
//! — in particular the sparse bag-of-words of the tweet generator — onto a
//! fixed `dim`-dimensional dense space. Stateless, so it parallelizes
//! perfectly; collisions are unbiased thanks to the sign hash. Reuses the
//! crate's [`crate::common::fxhash`] hasher.

use std::hash::Hasher;

use crate::common::fxhash::FxHasher;
use crate::core::instance::{Label, Values};
use crate::core::{AttributeKind, Instance, Schema};

use super::Transform;

/// Hash attribute index `j` (with `seed`) to 64 bits: low bits pick the
/// bucket, bit 63 the sign. The FxHash word mix alone leaves its low bits
/// depending only on `(j ^ seed) mod 2^b`, which would make attributes at
/// stride `dim` collide for every seed — finalize with the SplitMix
/// avalanche so bucket bits see the whole word.
#[inline]
fn hash_attr(j: u64, seed: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write_u64(j ^ seed);
    crate::topology::stream::hash64(h.finish())
}

/// Sparse→dense signed feature hasher.
pub struct FeatureHasher {
    dim: u32,
    seed: u64,
    /// Fold collision sign (+/-) instead of plain accumulation.
    signed: bool,
}

impl FeatureHasher {
    pub fn new(dim: u32) -> Self {
        Self::with_seed(dim, 0x5EED_F00D)
    }

    pub fn with_seed(dim: u32, seed: u64) -> Self {
        assert!(dim >= 1, "hash dimension must be >= 1");
        FeatureHasher { dim, seed, signed: true }
    }

    /// Disable the sign hash (plain count-style accumulation).
    pub fn unsigned(mut self) -> Self {
        self.signed = false;
        self
    }

    /// (bucket, sign) for input attribute `j`.
    #[inline]
    fn slot(&self, j: usize) -> (usize, f32) {
        let h = hash_attr(j as u64, self.seed);
        let bucket = (h % self.dim as u64) as usize;
        let sign = if self.signed && (h >> 63) == 1 { -1.0 } else { 1.0 };
        (bucket, sign)
    }
}

impl Transform for FeatureHasher {
    fn bind(&mut self, input: &Schema) -> Schema {
        input.with_attributes(
            &format!("{}|hash{}", input.name, self.dim),
            vec![AttributeKind::Numeric; self.dim as usize],
        )
    }

    fn transform(&mut self, inst: Instance) -> Option<Instance> {
        let mut out = vec![0.0f32; self.dim as usize];
        match inst.values() {
            Values::Dense(v) => {
                for (j, &x) in v.iter().enumerate() {
                    if x != 0.0 {
                        let (b, s) = self.slot(j);
                        out[b] += s * x;
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, &x) in indices.iter().zip(values.iter()) {
                    if x != 0.0 {
                        let (b, s) = self.slot(j as usize);
                        out[b] += s * x;
                    }
                }
            }
        }
        let mut hashed = Instance::dense(out, Label::None);
        hashed.label = inst.label;
        hashed.weight = inst.weight;
        Some(hashed)
    }

    fn name(&self) -> &'static str {
        "feature-hasher"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_label_preserving() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut h = FeatureHasher::new(16);
        h.bind(&schema);
        let i = Instance::sparse(vec![3, 40, 77], vec![1.0, 2.0, 3.0], 100, Label::Class(1));
        let a = h.transform(i.clone()).unwrap();
        let b = h.transform(i).unwrap();
        assert_eq!(a.values(), b.values());
        assert_eq!(a.label, Label::Class(1));
        assert_eq!(a.n_attributes(), 16);
    }

    #[test]
    fn total_mass_preserved_up_to_sign() {
        let schema = Schema::classification("t", Schema::all_numeric(50), 2);
        let mut h = FeatureHasher::new(64).unsigned();
        h.bind(&schema);
        let i = Instance::dense(vec![1.0; 50], Label::None);
        let out = h.transform(i).unwrap();
        let total: f32 = (0..64).map(|j| out.value(j)).sum();
        assert_eq!(total, 50.0); // unsigned hashing only moves mass
    }

    #[test]
    fn different_seeds_differ() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut h1 = FeatureHasher::with_seed(32, 1);
        let mut h2 = FeatureHasher::with_seed(32, 2);
        h1.bind(&schema);
        h2.bind(&schema);
        let i = Instance::sparse(vec![5, 6, 7], vec![1.0, 1.0, 1.0], 100, Label::None);
        assert_ne!(h1.transform(i.clone()).unwrap().values(), h2.transform(i).unwrap().values());
    }

    #[test]
    fn schema_rewritten_to_dim() {
        let schema = Schema::classification("tweets", Schema::all_numeric(10_000), 2);
        let mut h = FeatureHasher::new(256);
        let out = h.bind(&schema);
        assert_eq!(out.n_attributes(), 256);
        assert_eq!(out.n_classes(), 2);
    }
}
