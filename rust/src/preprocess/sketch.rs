//! Frequency sketches: Count-Min (Cormode & Muthukrishnan) and Misra-Gries
//! heavy hitters — the bounded-memory summaries backing
//! [`super::topk::TopKFilter`] and available to any processor that needs
//! approximate stream frequencies.
//!
//! Guarantees (N = total weight added):
//! * CountMin: `estimate(x) >= count(x)` always, and
//!   `estimate(x) <= count(x) + 2N/width` with probability `>= 1 - 2^-depth`
//!   per query (pairwise-independent row hashes via seeded SplitMix).
//! * Misra-Gries with `k` counters: `count(x) - N/k <= estimate(x) <=
//!   count(x)`, and every item with `count(x) > N/k` is present.

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;
use crate::topology::stream::hash64;

use super::merge::MergeableState;

/// Count-Min sketch over `u64` item ids with `u64` counts.
#[derive(Clone, Debug)]
pub struct CountMinSketch {
    width: usize,
    depth: usize,
    /// Row-major `depth × width` counter matrix.
    counters: Vec<u64>,
    /// Per-row hash seeds, fixed at construction (hot path: one hash64
    /// per row per operation).
    row_seeds: Vec<u64>,
    total: u64,
}

impl CountMinSketch {
    pub fn new(width: usize, depth: usize) -> Self {
        assert!(width >= 1 && depth >= 1, "CountMin needs width, depth >= 1");
        let row_seeds =
            (0..depth).map(|row| hash64(row as u64 ^ 0xA5A5_A5A5_5A5A_5A5A)).collect();
        CountMinSketch { width, depth, counters: vec![0; width * depth], row_seeds, total: 0 }
    }

    /// Size the sketch for additive error `<= epsilon * N` (with the 2N/w
    /// Markov bound) at failure probability `<= delta` per query.
    pub fn with_error(epsilon: f64, delta: f64) -> Self {
        let width = (2.0 / epsilon).ceil().max(1.0) as usize;
        let depth = (1.0 / delta).log2().ceil().max(1.0) as usize;
        Self::new(width, depth)
    }

    /// Per-row cell index: each row hashes with its own SplitMix-derived
    /// seed, giving (empirically) pairwise-independent rows.
    #[inline]
    fn cell(&self, row: usize, item: u64) -> usize {
        (hash64(item ^ self.row_seeds[row]) % self.width as u64) as usize
    }

    #[inline]
    pub fn add(&mut self, item: u64, count: u64) {
        self.total += count;
        for row in 0..self.depth {
            let c = self.cell(row, item);
            self.counters[row * self.width + c] += count;
        }
    }

    /// Point estimate: min over rows (overestimate-only).
    #[inline]
    pub fn estimate(&self, item: u64) -> u64 {
        let mut est = u64::MAX;
        for row in 0..self.depth {
            let c = self.cell(row, item);
            est = est.min(self.counters[row * self.width + c]);
        }
        est
    }

    /// Total weight added so far (the N of the error bound).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Sparse encoding of only the non-zero counter cells:
    /// `[NaN, width, depth, total, m, (cell, count) × m]` — a short sync
    /// window touches at most `interval × depth` cells of the
    /// `width × depth` matrix, so pending increments compress hard (see
    /// [`super::wire`]).
    pub fn sparse_delta(&self) -> Vec<f64> {
        let cells: Vec<usize> =
            (0..self.counters.len()).filter(|&c| self.counters[c] != 0).collect();
        let mut out = Vec::with_capacity(5 + 2 * cells.len());
        out.push(f64::NAN);
        out.push(self.width as f64);
        out.push(self.depth as f64);
        out.push(self.total as f64);
        out.push(cells.len() as f64);
        for c in cells {
            out.push(c as f64);
            out.push(self.counters[c] as f64);
        }
        out
    }
}

impl MergeableState for CountMinSketch {
    /// Pointwise counter addition — exact, commutative and associative
    /// (both sketches must share width/depth; the row seeds are derived
    /// deterministically from the row index, so equal depth ⇒ equal
    /// hashes).
    fn merge(&mut self, other: &Self) {
        if other.total == 0 {
            return;
        }
        if self.width != other.width || self.depth != other.depth {
            debug_assert!(false, "CountMin shape mismatch in merge");
            return;
        }
        for (c, o) in self.counters.iter_mut().zip(&other.counters) {
            *c += o;
        }
        self.total += other.total;
    }

    /// `[width, depth, total, counters...]`. Counts are carried as f64 —
    /// exact below 2^53, far beyond any bounded sync interval.
    fn delta(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(3 + self.counters.len());
        out.push(self.width as f64);
        out.push(self.depth as f64);
        out.push(self.total as f64);
        out.extend(self.counters.iter().map(|&c| c as f64));
        out
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        if super::wire::is_sparse(payload) {
            if payload.len() < 5 {
                return;
            }
            let (width, depth) = (payload[1] as usize, payload[2] as usize);
            let m = payload[4] as usize;
            if width < 1 || depth < 1 || payload.len() != 5 + 2 * m {
                return;
            }
            *self = CountMinSketch::new(width, depth);
            self.total = payload[3] as u64;
            for pair in payload[5..].chunks_exact(2) {
                let c = pair[0] as usize;
                if c < self.counters.len() {
                    self.counters[c] = pair[1] as u64;
                }
            }
            return;
        }
        if payload.len() < 3 {
            return;
        }
        let (width, depth) = (payload[0] as usize, payload[1] as usize);
        if width < 1 || depth < 1 || payload.len() != 3 + width * depth {
            return;
        }
        *self = CountMinSketch::new(width, depth);
        self.total = payload[2] as u64;
        for (c, &p) in self.counters.iter_mut().zip(&payload[3..]) {
            *c = p as u64;
        }
    }

    fn reset(&mut self) {
        self.counters.fill(0);
        self.total = 0;
    }
}

impl MemSize for CountMinSketch {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + vec_flat_bytes(&self.counters)
            + vec_flat_bytes(&self.row_seeds)
    }
}

/// Misra-Gries heavy-hitter summary with at most `k` counters.
#[derive(Clone, Debug)]
pub struct MisraGries {
    k: usize,
    counters: crate::common::fxhash::FxHashMap<u64, u64>,
    total: u64,
}

impl MisraGries {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "MisraGries needs k >= 1");
        MisraGries { k, counters: Default::default(), total: 0 }
    }

    /// Add one occurrence of `item`. Amortized O(1): the O(k)
    /// decrement-all fires at most once per k additions.
    pub fn add(&mut self, item: u64) {
        self.total += 1;
        if let Some(c) = self.counters.get_mut(&item) {
            *c += 1;
        } else if self.counters.len() < self.k {
            self.counters.insert(item, 1);
        } else {
            // Decrement every counter; evict the ones that reach zero.
            self.counters.retain(|_, c| {
                *c -= 1;
                *c > 0
            });
        }
    }

    /// Lower-bound estimate (0 when absent): `count(x) - N/k <= estimate`.
    pub fn estimate(&self, item: u64) -> u64 {
        self.counters.get(&item).copied().unwrap_or(0)
    }

    pub fn contains(&self, item: u64) -> bool {
        self.counters.contains_key(&item)
    }

    /// Tracked (item, estimate) pairs, heaviest first (ties by item id for
    /// determinism across runs).
    pub fn heavy_hitters(&self) -> Vec<(u64, u64)> {
        let mut hh: Vec<(u64, u64)> = self.counters.iter().map(|(&i, &c)| (i, c)).collect();
        hh.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        hh
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

impl MergeableState for MisraGries {
    /// The Agarwal et al. mergeable-summary rule: add counters pointwise,
    /// then if more than `k` survive, subtract the (k+1)-th largest count
    /// from every counter and drop the non-positive ones. Commutative
    /// exactly; associative within the composed `N/k` estimate bound
    /// (the classic MG guarantee is preserved under arbitrary merge
    /// trees, but individual counter values may differ by grouping).
    fn merge(&mut self, other: &Self) {
        if other.total == 0 {
            return;
        }
        self.total += other.total;
        for (&item, &c) in other.counters.iter() {
            *self.counters.entry(item).or_insert(0) += c;
        }
        if self.counters.len() > self.k {
            let mut counts: Vec<u64> = self.counters.values().copied().collect();
            counts.sort_unstable_by(|a, b| b.cmp(a));
            let thr = counts[self.k];
            self.counters.retain(|_, c| {
                if *c > thr {
                    *c -= thr;
                    true
                } else {
                    false
                }
            });
        }
    }

    /// `[k, total, m, (item, count) * m]`, pairs sorted by item id so
    /// equal states serialize identically.
    fn delta(&self) -> Vec<f64> {
        let mut pairs: Vec<(u64, u64)> = self.counters.iter().map(|(&i, &c)| (i, c)).collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let mut out = Vec::with_capacity(3 + 2 * pairs.len());
        out.push(self.k as f64);
        out.push(self.total as f64);
        out.push(pairs.len() as f64);
        for (i, c) in pairs {
            out.push(i as f64);
            out.push(c as f64);
        }
        out
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        if payload.len() < 3 {
            return;
        }
        let m = payload[2] as usize;
        if payload.len() != 3 + 2 * m {
            return;
        }
        // keep our own k (bind-time config); adopt the payload's counters
        self.counters.clear();
        self.total = payload[1] as u64;
        for pair in payload[3..].chunks_exact(2) {
            self.counters.insert(pair[0] as u64, pair[1] as u64);
        }
    }

    fn reset(&mut self) {
        self.counters.clear();
        self.total = 0;
    }
}

impl MemSize for MisraGries {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.counters.capacity() * (8 + 8 + 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn countmin_never_underestimates() {
        let mut cm = CountMinSketch::new(32, 4);
        for i in 0..1000u64 {
            cm.add(i % 50, 1);
        }
        for i in 0..50u64 {
            assert!(cm.estimate(i) >= 20, "item {i} underestimated: {}", cm.estimate(i));
        }
        assert_eq!(cm.total(), 1000);
    }

    #[test]
    fn countmin_exact_when_wide() {
        // width >> distinct items, collisions vanishingly unlikely to hit
        // all rows: estimates are exact here.
        let mut cm = CountMinSketch::new(4096, 5);
        for i in 0..64u64 {
            for _ in 0..(i + 1) {
                cm.add(i, 1);
            }
        }
        for i in 0..64u64 {
            assert_eq!(cm.estimate(i), i + 1);
        }
    }

    #[test]
    fn with_error_sizes_reasonably() {
        let cm = CountMinSketch::with_error(0.01, 0.01);
        assert!(cm.width() >= 200);
        assert!(cm.depth() >= 7);
    }

    #[test]
    fn misra_gries_tracks_majority() {
        let mut mg = MisraGries::new(4);
        // item 7 has frequency 1/2 > N/4: guaranteed present
        for i in 0..10_000u64 {
            mg.add(if i % 2 == 0 { 7 } else { 100 + (i % 97) });
        }
        assert!(mg.contains(7));
        assert_eq!(mg.heavy_hitters()[0].0, 7);
        assert!(mg.estimate(7) <= 5000);
        assert!(mg.estimate(7) + mg.total() / 4 >= 5000);
    }

    #[test]
    fn misra_gries_bounded_state() {
        let mut mg = MisraGries::new(8);
        for i in 0..100_000u64 {
            mg.add(i); // all-distinct adversarial stream
        }
        assert!(mg.heavy_hitters().len() <= 8);
    }

    #[test]
    fn countmin_merge_equals_union_stream() {
        let (mut a, mut b, mut all) =
            (CountMinSketch::new(64, 4), CountMinSketch::new(64, 4), CountMinSketch::new(64, 4));
        for i in 0..2000u64 {
            let x = i % 37;
            if i % 2 == 0 {
                a.add(x, 1);
            } else {
                b.add(x, 1);
            }
            all.add(x, 1);
        }
        a.merge(&b);
        assert_eq!(a.total(), all.total());
        for x in 0..37u64 {
            assert_eq!(a.estimate(x), all.estimate(x));
        }
        // delta round trip
        let mut c = CountMinSketch::new(1, 1);
        c.apply_delta(&a.delta());
        assert_eq!(c.delta(), a.delta());
    }

    /// The sparse form round-trips to the same sketch state and is
    /// smaller whenever few cells are occupied.
    #[test]
    fn countmin_sparse_delta_round_trips() {
        let mut cm = CountMinSketch::new(1024, 4);
        for i in 0..10u64 {
            cm.add(i, 2);
        }
        let sparse = cm.sparse_delta();
        assert!(sparse.len() < cm.delta().len());
        let mut back = CountMinSketch::new(1, 1);
        back.apply_delta(&sparse);
        assert_eq!(back.delta(), cm.delta());
        assert_eq!(back.total(), cm.total());
    }

    #[test]
    fn misra_gries_merge_keeps_heavy_hitters_bounded() {
        let (mut a, mut b) = (MisraGries::new(4), MisraGries::new(4));
        for i in 0..6000u64 {
            // item 3 is heavy in both halves
            let x = if i % 2 == 0 { 3 } else { 10 + i % 23 };
            if i < 3000 {
                a.add(x);
            } else {
                b.add(x);
            }
        }
        let n = a.total() + b.total();
        a.merge(&b);
        assert_eq!(a.total(), n);
        assert!(a.heavy_hitters().len() <= 4);
        assert!(a.contains(3), "majority item must survive the merge");
        let est = a.estimate(3);
        assert!(est <= 3000 && est + n / 4 >= 3000, "est={est}");
    }
}
