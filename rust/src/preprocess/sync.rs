//! Delta-sync aggregator — the topology stage that makes `p > 1`
//! pipeline shards converge to shared statistics.
//!
//! Protocol (one aggregator instance, `p` [`super::PipelineProcessor`]
//! shards):
//!
//! 1. every `interval` locally-processed instances, a shard takes each
//!    stateful stage's *pending increment* (`Transform::stats_delta`, the
//!    state accumulated since the shard's last emission) and emits it as
//!    an `Event::StatsDelta` on a **`Key`-grouped** stream (keyed by
//!    stage index);
//! 2. the aggregator folds the increment into its master state
//!    (`Transform::stats_merge`) — each update is merged **exactly
//!    once**, so the master equals the single-shard state up to merge
//!    reordering (commutativity/associativity, see
//!    [`super::merge::MergeableState`]);
//! 3. **once per stage per sync round** — i.e. after `round_size`
//!    (normally = the shard count `p`) deltas for that stage have been
//!    merged, not after every delta — the aggregator broadcasts the
//!    merged snapshot (`Transform::stats_snapshot`) as an
//!    `Event::StatsGlobal` on an **`All`-grouped** stream. This coalescing
//!    turns the previous `O(p²)` full-state deliveries per round into
//!    `O(p)`: broadcast *count* is independent of how many deltas arrive
//!    within a round. Any partial round still pending at shutdown is
//!    flushed by `on_shutdown` — exact on the local engine, whose
//!    shutdown sequence drains each processor's shutdown emissions
//!    before the next processor's `on_shutdown` runs, so shard
//!    straggler deltas reach the aggregator first (best-effort on the
//!    threaded engine, where shards and aggregator shut down
//!    concurrently);
//! 4. each shard replaces its transform-side view with the broadcast
//!    state merged with its own still-pending increment
//!    (`Transform::stats_apply`) — nothing is lost or double-counted.
//!
//! Both event kinds are control-plane (`Event::is_control`), so the
//! feedback loop can never deadlock against data-path backpressure in
//! the threaded engine — the same reasoning as the VHT `compute`/
//! `local-result` loop.

use std::sync::Arc;

use crate::core::Schema;
use crate::topology::{Ctx, Event, Processor, StreamId};

use super::pipeline::Pipeline;
use super::Transform;

/// Aggregator node: merges shard deltas into a master pipeline state and
/// broadcasts merged snapshots, one per stage per sync round.
pub struct StatsSyncProcessor {
    /// Master state container — a pipeline built by the same factory as
    /// the shards (never sees instances, only merged deltas).
    master: Pipeline,
    /// Broadcast (`All`-grouped) stream back to the shards.
    out: StreamId,
    /// Deltas per stage that complete a sync round (= shard count). 1
    /// reproduces the broadcast-per-delta behavior.
    round_size: usize,
    /// Deltas merged since the last broadcast, per stage.
    pending: Vec<usize>,
    /// Deltas merged so far (diagnostics).
    deltas_merged: u64,
    /// Snapshots broadcast so far (diagnostics; the sync-overhead bench
    /// asserts this is deltas/round_size, not deltas).
    broadcasts: u64,
}

impl StatsSyncProcessor {
    /// Bind `pipeline` (unbound, same factory as the shards) to the
    /// source schema and broadcast merged state on `out`. `shards` is the
    /// pipeline parallelism: one round = one delta from every shard.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId, shards: usize) -> Self {
        pipeline.bind(input);
        let stages = pipeline.len();
        StatsSyncProcessor {
            master: pipeline,
            out,
            round_size: shards.max(1),
            pending: vec![0; stages],
            deltas_merged: 0,
            broadcasts: 0,
        }
    }

    pub fn deltas_merged(&self) -> u64 {
        self.deltas_merged
    }

    pub fn broadcasts(&self) -> u64 {
        self.broadcasts
    }

    /// Master-state snapshot of `stage` (diagnostics/tests).
    pub fn snapshot(&self, stage: usize) -> Option<Vec<f64>> {
        self.master.stats_snapshot(stage)
    }

    fn broadcast(&mut self, stage: u32, ctx: &mut Ctx) {
        if let Some(snap) = self.master.stats_snapshot(stage as usize) {
            self.broadcasts += 1;
            ctx.emit_any(self.out, Event::StatsGlobal { stage, payload: Arc::new(snap) });
        }
    }
}

impl Processor for StatsSyncProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::StatsDelta { stage, payload } = event {
            self.master.stats_merge(stage as usize, &payload);
            self.deltas_merged += 1;
            if let Some(p) = self.pending.get_mut(stage as usize) {
                *p += 1;
                if *p >= self.round_size {
                    *p = 0;
                    self.broadcast(stage, ctx);
                }
            }
        }
    }

    /// Flush partial rounds: shards that emitted a straggler delta (e.g.
    /// the shutdown flush of `PipelineProcessor`) still get their state
    /// reflected in a final broadcast.
    fn on_shutdown(&mut self, ctx: &mut Ctx) {
        for stage in 0..self.pending.len() {
            if self.pending[stage] > 0 {
                self.pending[stage] = 0;
                self.broadcast(stage as u32, ctx);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        Transform::mem_bytes(&self.master)
    }

    fn name(&self) -> &'static str {
        "stats-sync"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::preprocess::{MergeableState, StandardScaler};

    /// Drive the shard ⇄ aggregator handshake by hand (no engine): four
    /// shards each see a disjoint quarter of the stream; after sync +
    /// apply, every shard's view moments equal the single-pass moments.
    #[test]
    fn manual_protocol_round_converges_shards() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shards: Vec<StandardScaler> = (0..4)
            .map(|_| {
                let mut s = StandardScaler::new();
                s.bind(&schema);
                s
            })
            .collect();
        let mut reference = StandardScaler::new();
        reference.bind(&schema);

        let mut rng = crate::common::Rng::new(17);
        for i in 0..4000 {
            let x = (rng.gaussian() * 3.0 + 1.0) as f32;
            shards[i % 4].transform(Instance::dense(vec![x], Label::None)).unwrap();
            reference.transform(Instance::dense(vec![x], Label::None)).unwrap();
        }

        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
            4,
        );
        let mut ctx = Ctx::new(0, 1);
        for shard in shards.iter_mut() {
            let delta = Transform::stats_delta(shard).unwrap();
            sync.process(
                Event::StatsDelta { stage: 0, payload: Arc::new(delta) },
                &mut ctx,
            );
        }
        assert_eq!(sync.deltas_merged(), 4);
        // coalescing: the round completed exactly once → one broadcast
        assert_eq!(sync.broadcasts(), 1);
        assert_eq!(ctx.take().len(), 1);
        let global = sync.snapshot(0).unwrap();
        for shard in shards.iter_mut() {
            shard.stats_apply(&global);
        }

        let want = reference.delta();
        for shard in &shards {
            let got = shard.delta();
            assert!(
                crate::preprocess::merge::payloads_close(&got, &want, 1e-9),
                "shard view {got:?} != single-pass {want:?}"
            );
        }
    }

    /// A partial round (fewer deltas than shards) is not broadcast until
    /// shutdown, where it is flushed exactly once.
    #[test]
    fn partial_round_flushes_on_shutdown() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shard = StandardScaler::new();
        shard.bind(&schema);
        shard.transform(Instance::dense(vec![1.0], Label::None)).unwrap();

        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
            4,
        );
        let mut ctx = Ctx::new(0, 1);
        let delta = Transform::stats_delta(&mut shard).unwrap();
        sync.process(Event::StatsDelta { stage: 0, payload: Arc::new(delta) }, &mut ctx);
        assert_eq!(sync.broadcasts(), 0, "partial round must not broadcast");
        assert!(ctx.take().is_empty());
        sync.on_shutdown(&mut ctx);
        assert_eq!(sync.broadcasts(), 1, "shutdown flushes the partial round");
        assert_eq!(ctx.take().len(), 1);
        let mut ctx2 = Ctx::new(0, 1);
        sync.on_shutdown(&mut ctx2);
        assert!(ctx2.take().is_empty(), "empty rounds are not re-flushed");
    }
}
