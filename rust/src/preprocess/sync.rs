//! Delta-sync aggregator — the topology stage that makes `p > 1`
//! pipeline shards converge to shared statistics.
//!
//! Protocol (one aggregator instance, `p` [`super::PipelineProcessor`]
//! shards):
//!
//! 1. per its [`super::processor::SyncPolicy`] (fixed count, ADWIN drift
//!    gate with staleness backstop, or hybrid), a shard takes each
//!    stateful stage's *pending increment* (`Transform::stats_delta`,
//!    the state accumulated since the shard's last emission — dense or
//!    sparse-compressed, see `super::wire`) and emits it as an
//!    `Event::StatsDelta` on a **`Key`-grouped** stream (keyed by stage
//!    index), stamped with the shard id and a per-stage round id;
//! 2. the aggregator folds the increment into its master state
//!    (`Transform::stats_merge`) — each update is merged **exactly
//!    once**, so the master equals the single-shard state up to merge
//!    reordering (commutativity/associativity, see
//!    [`super::merge::MergeableState`]);
//! 3. **once per stage per sync round** the aggregator broadcasts the
//!    merged snapshot (`Transform::stats_snapshot`) as an
//!    `Event::StatsGlobal` on an **`All`-grouped** stream. A round is
//!    **per-shard exact**: it closes when every one of the `p` shards
//!    has contributed one delta for the stage — not when *any* `p`
//!    deltas arrived, which under shard skew could count one fast shard
//!    several times. If a shard laps the round (its next delta arrives
//!    while slower or drift-silent shards still owe theirs), the round
//!    closes early with the members it has (a *skew round*) and the new
//!    delta opens the next one — so one shard's delta is **never merged
//!    twice into the same round**, and drift-gated shards that
//!    legitimately skip rounds cannot stall the broadcast. Coalescing
//!    keeps deliveries at `O(p)` per round (never `O(p²)`). Any partial
//!    round still pending at shutdown is flushed by `on_shutdown` —
//!    exact on *both* engines: the local engine drains each processor's
//!    shutdown emissions before the next processor's `on_shutdown`
//!    runs, and the threaded engine stages shutdown in the same
//!    processor-id order with a quiescence wait per stage, so shard
//!    straggler deltas always reach the aggregator before its own
//!    `on_shutdown` flush (`tests/shard_skew_rounds.rs` pins the exact
//!    counts on both engines);
//! 4. each shard replaces its transform-side view with the broadcast
//!    state merged with its own still-pending increment
//!    (`Transform::stats_apply`) — nothing is lost or double-counted.
//!
//! Both event kinds are control-plane (`Event::is_control`), so the
//! feedback loop can never deadlock against data-path backpressure in
//! the threaded engine — the same reasoning as the VHT `compute`/
//! `local-result` loop. This is load-bearing for the bounded data
//! plane: with data channels as small as one batch and shards stalled
//! in backpressure, deltas and global broadcasts still ride the
//! unbounded control channels, so sync rounds stay live under overload
//! (`tests/engine_properties.rs` pins round liveness at channel
//! capacities {1, 4, 64}).

use std::sync::Arc;

use crate::core::Schema;
use crate::topology::{Ctx, Event, Processor, StreamId};

use super::pipeline::Pipeline;
use super::Transform;

/// Closed-round audit record (tests/diagnostics): how many distinct
/// shards contributed and how many deltas were merged into the round.
/// The per-shard round protocol guarantees `contributors == merged`
/// (one delta per shard per round); a regression to any-p-deltas
/// counting shows up as `merged > contributors`.
#[derive(Clone, Copy, Debug)]
pub struct RoundAudit {
    pub stage: u32,
    pub contributors: u32,
    pub merged: u32,
    /// Closed early because a shard lapped the round (shard skew or
    /// drift-gated shards skipping it), not by full membership.
    pub skew_closed: bool,
}

/// Per-stage open-round bookkeeping.
struct StageRound {
    /// Shards that contributed to the open round.
    seen: Vec<bool>,
    n_seen: usize,
    /// Deltas merged into the open round (== n_seen by construction;
    /// audited separately so a regression is observable).
    merged: u32,
    /// Highest round id merged per shard (monotonicity diagnostics).
    last_round: Vec<Option<u64>>,
}

impl StageRound {
    fn new(shards: usize) -> Self {
        StageRound {
            seen: vec![false; shards],
            n_seen: 0,
            merged: 0,
            last_round: vec![None; shards],
        }
    }

    fn clear(&mut self) {
        self.seen.fill(false);
        self.n_seen = 0;
        self.merged = 0;
    }
}

/// Cap on the retained [`RoundAudit`] log (diagnostics stay bounded on
/// long runs; counters keep counting past it).
const AUDIT_CAP: usize = 4096;

/// Aggregator node: merges shard deltas into a master pipeline state and
/// broadcasts merged snapshots, one per stage per *per-shard-exact* sync
/// round.
pub struct StatsSyncProcessor {
    /// Master state container — a pipeline built by the same factory as
    /// the shards (never sees instances, only merged deltas).
    master: Pipeline,
    /// Broadcast (`All`-grouped) stream back to the shards.
    out: StreamId,
    /// Shard count: a full round = one delta from every shard.
    round_size: usize,
    /// Open round per stage.
    rounds: Vec<StageRound>,
    /// Deltas merged so far (diagnostics).
    deltas_merged: u64,
    /// Snapshots broadcast so far (diagnostics; the sync-overhead bench
    /// asserts broadcast deliveries == deltas under lockstep shards).
    broadcasts: u64,
    /// Rounds closed by full membership.
    completed_rounds: u64,
    /// Rounds closed early by a lapping shard.
    skew_rounds: u64,
    /// Bounded log of closed rounds.
    audit: Vec<RoundAudit>,
}

impl StatsSyncProcessor {
    /// Bind `pipeline` (unbound, same factory as the shards) to the
    /// source schema and broadcast merged state on `out`. `shards` is the
    /// pipeline parallelism: one round = one delta from every shard.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId, shards: usize) -> Self {
        pipeline.bind(input);
        let stages = pipeline.len();
        let shards = shards.max(1);
        StatsSyncProcessor {
            master: pipeline,
            out,
            round_size: shards,
            rounds: (0..stages).map(|_| StageRound::new(shards)).collect(),
            deltas_merged: 0,
            broadcasts: 0,
            completed_rounds: 0,
            skew_rounds: 0,
            audit: Vec::new(),
        }
    }

    pub fn deltas_merged(&self) -> u64 {
        self.deltas_merged
    }

    pub fn broadcasts(&self) -> u64 {
        self.broadcasts
    }

    /// Rounds closed with a delta from every shard.
    pub fn completed_rounds(&self) -> u64 {
        self.completed_rounds
    }

    /// Rounds closed early because a shard lapped them.
    pub fn skew_rounds(&self) -> u64 {
        self.skew_rounds
    }

    /// Closed-round log (capped at an internal bound).
    pub fn round_audit(&self) -> &[RoundAudit] {
        &self.audit
    }

    /// Master-state snapshot of `stage` (diagnostics/tests).
    pub fn snapshot(&self, stage: usize) -> Option<Vec<f64>> {
        self.master.stats_snapshot(stage)
    }

    fn close_round(&mut self, stage: u32, skew: bool, ctx: &mut Ctx) {
        let r = &mut self.rounds[stage as usize];
        let record = RoundAudit {
            stage,
            contributors: r.n_seen as u32,
            merged: r.merged,
            skew_closed: skew,
        };
        r.clear();
        if skew {
            self.skew_rounds += 1;
        } else {
            self.completed_rounds += 1;
        }
        if self.audit.len() < AUDIT_CAP {
            self.audit.push(record);
        }
        if let Some(snap) = self.master.stats_snapshot(stage as usize) {
            self.broadcasts += 1;
            ctx.emit_any(self.out, Event::StatsGlobal { stage, payload: Arc::new(snap) });
        }
    }
}

impl Processor for StatsSyncProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::StatsDelta { stage, shard, round, payload } = event {
            let (s, sh) = (stage as usize, shard as usize);
            if s >= self.rounds.len() || sh >= self.round_size {
                debug_assert!(false, "StatsDelta out of range: stage {stage} shard {shard}");
                return;
            }
            // A lapping shard closes the open round BEFORE its new delta
            // is merged: the closing broadcast reflects at most one delta
            // per shard, and the lapper's delta opens the next round.
            if self.rounds[s].seen[sh] {
                self.close_round(stage, true, ctx);
            }
            self.master.stats_merge(s, &payload);
            self.deltas_merged += 1;
            let r = &mut self.rounds[s];
            debug_assert!(
                r.last_round[sh].map_or(true, |prev| round > prev),
                "shard {shard} round ids must be monotonic on stage {stage}"
            );
            r.last_round[sh] = Some(round);
            r.seen[sh] = true;
            r.n_seen += 1;
            r.merged += 1;
            if r.n_seen == self.round_size {
                self.close_round(stage, false, ctx);
            }
        }
    }

    /// Flush partial rounds: shards that emitted a straggler delta (e.g.
    /// the shutdown flush of `PipelineProcessor`) still get their state
    /// reflected in a final broadcast.
    fn on_shutdown(&mut self, ctx: &mut Ctx) {
        for stage in 0..self.rounds.len() {
            if self.rounds[stage].n_seen > 0 {
                self.close_round(stage as u32, true, ctx);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        Transform::mem_bytes(&self.master)
    }

    fn name(&self) -> &'static str {
        "stats-sync"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    fn report(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("deltas_merged", self.deltas_merged() as f64),
            ("broadcasts", self.broadcasts() as f64),
            ("completed_rounds", self.completed_rounds() as f64),
            ("skew_rounds", self.skew_rounds() as f64),
        ]
    }

    /// Checkpoint = the master pipeline's full per-stage snapshots (the
    /// merged statistics — every delta merged before the cut is in
    /// there) plus the four diagnostic counters. Open-round *membership*
    /// (which shards contributed to a round still open at the cut) is
    /// deliberately not captured: restored rounds restart empty, so a
    /// kill landing mid-round can shift later completed/skew round
    /// classification — the master statistics themselves stay exact,
    /// because replay re-merges only post-checkpoint deltas, each
    /// exactly once.
    fn snapshot(&self) -> Option<Vec<u8>> {
        use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
        let mut sections: Vec<(u32, Vec<f64>)> = self
            .master
            .stateful_stages()
            .into_iter()
            .map(|stage| (stage as u32, self.master.stats_snapshot(stage).unwrap_or_default()))
            .collect();
        sections.push((
            TAG_META_BASE,
            vec![
                self.deltas_merged as f64,
                self.broadcasts as f64,
                self.completed_rounds as f64,
                self.skew_rounds as f64,
            ],
        ));
        Some(encode_frame(&sections))
    }

    fn restore(&mut self, frame: &[u8]) -> crate::Result<()> {
        use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
        let sections = decode_frame(frame)?;
        for stage in self.master.stateful_stages() {
            let Some(payload) = section(&sections, stage as u32) else {
                crate::bail!("stats-sync restore: missing stage {stage} section");
            };
            self.master.stats_apply(stage, payload);
        }
        if let Some(meta) = section(&sections, TAG_META_BASE) {
            crate::ensure!(meta.len() == 4, "stats-sync restore: bad counter section");
            self.deltas_merged = meta[0] as u64;
            self.broadcasts = meta[1] as u64;
            self.completed_rounds = meta[2] as u64;
            self.skew_rounds = meta[3] as u64;
        }
        for r in &mut self.rounds {
            r.clear();
            r.last_round.fill(None);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::preprocess::{MergeableState, StandardScaler};

    fn delta_event(stage: u32, shard: u32, round: u64, payload: Vec<f64>) -> Event {
        Event::StatsDelta { stage, shard, round, payload: Arc::new(payload) }
    }

    /// Drive the shard ⇄ aggregator handshake by hand (no engine): four
    /// shards each see a disjoint quarter of the stream; after sync +
    /// apply, every shard's view moments equal the single-pass moments.
    #[test]
    fn manual_protocol_round_converges_shards() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shards: Vec<StandardScaler> = (0..4)
            .map(|_| {
                let mut s = StandardScaler::new();
                s.bind(&schema);
                s
            })
            .collect();
        let mut reference = StandardScaler::new();
        reference.bind(&schema);

        let mut rng = crate::common::Rng::new(17);
        for i in 0..4000 {
            let x = (rng.gaussian() * 3.0 + 1.0) as f32;
            shards[i % 4].transform(Instance::dense(vec![x], Label::None)).unwrap();
            reference.transform(Instance::dense(vec![x], Label::None)).unwrap();
        }

        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
            4,
        );
        let mut ctx = Ctx::new(0, 1);
        for (i, shard) in shards.iter_mut().enumerate() {
            let delta = Transform::stats_delta(shard).unwrap();
            sync.process(delta_event(0, i as u32, 0, delta), &mut ctx);
        }
        assert_eq!(sync.deltas_merged(), 4);
        // per-shard round: four distinct shards complete exactly one
        // full round → one broadcast
        assert_eq!(sync.broadcasts(), 1);
        assert_eq!(sync.completed_rounds(), 1);
        assert_eq!(sync.skew_rounds(), 0);
        assert_eq!(ctx.take().len(), 1);
        let global = sync.snapshot(0).unwrap();
        for shard in shards.iter_mut() {
            shard.stats_apply(&global);
        }

        let want = reference.delta();
        for shard in &shards {
            let got = shard.delta();
            assert!(
                crate::preprocess::merge::payloads_close(&got, &want, 1e-9),
                "shard view {got:?} != single-pass {want:?}"
            );
        }
    }

    /// A partial round (fewer shards than `p`) is not broadcast until
    /// shutdown, where it is flushed exactly once.
    #[test]
    fn partial_round_flushes_on_shutdown() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shard = StandardScaler::new();
        shard.bind(&schema);
        shard.transform(Instance::dense(vec![1.0], Label::None)).unwrap();

        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
            4,
        );
        let mut ctx = Ctx::new(0, 1);
        let delta = Transform::stats_delta(&mut shard).unwrap();
        sync.process(delta_event(0, 0, 0, delta), &mut ctx);
        assert_eq!(sync.broadcasts(), 0, "partial round must not broadcast");
        assert!(ctx.take().is_empty());
        sync.on_shutdown(&mut ctx);
        assert_eq!(sync.broadcasts(), 1, "shutdown flushes the partial round");
        assert_eq!(ctx.take().len(), 1);
        let mut ctx2 = Ctx::new(0, 1);
        sync.on_shutdown(&mut ctx2);
        assert!(ctx2.take().is_empty(), "empty rounds are not re-flushed");
    }

    /// The exactness fix: p deltas from ONE shard are p rounds, not one.
    /// Each lap closes the open round (with one contributor) and opens
    /// the next — the old any-p-deltas counter would have merged all
    /// four into a single round and broadcast once.
    #[test]
    fn lapping_shard_never_merges_twice_into_one_round() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shard = StandardScaler::new();
        shard.bind(&schema);
        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
            4,
        );
        let mut ctx = Ctx::new(0, 1);
        for round in 0..4u64 {
            shard.transform(Instance::dense(vec![round as f32], Label::None)).unwrap();
            let delta = Transform::stats_delta(&mut shard).unwrap();
            sync.process(delta_event(0, 0, round, delta), &mut ctx);
        }
        assert_eq!(sync.deltas_merged(), 4);
        // rounds 1..3 were skew-closed by the lapping shard; round 4 is
        // still open (one contributor)
        assert_eq!(sync.skew_rounds(), 3);
        assert_eq!(sync.completed_rounds(), 0);
        assert_eq!(sync.broadcasts(), 3);
        for r in sync.round_audit() {
            assert_eq!(r.contributors, 1, "one shard can contribute once per round");
            assert_eq!(r.merged, 1);
            assert!(r.skew_closed);
        }
        // the master still merged every delta exactly once
        assert_eq!(sync.snapshot(0).unwrap()[0], 4.0);
    }
}
