//! Delta-sync aggregator — the topology stage that makes `p > 1`
//! pipeline shards converge to shared statistics.
//!
//! Protocol (one aggregator instance, `p` [`super::PipelineProcessor`]
//! shards):
//!
//! 1. every `interval` locally-processed instances, a shard takes each
//!    stateful stage's *pending increment* (`Transform::stats_delta`, the
//!    state accumulated since the shard's last emission) and emits it as
//!    an `Event::StatsDelta` on a **`Key`-grouped** stream (keyed by
//!    stage index);
//! 2. the aggregator folds the increment into its master state
//!    (`Transform::stats_merge`) — each update is merged **exactly
//!    once**, so the master equals the single-shard state up to merge
//!    reordering (commutativity/associativity, see
//!    [`super::merge::MergeableState`]);
//! 3. the aggregator broadcasts the merged snapshot
//!    (`Transform::stats_snapshot`) as an `Event::StatsGlobal` on an
//!    **`All`-grouped** stream;
//! 4. each shard replaces its transform-side view with the broadcast
//!    state merged with its own still-pending increment
//!    (`Transform::stats_apply`) — nothing is lost or double-counted.
//!
//! Both event kinds are control-plane (`Event::is_control`), so the
//! feedback loop can never deadlock against data-path backpressure in
//! the threaded engine — the same reasoning as the VHT `compute`/
//! `local-result` loop.

use std::sync::Arc;

use crate::core::Schema;
use crate::topology::{Ctx, Event, Processor, StreamId};

use super::pipeline::Pipeline;
use super::Transform;

/// Aggregator node: merges shard deltas into a master pipeline state and
/// broadcasts merged snapshots.
pub struct StatsSyncProcessor {
    /// Master state container — a pipeline built by the same factory as
    /// the shards (never sees instances, only merged deltas).
    master: Pipeline,
    /// Broadcast (`All`-grouped) stream back to the shards.
    out: StreamId,
    /// Deltas merged so far (diagnostics).
    deltas_merged: u64,
}

impl StatsSyncProcessor {
    /// Bind `pipeline` (unbound, same factory as the shards) to the
    /// source schema and broadcast merged state on `out`.
    pub fn new(mut pipeline: Pipeline, input: &Schema, out: StreamId) -> Self {
        pipeline.bind(input);
        StatsSyncProcessor { master: pipeline, out, deltas_merged: 0 }
    }

    pub fn deltas_merged(&self) -> u64 {
        self.deltas_merged
    }

    /// Master-state snapshot of `stage` (diagnostics/tests).
    pub fn snapshot(&self, stage: usize) -> Option<Vec<f64>> {
        self.master.stats_snapshot(stage)
    }
}

impl Processor for StatsSyncProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::StatsDelta { stage, payload } = event {
            self.master.stats_merge(stage as usize, &payload);
            self.deltas_merged += 1;
            if let Some(snap) = self.master.stats_snapshot(stage as usize) {
                ctx.emit_any(self.out, Event::StatsGlobal { stage, payload: Arc::new(snap) });
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        Transform::mem_bytes(&self.master)
    }

    fn name(&self) -> &'static str {
        "stats-sync"
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::preprocess::{MergeableState, StandardScaler};

    /// Drive the shard ⇄ aggregator handshake by hand (no engine): four
    /// shards each see a disjoint quarter of the stream; after sync +
    /// apply, every shard's view moments equal the single-pass moments.
    #[test]
    fn manual_protocol_round_converges_shards() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut shards: Vec<StandardScaler> = (0..4)
            .map(|_| {
                let mut s = StandardScaler::new();
                s.bind(&schema);
                s
            })
            .collect();
        let mut reference = StandardScaler::new();
        reference.bind(&schema);

        let mut rng = crate::common::Rng::new(17);
        for i in 0..4000 {
            let x = (rng.gaussian() * 3.0 + 1.0) as f32;
            shards[i % 4].transform(Instance::dense(vec![x], Label::None)).unwrap();
            reference.transform(Instance::dense(vec![x], Label::None)).unwrap();
        }

        let mut sync = StatsSyncProcessor::new(
            crate::preprocess::Pipeline::new().then(StandardScaler::new()),
            &schema,
            StreamId(0),
        );
        let mut ctx = Ctx::new(0, 1);
        for shard in shards.iter_mut() {
            let delta = Transform::stats_delta(shard).unwrap();
            sync.process(
                Event::StatsDelta { stage: 0, payload: Arc::new(delta) },
                &mut ctx,
            );
        }
        assert_eq!(sync.deltas_merged(), 4);
        let global = sync.snapshot(0).unwrap();
        for shard in shards.iter_mut() {
            shard.stats_apply(&global);
        }

        let want = reference.delta();
        for shard in &shards {
            let got = shard.delta();
            assert!(
                crate::preprocess::merge::payloads_close(&got, &want, 1e-9),
                "shard view {got:?} != single-pass {want:?}"
            );
        }
    }
}
