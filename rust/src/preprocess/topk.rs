//! Heavy-hitter attribute filter: keep only the `k` most frequent
//! attributes of a sparse stream (bag-of-words vocabulary pruning), as
//! estimated online by a Misra-Gries summary with Count-Min refinement —
//! MG nominates a bounded candidate set (no false-negative heavy hitters),
//! CountMin ranks the candidates with overestimate-only counts.

use crate::common::MemSize;
use crate::core::instance::Values;
use crate::core::{Instance, Schema};

use super::merge::MergeableState;
use super::sketch::{CountMinSketch, MisraGries};
use super::Transform;

/// Keep the top-`k` attributes by stream frequency; everything else is
/// dropped (sparse) or zeroed (dense). Schema is unchanged — the surviving
/// attributes keep their indices.
///
/// Both backing sketches are mergeable, so under `p > 1` shards the
/// delta-sync protocol ([`super::sync`]) converges every shard to the
/// same keep-set: pending (since last emission) sketch increments ship to
/// the aggregator and the broadcast global sketches replace the local
/// view (the keep-set is recomputed on every broadcast).
pub struct TopKFilter {
    k: usize,
    mg: MisraGries,
    cm: CountMinSketch,
    /// Increments since the last `stats_delta` emission.
    pending_mg: MisraGries,
    pending_cm: CountMinSketch,
    /// Recompute the keep-set every `refresh` instances.
    refresh: u64,
    seen: u64,
    /// Sorted attribute indices currently kept (empty until first refresh
    /// = keep everything while the summaries warm up).
    keep: Vec<u32>,
}

impl TopKFilter {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "need k >= 1");
        TopKFilter {
            k,
            // 4x headroom: MG's N/cap error must be well under the k-th
            // frequency for a stable keep-set.
            mg: MisraGries::new(4 * k),
            cm: CountMinSketch::new((16 * k).next_power_of_two(), 4),
            pending_mg: MisraGries::new(4 * k),
            pending_cm: CountMinSketch::new((16 * k).next_power_of_two(), 4),
            refresh: 512,
            seen: 0,
            keep: Vec::new(),
        }
    }

    pub fn with_refresh(mut self, refresh: u64) -> Self {
        self.refresh = refresh.max(1);
        self
    }

    /// Current keep-set (sorted attribute indices); empty before warmup.
    pub fn kept(&self) -> &[u32] {
        &self.keep
    }

    fn recompute_keep(&mut self) {
        let mut candidates = self.mg.heavy_hitters();
        // rank MG candidates by the (tighter at the top) CountMin estimate
        for c in candidates.iter_mut() {
            c.1 = self.cm.estimate(c.0);
        }
        candidates.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        candidates.truncate(self.k);
        self.keep = candidates.iter().map(|&(i, _)| i as u32).collect();
        self.keep.sort_unstable();
    }

    #[inline]
    fn keeps(&self, j: u32) -> bool {
        // empty keep-set = warmup, let everything through
        self.keep.is_empty() || self.keep.binary_search(&j).is_ok()
    }
}

impl Transform for TopKFilter {
    fn bind(&mut self, input: &Schema) -> Schema {
        let mut out = input.clone();
        out.name = format!("{}|top{}", input.name, self.k);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        // observe attribute occurrences (presence, not magnitude)
        match inst.values() {
            Values::Dense(v) => {
                for (j, &x) in v.iter().enumerate() {
                    if x != 0.0 {
                        self.mg.add(j as u64);
                        self.cm.add(j as u64, 1);
                        self.pending_mg.add(j as u64);
                        self.pending_cm.add(j as u64, 1);
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, &x) in indices.iter().zip(values.iter()) {
                    if x != 0.0 {
                        self.mg.add(j as u64);
                        self.cm.add(j as u64, 1);
                        self.pending_mg.add(j as u64);
                        self.pending_cm.add(j as u64, 1);
                    }
                }
            }
        }
        self.seen += 1;
        if self.seen % self.refresh == 0 {
            self.recompute_keep();
        }

        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, x) in v.iter_mut().enumerate() {
                    if !self.keeps(j as u32) {
                        *x = 0.0;
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                let keep = std::mem::take(indices);
                let vals = std::mem::take(values);
                for (j, x) in keep.into_iter().zip(vals) {
                    if self.keeps(j) {
                        indices.push(j);
                        values.push(x);
                    }
                }
            }
        }
        Some(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        let mg = self.pending_mg.delta();
        let cm = self.pending_cm.delta();
        let mut out = Vec::with_capacity(1 + mg.len() + cm.len());
        out.push(mg.len() as f64);
        out.extend(mg);
        out.extend(cm);
        self.pending_mg.reset();
        self.pending_cm.reset();
        Some(out)
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        let Some((mg, cm)) = split_sketch_payload(payload) else { return };
        let mut inc_mg = MisraGries::new(self.mg.k());
        inc_mg.apply_delta(mg);
        self.mg.merge(&inc_mg);
        let mut inc_cm = CountMinSketch::new(self.cm.width(), self.cm.depth());
        inc_cm.apply_delta(cm);
        self.cm.merge(&inc_cm);
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        let mg = self.mg.delta();
        let cm = self.cm.delta();
        let mut out = Vec::with_capacity(1 + mg.len() + cm.len());
        out.push(mg.len() as f64);
        out.extend(mg);
        out.extend(cm);
        Some(out)
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        let Some((mg, cm)) = split_sketch_payload(payload) else { return };
        let mut global_mg = MisraGries::new(self.mg.k());
        global_mg.apply_delta(mg);
        global_mg.merge(&self.pending_mg);
        self.mg = global_mg;
        let mut global_cm = CountMinSketch::new(self.cm.width(), self.cm.depth());
        global_cm.apply_delta(cm);
        global_cm.merge(&self.pending_cm);
        self.cm = global_cm;
        self.recompute_keep();
    }

    fn name(&self) -> &'static str {
        "topk-filter"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.mg.mem_bytes()
            + self.cm.mem_bytes()
            + self.pending_mg.mem_bytes()
            + self.pending_cm.mem_bytes()
            + self.keep.capacity() * 4
    }
}

/// Split a `[mg_len, mg..., cm...]` combined payload.
fn split_sketch_payload(payload: &[f64]) -> Option<(&[f64], &[f64])> {
    let mg_len = *payload.first()? as usize;
    if payload.len() < 1 + mg_len {
        return None;
    }
    Some((&payload[1..1 + mg_len], &payload[1 + mg_len..]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    #[test]
    fn converges_to_true_heavy_hitters() {
        // attributes 0..8 appear every instance; 100 noise attributes
        // appear rarely — after refresh, exactly 0..8 must be kept
        let schema = Schema::classification("t", Schema::all_numeric(200), 2);
        let mut f = TopKFilter::new(8).with_refresh(256);
        f.bind(&schema);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let noise = 8 + rng.below(192) as u32;
            let mut idx = vec![0u32, 1, 2, 3, 4, 5, 6, 7];
            if !idx.contains(&noise) {
                idx.push(noise);
            }
            idx.sort_unstable();
            let vals = vec![1.0f32; idx.len()];
            f.transform(Instance::sparse(idx, vals, 200, Label::None)).unwrap();
        }
        assert_eq!(f.kept(), &[0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn filters_sparse_instances_to_keep_set() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut f = TopKFilter::new(2).with_refresh(64);
        f.bind(&schema);
        for _ in 0..500 {
            f.transform(Instance::sparse(
                vec![10, 20, 30],
                vec![1.0, 1.0, 1.0],
                100,
                Label::None,
            ))
            .unwrap();
        }
        // 10/20/30 tie at equal frequency; deterministic tie-break keeps
        // the two lowest ids
        let out = f
            .transform(Instance::sparse(vec![10, 20, 30], vec![1.0, 1.0, 1.0], 100, Label::None))
            .unwrap();
        assert_eq!(out.n_stored(), 2);
        assert_eq!(out.n_attributes(), 100);
    }

    #[test]
    fn dense_zeroing() {
        let schema = Schema::classification("t", Schema::all_numeric(4), 2);
        let mut f = TopKFilter::new(1).with_refresh(16);
        f.bind(&schema);
        for _ in 0..64 {
            f.transform(Instance::dense(vec![1.0, 0.0, 0.5, 0.0], Label::None)).unwrap();
        }
        let out = f.transform(Instance::dense(vec![1.0, 1.0, 0.5, 1.0], Label::None)).unwrap();
        // only one attribute survives; it must be 0 or 2 (the observed ones)
        let kept: Vec<usize> = (0..4).filter(|&j| out.value(j) != 0.0).collect();
        assert_eq!(kept.len(), 1);
        assert!(kept[0] == 0 || kept[0] == 2);
    }
}
