//! Heavy-hitter attribute filter: keep only the `k` most frequent
//! attributes of a sparse stream (bag-of-words vocabulary pruning), as
//! estimated online by a Misra-Gries summary with Count-Min refinement —
//! MG nominates a bounded candidate set (no false-negative heavy hitters),
//! CountMin ranks the candidates with overestimate-only counts.

use crate::common::MemSize;
use crate::core::instance::Values;
use crate::core::{Instance, Schema};

use super::merge::MergeableState;
use super::sketch::{CountMinSketch, MisraGries};
use super::Transform;

/// Keep the top-`k` attributes by stream frequency; everything else is
/// dropped (sparse) or zeroed (dense). Schema is unchanged — the surviving
/// attributes keep their indices.
///
/// Both backing sketches are mergeable, so under `p > 1` shards the
/// delta-sync protocol ([`super::sync`]) converges every shard to the
/// same keep-set: pending (since last emission) sketch increments ship to
/// the aggregator and the broadcast global sketches replace the local
/// view (the keep-set is recomputed on every broadcast).
pub struct TopKFilter {
    k: usize,
    mg: MisraGries,
    cm: CountMinSketch,
    /// Increments since the last `stats_delta` emission.
    pending_mg: MisraGries,
    pending_cm: CountMinSketch,
    /// Recompute the keep-set every `refresh` instances.
    refresh: u64,
    seen: u64,
    /// Sorted attribute indices currently kept (empty until first refresh
    /// = keep everything while the summaries warm up).
    keep: Vec<u32>,
    /// Keep-set hysteresis: a challenger must beat an incumbent's count
    /// by this relative margin to displace it, so features oscillating
    /// around the k-th count across refreshes / consecutive global
    /// snapshots are not churned in and out (ROADMAP "keep-set
    /// hysteresis under sync churn").
    hysteresis: f64,
    /// Compute the drift signal per instance (off = zero hot-path cost).
    track_signal: bool,
    /// Last instance's fraction of observed attributes inside the
    /// keep-set (drift-gate signal: drops when the vocabulary shifts).
    last_signal: Option<f64>,
}

impl TopKFilter {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "need k >= 1");
        TopKFilter {
            k,
            // 4x headroom: MG's N/cap error must be well under the k-th
            // frequency for a stable keep-set.
            mg: MisraGries::new(4 * k),
            cm: CountMinSketch::new((16 * k).next_power_of_two(), 4),
            pending_mg: MisraGries::new(4 * k),
            pending_cm: CountMinSketch::new((16 * k).next_power_of_two(), 4),
            refresh: 512,
            seen: 0,
            keep: Vec::new(),
            hysteresis: 0.1,
            track_signal: false,
            last_signal: None,
        }
    }

    pub fn with_refresh(mut self, refresh: u64) -> Self {
        self.refresh = refresh.max(1);
        self
    }

    /// Set the keep-set hysteresis margin (0 = any strictly higher count
    /// displaces an incumbent — the churny pre-hysteresis behavior).
    pub fn with_hysteresis(mut self, h: f64) -> Self {
        self.hysteresis = h.max(0.0);
        self
    }

    /// Current keep-set (sorted attribute indices); empty before warmup.
    pub fn kept(&self) -> &[u32] {
        &self.keep
    }

    fn recompute_keep(&mut self) {
        let mut candidates = self.mg.heavy_hitters();
        // rank MG candidates by the (tighter at the top) CountMin estimate
        for c in candidates.iter_mut() {
            c.1 = self.cm.estimate(c.0);
        }
        candidates.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        if self.keep.is_empty() {
            // first refresh: no incumbents, take the strict top-k
            candidates.truncate(self.k);
            self.keep = candidates.iter().map(|&(i, _)| i as u32).collect();
            self.keep.sort_unstable();
            return;
        }
        // Hysteresis pass: incumbents hold their slot unless a challenger
        // beats them by the margin. Near-ties around the k-th count
        // therefore stay with whoever held the slot first, instead of
        // flapping on every refresh (or every global-snapshot apply).
        let is_incumbent = |id: u64| self.keep.binary_search(&(id as u32)).is_ok();
        let mut slots: Vec<(u64, u64)> = self
            .keep
            .iter()
            .map(|&j| (j as u64, self.cm.estimate(j as u64)))
            .collect();
        slots.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let challengers: Vec<(u64, u64)> =
            candidates.into_iter().filter(|&(id, _)| !is_incumbent(id)).collect();
        for &(id, est) in &challengers {
            if slots.len() < self.k {
                // free slot: no one to displace, admit outright
                Self::slot_insert(&mut slots, id, est);
                continue;
            }
            let &(_, weakest) = slots.last().expect("k >= 1");
            // relative margin, with an absolute floor of 1 count so
            // zero-count incumbents don't hold slots forever
            let bar = weakest + (weakest as f64 * self.hysteresis).ceil().max(1.0) as u64;
            if est >= bar {
                slots.pop();
                Self::slot_insert(&mut slots, id, est);
            }
        }
        self.keep = slots.iter().map(|&(i, _)| i as u32).collect();
        self.keep.sort_unstable();
    }

    /// Insert into a (estimate desc, id asc)-sorted slot list.
    fn slot_insert(slots: &mut Vec<(u64, u64)>, id: u64, est: u64) {
        let at = slots.partition_point(|&(sid, sest)| {
            (sest, std::cmp::Reverse(sid)) > (est, std::cmp::Reverse(id))
        });
        slots.insert(at, (id, est));
    }

    #[inline]
    fn keeps(&self, j: u32) -> bool {
        // empty keep-set = warmup, let everything through
        self.keep.is_empty() || self.keep.binary_search(&j).is_ok()
    }
}

impl Transform for TopKFilter {
    fn bind(&mut self, input: &Schema) -> Schema {
        let mut out = input.clone();
        out.name = format!("{}|top{}", input.name, self.k);
        out
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        // observe attribute occurrences (presence, not magnitude)
        match inst.values() {
            Values::Dense(v) => {
                for (j, &x) in v.iter().enumerate() {
                    if x != 0.0 {
                        self.mg.add(j as u64);
                        self.cm.add(j as u64, 1);
                        self.pending_mg.add(j as u64);
                        self.pending_cm.add(j as u64, 1);
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, &x) in indices.iter().zip(values.iter()) {
                    if x != 0.0 {
                        self.mg.add(j as u64);
                        self.cm.add(j as u64, 1);
                        self.pending_mg.add(j as u64);
                        self.pending_cm.add(j as u64, 1);
                    }
                }
            }
        }
        self.seen += 1;
        if self.seen % self.refresh == 0 {
            self.recompute_keep();
        }

        let track = self.track_signal;
        let (mut observed, mut kept) = (0u32, 0u32);
        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, x) in v.iter_mut().enumerate() {
                    if track && *x != 0.0 {
                        observed += 1;
                    }
                    if !self.keeps(j as u32) {
                        *x = 0.0;
                    } else if track && *x != 0.0 {
                        kept += 1;
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                let keep = std::mem::take(indices);
                let vals = std::mem::take(values);
                for (j, x) in keep.into_iter().zip(vals) {
                    if track && x != 0.0 {
                        observed += 1;
                    }
                    if self.keeps(j) {
                        if track && x != 0.0 {
                            kept += 1;
                        }
                        indices.push(j);
                        values.push(x);
                    }
                }
            }
        }
        if observed > 0 {
            // fraction of this instance's active attributes that survive
            // the filter: near-constant under a stable vocabulary, drops
            // when the heavy-hitter set shifts
            self.last_signal = Some(kept as f64 / observed as f64);
        }
        Some(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        // MG deltas are changed-key sets by construction; the CountMin
        // half ships whichever of dense/sparse is smaller
        let mg = self.pending_mg.delta();
        let cm =
            super::wire::pick_smaller(self.pending_cm.delta(), self.pending_cm.sparse_delta());
        let mut out = Vec::with_capacity(1 + mg.len() + cm.len());
        out.push(mg.len() as f64);
        out.extend(mg);
        out.extend(cm);
        self.pending_mg.reset();
        self.pending_cm.reset();
        Some(out)
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        let mg = self.pending_mg.delta();
        let cm = self.pending_cm.delta();
        let mut out = Vec::with_capacity(1 + mg.len() + cm.len());
        out.push(mg.len() as f64);
        out.extend(mg);
        out.extend(cm);
        self.pending_mg.reset();
        self.pending_cm.reset();
        Some(out)
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        let Some((mg, cm)) = split_sketch_payload(payload) else { return };
        let mut inc_mg = MisraGries::new(self.mg.k());
        inc_mg.apply_delta(mg);
        self.mg.merge(&inc_mg);
        let mut inc_cm = CountMinSketch::new(self.cm.width(), self.cm.depth());
        inc_cm.apply_delta(cm);
        self.cm.merge(&inc_cm);
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        let mg = self.mg.delta();
        let cm = self.cm.delta();
        let mut out = Vec::with_capacity(1 + mg.len() + cm.len());
        out.push(mg.len() as f64);
        out.extend(mg);
        out.extend(cm);
        Some(out)
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        let Some((mg, cm)) = split_sketch_payload(payload) else { return };
        let mut global_mg = MisraGries::new(self.mg.k());
        global_mg.apply_delta(mg);
        global_mg.merge(&self.pending_mg);
        self.mg = global_mg;
        let mut global_cm = CountMinSketch::new(self.cm.width(), self.cm.depth());
        global_cm.apply_delta(cm);
        global_cm.merge(&self.pending_cm);
        self.cm = global_cm;
        self.recompute_keep();
    }

    fn track_drift_signal(&mut self, on: bool) {
        self.track_signal = on;
    }

    fn drift_signal(&mut self) -> Option<f64> {
        self.last_signal.take()
    }

    fn name(&self) -> &'static str {
        "topk-filter"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.mg.mem_bytes()
            + self.cm.mem_bytes()
            + self.pending_mg.mem_bytes()
            + self.pending_cm.mem_bytes()
            + self.keep.capacity() * 4
    }
}

/// Split a `[mg_len, mg..., cm...]` combined payload.
fn split_sketch_payload(payload: &[f64]) -> Option<(&[f64], &[f64])> {
    let mg_len = *payload.first()? as usize;
    if payload.len() < 1 + mg_len {
        return None;
    }
    Some((&payload[1..1 + mg_len], &payload[1 + mg_len..]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    #[test]
    fn converges_to_true_heavy_hitters() {
        // attributes 0..8 appear every instance; 100 noise attributes
        // appear rarely — after refresh, exactly 0..8 must be kept
        let schema = Schema::classification("t", Schema::all_numeric(200), 2);
        let mut f = TopKFilter::new(8).with_refresh(256);
        f.bind(&schema);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let noise = 8 + rng.below(192) as u32;
            let mut idx = vec![0u32, 1, 2, 3, 4, 5, 6, 7];
            if !idx.contains(&noise) {
                idx.push(noise);
            }
            idx.sort_unstable();
            let vals = vec![1.0f32; idx.len()];
            f.transform(Instance::sparse(idx, vals, 200, Label::None)).unwrap();
        }
        assert_eq!(f.kept(), &[0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn filters_sparse_instances_to_keep_set() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let mut f = TopKFilter::new(2).with_refresh(64);
        f.bind(&schema);
        for _ in 0..500 {
            f.transform(Instance::sparse(
                vec![10, 20, 30],
                vec![1.0, 1.0, 1.0],
                100,
                Label::None,
            ))
            .unwrap();
        }
        // 10/20/30 tie at equal frequency; deterministic tie-break keeps
        // the two lowest ids
        let out = f
            .transform(Instance::sparse(vec![10, 20, 30], vec![1.0, 1.0, 1.0], 100, Label::None))
            .unwrap();
        assert_eq!(out.n_stored(), 2);
        assert_eq!(out.n_attributes(), 100);
    }

    /// Regression (ROADMAP follow-up): two features oscillating around
    /// the k-th count must not be churned in and out of the keep-set on
    /// every refresh. The adversarial stream alternates blocks where
    /// attribute 10 then attribute 11 is *slightly* ahead — within the
    /// hysteresis margin — so whoever first claims the last slot keeps
    /// it; with hysteresis 0 the set flips nearly every refresh.
    #[test]
    fn hysteresis_stops_keep_set_oscillation_on_near_ties() {
        let schema = Schema::classification("t", Schema::all_numeric(100), 2);
        let run = |hysteresis: f64| -> usize {
            let mut f = TopKFilter::new(3).with_refresh(64).with_hysteresis(hysteresis);
            f.bind(&schema);
            let mut changes = 0;
            let mut last: Vec<u32> = Vec::new();
            for block in 0..40u64 {
                // attrs 1, 2 are solid heavy hitters; 10 and 11 near-tie
                // for the third slot. The per-block deficit is sized so
                // the *cumulative* lead alternates sign by ±6 at every
                // block boundary — tiny against totals in the thousands,
                // so it sits well inside a 10% hysteresis margin.
                let leader = if block % 2 == 0 { 10 } else { 11 };
                let trailer = if block % 2 == 0 { 11 } else { 10 };
                let skips = if block == 0 { 6 } else { 12 };
                for i in 0..64u64 {
                    let mut idx = vec![1u32, 2, leader];
                    if i >= skips {
                        idx.push(trailer);
                    }
                    idx.sort_unstable();
                    let vals = vec![1.0f32; idx.len()];
                    f.transform(Instance::sparse(idx, vals, 100, Label::None)).unwrap();
                }
                if !last.is_empty() && f.kept() != last.as_slice() {
                    changes += 1;
                }
                last = f.kept().to_vec();
            }
            changes
        };
        let churny = run(0.0);
        let stable = run(0.1);
        assert!(
            stable <= 1,
            "hysteresis keep-set still oscillates: {stable} changes (no-hysteresis: {churny})"
        );
        assert!(
            churny > stable,
            "adversarial stream failed to churn the margin-free filter ({churny} changes)"
        );
    }

    #[test]
    fn dense_zeroing() {
        let schema = Schema::classification("t", Schema::all_numeric(4), 2);
        let mut f = TopKFilter::new(1).with_refresh(16);
        f.bind(&schema);
        for _ in 0..64 {
            f.transform(Instance::dense(vec![1.0, 0.0, 0.5, 0.0], Label::None)).unwrap();
        }
        let out = f.transform(Instance::dense(vec![1.0, 1.0, 0.5, 1.0], Label::None)).unwrap();
        // only one attribute survives; it must be 0 or 2 (the observed ones)
        let kept: Vec<usize> = (0..4).filter(|&j| out.value(j) != 0.0).collect();
        assert_eq!(kept.len(), 1);
        assert!(kept[0] == 0 || kept[0] == 2);
    }
}
