//! [`Pipeline`] — the combinator chaining transforms into one operator,
//! rewriting the schema end-to-end at bind time. A pipeline is itself a
//! [`Transform`], so pipelines nest.

use crate::core::{Instance, Schema};

use super::Transform;

/// An ordered chain of transforms. Build with [`Pipeline::then`], bind
/// once to the source schema, then feed instances in arrival order.
pub struct Pipeline {
    transforms: Vec<Box<dyn Transform>>,
    /// Set by `bind`: the schema after every stage.
    output: Option<Schema>,
}

impl Pipeline {
    pub fn new() -> Self {
        Pipeline { transforms: Vec::new(), output: None }
    }

    /// Append a transform (builder style).
    pub fn then(mut self, t: impl Transform + 'static) -> Self {
        assert!(self.output.is_none(), "cannot extend a pipeline after bind");
        self.transforms.push(Box::new(t));
        self
    }

    /// Append a boxed transform (for dynamically assembled pipelines).
    pub fn then_boxed(mut self, t: Box<dyn Transform>) -> Self {
        assert!(self.output.is_none(), "cannot extend a pipeline after bind");
        self.transforms.push(t);
        self
    }

    pub fn len(&self) -> usize {
        self.transforms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transforms.is_empty()
    }

    /// Output schema; panics if the pipeline is not bound yet.
    pub fn output_schema(&self) -> &Schema {
        self.output.as_ref().expect("pipeline not bound")
    }

    /// Stage names, in order (diagnostics / `samoa run` banner).
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.transforms.iter().map(|t| t.name()).collect()
    }

    // --- delta-sync plumbing (per-stage fan-out of the Transform hooks;
    // see `super::sync`). Nested pipelines count as one opaque stage and
    // keep the stateless defaults, so only top-level operators sync.

    /// Pending (stage index, payload) increments of every stateful stage,
    /// resetting each as it is taken.
    pub fn stats_deltas(&mut self) -> Vec<(usize, Vec<f64>)> {
        self.transforms
            .iter_mut()
            .enumerate()
            .filter_map(|(i, t)| t.stats_delta().map(|p| (i, p)))
            .collect()
    }

    /// Pending increment of a single stage (resetting it); `compress`
    /// picks between the adaptive sparse form and the dense baseline.
    /// `None` for stateless stages and out-of-range indices.
    pub fn stats_delta_stage(&mut self, stage: usize, compress: bool) -> Option<Vec<f64>> {
        let t = self.transforms.get_mut(stage)?;
        if compress {
            t.stats_delta()
        } else {
            t.stats_delta_dense()
        }
    }

    /// Stage indices that carry mergeable state (probe: they answer
    /// [`Transform::stats_snapshot`]).
    pub fn stateful_stages(&self) -> Vec<usize> {
        self.transforms
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.stats_snapshot().map(|_| i))
            .collect()
    }

    /// Take the drift-gate signal of `stage` from its last transform
    /// (see [`Transform::drift_signal`] — take-semantics, one sample
    /// per real observation).
    pub fn drift_signal(&mut self, stage: usize) -> Option<f64> {
        self.transforms.get_mut(stage).and_then(|t| t.drift_signal())
    }

    /// Aggregator side: fold a shard's delta for `stage` into the master.
    pub fn stats_merge(&mut self, stage: usize, payload: &[f64]) {
        if let Some(t) = self.transforms.get_mut(stage) {
            t.stats_merge(payload);
        }
    }

    /// Full-state snapshot of `stage` (`None` for stateless stages or
    /// out-of-range indices).
    pub fn stats_snapshot(&self, stage: usize) -> Option<Vec<f64>> {
        self.transforms.get(stage).and_then(|t| t.stats_snapshot())
    }

    /// Shard side: adopt the broadcast global state for `stage`.
    pub fn stats_apply(&mut self, stage: usize, payload: &[f64]) {
        if let Some(t) = self.transforms.get_mut(stage) {
            t.stats_apply(payload);
        }
    }
}

impl Default for Pipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Transform for Pipeline {
    fn bind(&mut self, input: &Schema) -> Schema {
        let mut schema = input.clone();
        for t in &mut self.transforms {
            schema = t.bind(&schema);
        }
        self.output = Some(schema.clone());
        schema
    }

    fn transform(&mut self, inst: Instance) -> Option<Instance> {
        let mut cur = inst;
        for t in &mut self.transforms {
            cur = t.transform(cur)?;
        }
        Some(cur)
    }

    /// Propagate to every stage (nested pipelines included), so enabling
    /// tracking on the outer pipeline reaches all gated operators.
    fn track_drift_signal(&mut self, on: bool) {
        for t in &mut self.transforms {
            t.track_drift_signal(on);
        }
    }

    fn name(&self) -> &'static str {
        "pipeline"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.transforms.iter().map(|t| t.mem_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Label;
    use crate::core::AttributeKind;
    use crate::preprocess::{Discretizer, FeatureHasher, StandardScaler};

    #[test]
    fn schema_rewrites_chain() {
        let schema = Schema::classification("src", Schema::all_numeric(100), 3);
        let mut p = Pipeline::new()
            .then(FeatureHasher::new(32))
            .then(StandardScaler::new())
            .then(Discretizer::new(5));
        let out = p.bind(&schema);
        assert_eq!(out.n_attributes(), 32);
        assert_eq!(out.attributes[0], AttributeKind::Categorical { n_values: 5 });
        assert_eq!(out.n_classes(), 3);
        assert_eq!(p.output_schema().n_attributes(), 32);
        assert_eq!(p.stage_names(), vec!["feature-hasher", "standard-scaler", "discretizer"]);
    }

    #[test]
    fn instances_flow_through_all_stages() {
        let schema = Schema::classification("src", Schema::all_numeric(10), 2);
        let mut p = Pipeline::new().then(FeatureHasher::new(4)).then(Discretizer::new(3));
        p.bind(&schema);
        for n in 0..300 {
            let vals: Vec<f32> = (0..10).map(|j| (n * j) as f32 * 0.1).collect();
            let out = p.transform(Instance::dense(vals, Label::Class(0))).unwrap();
            assert_eq!(out.n_attributes(), 4);
            for j in 0..4 {
                assert!(out.value(j) < 3.0);
            }
        }
    }

    #[test]
    fn nested_pipelines() {
        let schema = Schema::classification("src", Schema::all_numeric(8), 2);
        let inner = Pipeline::new().then(StandardScaler::new());
        let mut outer = Pipeline::new().then(inner).then(Discretizer::new(4));
        let out = outer.bind(&schema);
        assert_eq!(out.attributes[7], AttributeKind::Categorical { n_values: 4 });
        let i = outer.transform(Instance::dense(vec![1.0; 8], Label::None)).unwrap();
        assert_eq!(i.n_attributes(), 8);
    }
}
