//! Streaming equal-frequency discretization, PiD-style (Gama & Pinto's
//! Partition Incremental Discretization): a fine-grained layer-1 summary
//! per attribute feeds quantile queries; the layer-2 output is the
//! equal-frequency bin index, so downstream learners see a categorical
//! attribute with `k` values.
//!
//! Layer 1 is an exact buffer for the first `warmup` values (the range is
//! unknown at stream start), then an equal-width histogram over the warmup
//! range with out-of-range values clamped into the edge cells. Memory per
//! attribute is O(warmup + fine_bins), independent of stream length.
//!
//! Rank queries are served from a Fenwick (binary indexed) tree over the
//! fine cells: O(log fine) per query and per insert, instead of the
//! O(fine) prefix scan of the naive layout ([`Discretizer::rank_naive`]
//! keeps that path as the reference for tests and benches). The tree is
//! rebuilt wholesale on merge/deserialize.
//!
//! The per-attribute summaries are **mergeable**
//! ([`super::merge::MergeableState`]): equal-range histograms add
//! pointwise (exact); differing ranges re-bin by cell center into the
//! union range (approximate, within one fine cell); unfrozen buffers
//! concatenate. Under `p > 1` shards the delta-sync protocol ships
//! pending summaries so every shard converges to shared cut points.
//!
//! Sparse handling: like the scalers, absent attributes are "not
//! observed" — only stored values are summarized and rewritten, and an
//! absent attribute still reads as 0 downstream, i.e. it aliases with
//! the lowest-quantile bin. The same data piped dense vs sparse can
//! therefore discretize differently around value 0; discretization is
//! meant for dense numeric streams (waveform, covtype), while sparse
//! bag-of-words streams should be hashed dense first.

use crate::common::memsize::vec_flat_bytes;
use crate::core::instance::Values;
use crate::core::{AttributeKind, Instance, Schema};

use super::merge::MergeableState;
use super::Transform;

/// Point update: add `delta` to cell `i` (0-based).
fn fenwick_update(tree: &mut [f64], i: usize, delta: f64) {
    let mut i = i + 1;
    while i <= tree.len() {
        tree[i - 1] += delta;
        i += i & i.wrapping_neg();
    }
}

/// Prefix sum of cells `[0, i)`.
fn fenwick_prefix(tree: &[f64], i: usize) -> f64 {
    let mut i = i.min(tree.len());
    let mut s = 0.0;
    while i > 0 {
        s += tree[i - 1];
        i -= i & i.wrapping_neg();
    }
    s
}

fn fenwick_build(counts: &[f64]) -> Vec<f64> {
    let mut tree = vec![0.0; counts.len()];
    for (i, &c) in counts.iter().enumerate() {
        if c != 0.0 {
            fenwick_update(&mut tree, i, c);
        }
    }
    tree
}

/// Per-attribute layer-1 quantile summary.
#[derive(Clone, Debug)]
struct AttrSummary {
    /// Exact values until the histogram is frozen.
    buffer: Vec<f32>,
    /// Equal-width histogram over [lo, hi] after warmup (empty before).
    counts: Vec<f64>,
    /// Fenwick tree mirroring `counts` for O(log fine) prefix sums.
    fenwick: Vec<f64>,
    lo: f64,
    hi: f64,
    n: f64,
}

impl AttrSummary {
    fn new() -> Self {
        AttrSummary {
            buffer: Vec::new(),
            counts: Vec::new(),
            fenwick: Vec::new(),
            lo: 0.0,
            hi: 0.0,
            n: 0.0,
        }
    }

    fn frozen(&self) -> bool {
        !self.counts.is_empty()
    }

    fn freeze(&mut self, fine: usize) {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &self.buffer {
            lo = lo.min(v as f64);
            hi = hi.max(v as f64);
        }
        // Widen 10% each side so near-range values don't all clamp.
        let pad = (hi - lo).max(1e-9) * 0.1;
        self.lo = lo - pad;
        self.hi = hi + pad;
        self.counts = vec![0.0; fine];
        let buffer = std::mem::take(&mut self.buffer);
        for &v in &buffer {
            let c = self.cell(v as f64);
            self.counts[c] += 1.0;
        }
        self.fenwick = fenwick_build(&self.counts);
    }

    #[inline]
    fn cell(&self, x: f64) -> usize {
        let fine = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        ((t * fine as f64) as isize).clamp(0, fine as isize - 1) as usize
    }

    fn add(&mut self, x: f64, warmup: usize, fine: usize) {
        self.n += 1.0;
        if self.frozen() {
            let c = self.cell(x);
            self.counts[c] += 1.0;
            fenwick_update(&mut self.fenwick, c, 1.0);
        } else {
            self.buffer.push(x as f32);
            if self.buffer.len() >= warmup {
                self.freeze(fine);
            }
        }
    }

    /// Approximate rank of `x` in [0, 1]; O(log fine) once frozen.
    fn rank(&self, x: f64) -> f64 {
        if self.n < 1.0 {
            return 0.0;
        }
        if !self.frozen() {
            let below = self.buffer.iter().filter(|&&v| (v as f64) < x).count();
            return below as f64 / self.buffer.len() as f64;
        }
        let c = self.cell(x);
        let below = fenwick_prefix(&self.fenwick, c);
        self.interpolated(x, c, below)
    }

    /// Reference rank with the O(fine) prefix scan (tests/benches).
    fn rank_naive(&self, x: f64) -> f64 {
        if self.n < 1.0 {
            return 0.0;
        }
        if !self.frozen() {
            let below = self.buffer.iter().filter(|&&v| (v as f64) < x).count();
            return below as f64 / self.buffer.len() as f64;
        }
        let c = self.cell(x);
        let below: f64 = self.counts[..c].iter().sum();
        self.interpolated(x, c, below)
    }

    /// Linear interpolation inside cell `c` given the mass `below` it.
    fn interpolated(&self, x: f64, c: usize, below: f64) -> f64 {
        let fine = self.counts.len();
        let cell_lo = self.lo + (self.hi - self.lo) * c as f64 / fine as f64;
        let cell_w = (self.hi - self.lo) / fine as f64;
        let frac = ((x - cell_lo) / cell_w).clamp(0.0, 1.0);
        (below + frac * self.counts[c]) / self.n
    }

    /// Histogram merge. Equal-range frozen summaries add pointwise
    /// (exact); differing ranges re-bin each source cell's mass at its
    /// center into the union range; unfrozen buffers concatenate (and
    /// freeze once the combined buffer reaches `warmup`).
    fn merge(&mut self, other: &AttrSummary, warmup: usize, fine: usize) {
        if other.n == 0.0 {
            return;
        }
        if self.n == 0.0 {
            *self = other.clone();
            return;
        }
        match (self.frozen(), other.frozen()) {
            (false, false) => {
                self.buffer.extend_from_slice(&other.buffer);
                self.n += other.n;
                if self.buffer.len() >= warmup {
                    self.freeze(fine);
                }
            }
            (true, false) => {
                for &v in &other.buffer {
                    let c = self.cell(v as f64);
                    self.counts[c] += 1.0;
                    fenwick_update(&mut self.fenwick, c, 1.0);
                }
                self.n += other.n;
            }
            (false, true) => {
                let buffer = std::mem::take(&mut self.buffer);
                let my_n = self.n;
                *self = other.clone();
                self.n += my_n;
                for &v in &buffer {
                    let c = self.cell(v as f64);
                    self.counts[c] += 1.0;
                    fenwick_update(&mut self.fenwick, c, 1.0);
                }
            }
            (true, true) => {
                if self.lo == other.lo
                    && self.hi == other.hi
                    && self.counts.len() == other.counts.len()
                {
                    // identical layout: pointwise (exact, associative);
                    // Fenwick trees are linear in the counts, so they add
                    // elementwise too.
                    for (c, o) in self.counts.iter_mut().zip(&other.counts) {
                        *c += o;
                    }
                    for (f, o) in self.fenwick.iter_mut().zip(&other.fenwick) {
                        *f += o;
                    }
                } else {
                    let lo = self.lo.min(other.lo);
                    let hi = self.hi.max(other.hi);
                    let cells = self.counts.len().max(other.counts.len());
                    let mut counts = vec![0.0; cells];
                    for src in [&*self, other] {
                        let w = (src.hi - src.lo) / src.counts.len() as f64;
                        for (c, &m) in src.counts.iter().enumerate() {
                            if m > 0.0 {
                                let center = src.lo + (c as f64 + 0.5) * w;
                                let t = ((center - lo) / (hi - lo) * cells as f64) as isize;
                                counts[t.clamp(0, cells as isize - 1) as usize] += m;
                            }
                        }
                    }
                    self.lo = lo;
                    self.hi = hi;
                    self.fenwick = fenwick_build(&counts);
                    self.counts = counts;
                }
                self.n += other.n;
            }
        }
    }

    /// Flat encoding: `[frozen, n, lo, hi, len, data...]` where `data` is
    /// the buffer (unfrozen) or the counts (frozen).
    fn encode(&self, out: &mut Vec<f64>) {
        let frozen = self.frozen();
        out.push(if frozen { 1.0 } else { 0.0 });
        out.push(self.n);
        out.push(self.lo);
        out.push(self.hi);
        if frozen {
            out.push(self.counts.len() as f64);
            out.extend_from_slice(&self.counts);
        } else {
            out.push(self.buffer.len() as f64);
            out.extend(self.buffer.iter().map(|&v| v as f64));
        }
    }

    /// Decode one summary starting at `payload[*pos]`; advances `pos`.
    /// Returns `None` (leaving `pos` unusable) on malformed input.
    fn decode(payload: &[f64], pos: &mut usize) -> Option<AttrSummary> {
        if payload.len() < *pos + 5 {
            return None;
        }
        let frozen = payload[*pos] != 0.0;
        let n = payload[*pos + 1];
        let lo = payload[*pos + 2];
        let hi = payload[*pos + 3];
        let len = payload[*pos + 4] as usize;
        *pos += 5;
        if payload.len() < *pos + len {
            return None;
        }
        let data = &payload[*pos..*pos + len];
        *pos += len;
        let mut s = AttrSummary::new();
        s.n = n;
        s.lo = lo;
        s.hi = hi;
        if frozen {
            s.counts = data.to_vec();
            s.fenwick = fenwick_build(&s.counts);
        } else {
            s.buffer = data.iter().map(|&v| v as f32).collect();
        }
        Some(s)
    }

    fn bytes(&self) -> usize {
        std::mem::size_of::<AttrSummary>()
            + vec_flat_bytes(&self.buffer)
            + vec_flat_bytes(&self.counts)
            + vec_flat_bytes(&self.fenwick)
    }
}

/// Equal-frequency discretizer: numeric attributes become
/// `Categorical { n_values: k }`, the emitted value being the bin index.
pub struct Discretizer {
    k: u32,
    warmup: usize,
    fine: usize,
    /// Transform-side summaries (global ⊕ pending after a sync).
    summaries: Vec<Option<AttrSummary>>,
    /// Increment since the last `stats_delta` emission.
    pending: Vec<Option<AttrSummary>>,
    /// Compute the drift signal per instance (off = zero hot-path cost).
    track_signal: bool,
    /// Mean normalized bin index of the last instance — ≈ 0.5 while the
    /// cut points fit the stream (equal-frequency bins are uniform),
    /// skewed toward 0/1 under drift. The per-stage drift-gate signal.
    last_signal: Option<f64>,
}

impl Discretizer {
    /// `k` output bins with default layer-1 resolution (256-value warmup,
    /// 128 fine cells).
    pub fn new(k: u32) -> Self {
        Self::with_resolution(k, 256, 128)
    }

    pub fn with_resolution(k: u32, warmup: usize, fine: usize) -> Self {
        assert!(k >= 2, "need at least 2 bins");
        assert!(warmup >= 2 && fine >= k as usize);
        Discretizer {
            k,
            warmup,
            fine,
            summaries: Vec::new(),
            pending: Vec::new(),
            track_signal: false,
            last_signal: None,
        }
    }

    /// Bin index for attribute `j` and raw value `x` under current stats.
    #[inline]
    fn bin(&self, j: usize, x: f64) -> u32 {
        match &self.summaries[j] {
            Some(s) => ((s.rank(x) * self.k as f64) as u32).min(self.k - 1),
            None => 0,
        }
    }

    /// Approximate rank of `x` on attribute `j` in [0, 1] (Fenwick path;
    /// 0.0 for categorical attributes). Diagnostics/benches.
    pub fn rank(&self, j: usize, x: f64) -> f64 {
        self.summaries[j].as_ref().map_or(0.0, |s| s.rank(x))
    }

    /// Reference rank via the O(fine) prefix scan — must agree with
    /// [`Discretizer::rank`] exactly up to f64 summation order.
    pub fn rank_naive(&self, j: usize, x: f64) -> f64 {
        self.summaries[j].as_ref().map_or(0.0, |s| s.rank_naive(x))
    }

    /// Encode a summary set (shared by delta/snapshot paths). With
    /// `skip_empty`, summaries that saw no observations encode as absent
    /// — the per-attribute presence flags then act as the changed-column
    /// bitmask of the sparse delta form (see [`super::wire`]), shrinking
    /// pending increments to the attributes that actually changed.
    fn encode_set_filtered(set: &[Option<AttrSummary>], skip_empty: bool) -> Vec<f64> {
        let mut out = Vec::new();
        for s in set {
            match s {
                Some(s) if !(skip_empty && s.n == 0.0) => {
                    out.push(1.0);
                    s.encode(&mut out);
                }
                _ => out.push(0.0),
            }
        }
        out
    }

    /// Dense encoding (every stateful attribute present).
    fn encode_set(set: &[Option<AttrSummary>]) -> Vec<f64> {
        Self::encode_set_filtered(set, false)
    }

    /// Decode a payload produced by [`Discretizer::encode_set`]. Returns
    /// `None` on malformed input.
    fn decode_set(payload: &[f64]) -> Option<Vec<Option<AttrSummary>>> {
        let mut set = Vec::new();
        let mut pos = 0;
        while pos < payload.len() {
            let present = payload[pos] != 0.0;
            pos += 1;
            if present {
                set.push(Some(AttrSummary::decode(payload, &mut pos)?));
            } else {
                set.push(None);
            }
        }
        Some(set)
    }

    fn merge_sets(
        dst: &mut [Option<AttrSummary>],
        src: &[Option<AttrSummary>],
        warmup: usize,
        fine: usize,
    ) {
        for (d, s) in dst.iter_mut().zip(src) {
            if let (Some(d), Some(s)) = (d.as_mut(), s.as_ref()) {
                d.merge(s, warmup, fine);
            }
        }
    }

    fn fresh_set(&self) -> Vec<Option<AttrSummary>> {
        self.summaries
            .iter()
            .map(|s| s.as_ref().map(|_| AttrSummary::new()))
            .collect()
    }
}

impl MergeableState for Discretizer {
    fn merge(&mut self, other: &Self) {
        let (warmup, fine) = (self.warmup, self.fine);
        Self::merge_sets(&mut self.summaries, &other.summaries, warmup, fine);
    }

    fn delta(&self) -> Vec<f64> {
        Self::encode_set(&self.summaries)
    }

    fn apply_delta(&mut self, payload: &[f64]) {
        if let Some(set) = Self::decode_set(payload) {
            if set.len() == self.summaries.len() {
                self.summaries = set;
            }
        }
    }

    fn reset(&mut self) {
        self.summaries = self.fresh_set();
        self.pending = self.fresh_set();
    }
}

impl Transform for Discretizer {
    fn bind(&mut self, input: &Schema) -> Schema {
        self.summaries = input
            .attributes
            .iter()
            .map(|a| matches!(a, AttributeKind::Numeric).then(AttrSummary::new))
            .collect();
        self.pending = self.fresh_set();
        input.with_attributes(
            &format!("{}|discretize{}", input.name, self.k),
            input
                .attributes
                .iter()
                .map(|a| match a {
                    AttributeKind::Numeric => AttributeKind::Categorical { n_values: self.k },
                    c => c.clone(),
                })
                .collect(),
        )
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        let (warmup, fine) = (self.warmup, self.fine);
        let (mut sig_sum, mut sig_n) = (0.0f64, 0u32);
        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    let x = *val as f64;
                    if let Some(s) = &mut self.summaries[j] {
                        s.add(x, warmup, fine);
                    } else {
                        continue;
                    }
                    if let Some(p) = &mut self.pending[j] {
                        p.add(x, warmup, fine);
                    }
                    let b = self.bin(j, x);
                    if self.track_signal {
                        sig_sum += b as f64 / (self.k - 1) as f64;
                        sig_n += 1;
                    }
                    *val = b as f32;
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    let x = *val as f64;
                    if let Some(s) = &mut self.summaries[j] {
                        s.add(x, warmup, fine);
                    } else {
                        continue;
                    }
                    if let Some(p) = &mut self.pending[j] {
                        p.add(x, warmup, fine);
                    }
                    let b = self.bin(j, x);
                    if self.track_signal {
                        sig_sum += b as f64 / (self.k - 1) as f64;
                        sig_n += 1;
                    }
                    *val = b as f32;
                }
            }
        }
        if sig_n > 0 {
            self.last_signal = Some(sig_sum / sig_n as f64);
        }
        Some(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        // sparse: attributes untouched since the last emission encode as
        // absent (strictly no larger than the dense form)
        let payload = Self::encode_set_filtered(&self.pending, true);
        self.pending = self.fresh_set();
        Some(payload)
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        let payload = Self::encode_set(&self.pending);
        self.pending = self.fresh_set();
        Some(payload)
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        if let Some(set) = Self::decode_set(payload) {
            if set.len() == self.summaries.len() {
                let (warmup, fine) = (self.warmup, self.fine);
                Self::merge_sets(&mut self.summaries, &set, warmup, fine);
            }
        }
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        Some(Self::encode_set(&self.summaries))
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        if let Some(mut set) = Self::decode_set(payload) {
            if set.len() == self.summaries.len() {
                let (warmup, fine) = (self.warmup, self.fine);
                Self::merge_sets(&mut set, &self.pending, warmup, fine);
                self.summaries = set;
            }
        }
    }

    fn track_drift_signal(&mut self, on: bool) {
        self.track_signal = on;
    }

    fn drift_signal(&mut self) -> Option<f64> {
        self.last_signal.take()
    }

    fn name(&self) -> &'static str {
        "discretizer"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .summaries
                .iter()
                .chain(self.pending.iter())
                .flatten()
                .map(AttrSummary::bytes)
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    fn occupancy(dist: &str, k: u32) -> Vec<u64> {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut d = Discretizer::new(k);
        d.bind(&schema);
        let mut rng = Rng::new(11);
        let mut occ = vec![0u64; k as usize];
        for i in 0..12_000 {
            let x = match dist {
                "uniform" => rng.f64() * 40.0 - 7.0,
                _ => rng.gaussian() * 3.0 + 1.0,
            };
            let out = d.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let b = out.value(0) as usize;
            assert!(b < k as usize);
            if i >= 2000 {
                occ[b] += 1; // skip the adaptation prefix
            }
        }
        occ
    }

    #[test]
    fn equal_frequency_on_uniform() {
        let occ = occupancy("uniform", 8);
        let total: u64 = occ.iter().sum();
        let expect = total as f64 / 8.0;
        for (b, &c) in occ.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.7 && (c as f64) < expect * 1.3,
                "bin {b}: {c} vs expected {expect} ({occ:?})"
            );
        }
    }

    #[test]
    fn equal_frequency_on_gaussian() {
        // equal-frequency (not equal-width): a skew-free gaussian must
        // still fill every bin roughly evenly
        let occ = occupancy("gaussian", 6);
        let total: u64 = occ.iter().sum();
        let expect = total as f64 / 6.0;
        for (b, &c) in occ.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.6 && (c as f64) < expect * 1.4,
                "bin {b}: {c} vs expected {expect} ({occ:?})"
            );
        }
    }

    #[test]
    fn schema_becomes_categorical() {
        let schema = Schema::classification("t", Schema::all_numeric(3), 2);
        let mut d = Discretizer::new(4);
        let out = d.bind(&schema);
        for a in &out.attributes {
            assert_eq!(*a, AttributeKind::Categorical { n_values: 4 });
        }
        assert_eq!(out.n_classes(), 2);
    }

    #[test]
    fn categorical_input_passes_through() {
        let schema = Schema::classification("t", Schema::all_categorical(1, 3), 2);
        let mut d = Discretizer::new(4);
        let out = d.bind(&schema);
        assert_eq!(out.attributes, schema.attributes);
        let i = d.transform(Instance::dense(vec![2.0], Label::None)).unwrap();
        assert_eq!(i.value(0), 2.0);
    }

    #[test]
    fn fenwick_rank_matches_naive_scan() {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut d = Discretizer::with_resolution(8, 64, 256);
        d.bind(&schema);
        let mut rng = Rng::new(21);
        for _ in 0..5000 {
            let x = rng.gaussian() * 4.0;
            d.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let q = rng.gaussian() * 5.0;
            let (fast, slow) = (d.rank(0, q), d.rank_naive(0, q));
            assert!(
                (fast - slow).abs() < 1e-9,
                "fenwick rank {fast} != naive {slow} at {q}"
            );
        }
    }

    #[test]
    fn merge_equal_ranges_is_exact() {
        // two summaries frozen over the same warmup data: merging doubles
        // every count, leaving ranks unchanged
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mk = || {
            let mut d = Discretizer::with_resolution(4, 16, 32);
            d.bind(&schema);
            let mut rng = Rng::new(3);
            for _ in 0..500 {
                let x = rng.f64() * 10.0;
                d.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            }
            d
        };
        let (mut a, b) = (mk(), mk());
        let before = a.rank(0, 5.0);
        a.merge(&b);
        assert!((a.rank(0, 5.0) - before).abs() < 1e-9);
        assert!((a.rank(0, 5.0) - a.rank_naive(0, 5.0)).abs() < 1e-9);
    }

    /// Untouched attributes vanish from the pending delta (sparse form)
    /// but the aggregator-side merge result is identical.
    #[test]
    fn sparse_pending_delta_skips_untouched_attributes() {
        let schema = Schema::classification("t", Schema::all_numeric(3), 2);
        let mk = || {
            let mut d = Discretizer::with_resolution(4, 8, 16);
            d.bind(&schema);
            d
        };
        let (mut d_sparse, mut d_dense) = (mk(), mk());
        for i in 0..40 {
            let inst = Instance::sparse(vec![0], vec![i as f32 * 0.1], 3, Label::None);
            d_sparse.transform(inst.clone()).unwrap();
            d_dense.transform(inst).unwrap();
        }
        let sparse = Transform::stats_delta(&mut d_sparse).unwrap();
        let dense = Transform::stats_delta_dense(&mut d_dense).unwrap();
        assert!(sparse.len() < dense.len(), "{} !< {}", sparse.len(), dense.len());
        // both forms merge identically into a master
        let (mut ma, mut mb) = (mk(), mk());
        ma.stats_merge(&sparse);
        mb.stats_merge(&dense);
        assert_eq!(
            Transform::stats_snapshot(&ma).unwrap(),
            Transform::stats_snapshot(&mb).unwrap()
        );
    }

    #[test]
    fn delta_round_trip_preserves_ranks() {
        let schema = Schema::classification("t", Schema::all_numeric(2), 2);
        let mut d = Discretizer::with_resolution(4, 16, 32);
        d.bind(&schema);
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let (x, y) = (rng.f64() * 4.0, rng.gaussian());
            d.transform(Instance::dense(vec![x as f32, y as f32], Label::None))
                .unwrap();
        }
        let mut e = Discretizer::with_resolution(4, 16, 32);
        e.bind(&schema);
        e.apply_delta(&d.delta());
        for q in [-1.0, 0.5, 2.0, 3.9] {
            assert!((d.rank(0, q) - e.rank(0, q)).abs() < 1e-9);
            assert!((d.rank(1, q) - e.rank(1, q)).abs() < 1e-9);
        }
    }
}
