//! Streaming equal-frequency discretization, PiD-style (Gama & Pinto's
//! Partition Incremental Discretization): a fine-grained layer-1 summary
//! per attribute feeds quantile queries; the layer-2 output is the
//! equal-frequency bin index, so downstream learners see a categorical
//! attribute with `k` values.
//!
//! Layer 1 is an exact buffer for the first `warmup` values (the range is
//! unknown at stream start), then an equal-width histogram over the warmup
//! range with out-of-range values clamped into the edge cells. Memory per
//! attribute is O(warmup + fine_bins), independent of stream length.
//!
//! Sparse handling: like the scalers, absent attributes are "not
//! observed" — only stored values are summarized and rewritten, and an
//! absent attribute still reads as 0 downstream, i.e. it aliases with
//! the lowest-quantile bin. The same data piped dense vs sparse can
//! therefore discretize differently around value 0; discretization is
//! meant for dense numeric streams (waveform, covtype), while sparse
//! bag-of-words streams should be hashed dense first.

use crate::common::memsize::vec_flat_bytes;
use crate::core::instance::Values;
use crate::core::{AttributeKind, Instance, Schema};

use super::Transform;

/// Per-attribute layer-1 quantile summary.
struct AttrSummary {
    /// Exact values until the histogram is frozen.
    buffer: Vec<f32>,
    /// Equal-width histogram over [lo, hi] after warmup (empty before).
    counts: Vec<f64>,
    lo: f64,
    hi: f64,
    n: f64,
}

impl AttrSummary {
    fn new() -> Self {
        AttrSummary { buffer: Vec::new(), counts: Vec::new(), lo: 0.0, hi: 0.0, n: 0.0 }
    }

    fn frozen(&self) -> bool {
        !self.counts.is_empty()
    }

    fn freeze(&mut self, fine: usize) {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &self.buffer {
            lo = lo.min(v as f64);
            hi = hi.max(v as f64);
        }
        // Widen 10% each side so near-range values don't all clamp.
        let pad = (hi - lo).max(1e-9) * 0.1;
        self.lo = lo - pad;
        self.hi = hi + pad;
        self.counts = vec![0.0; fine];
        let buffer = std::mem::take(&mut self.buffer);
        for &v in &buffer {
            let c = self.cell(v as f64);
            self.counts[c] += 1.0;
        }
    }

    #[inline]
    fn cell(&self, x: f64) -> usize {
        let fine = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        ((t * fine as f64) as isize).clamp(0, fine as isize - 1) as usize
    }

    fn add(&mut self, x: f64, warmup: usize, fine: usize) {
        self.n += 1.0;
        if self.frozen() {
            let c = self.cell(x);
            self.counts[c] += 1.0;
        } else {
            self.buffer.push(x as f32);
            if self.buffer.len() >= warmup {
                self.freeze(fine);
            }
        }
    }

    /// Approximate rank of `x` in [0, 1].
    fn rank(&self, x: f64) -> f64 {
        if self.n < 1.0 {
            return 0.0;
        }
        if !self.frozen() {
            let below = self.buffer.iter().filter(|&&v| (v as f64) < x).count();
            return below as f64 / self.buffer.len() as f64;
        }
        let c = self.cell(x);
        let below: f64 = self.counts[..c].iter().sum();
        // linear interpolation inside the cell
        let fine = self.counts.len();
        let cell_lo = self.lo + (self.hi - self.lo) * c as f64 / fine as f64;
        let cell_w = (self.hi - self.lo) / fine as f64;
        let frac = ((x - cell_lo) / cell_w).clamp(0.0, 1.0);
        (below + frac * self.counts[c]) / self.n
    }
}

/// Equal-frequency discretizer: numeric attributes become
/// `Categorical { n_values: k }`, the emitted value being the bin index.
pub struct Discretizer {
    k: u32,
    warmup: usize,
    fine: usize,
    summaries: Vec<Option<AttrSummary>>,
}

impl Discretizer {
    /// `k` output bins with default layer-1 resolution (256-value warmup,
    /// 128 fine cells).
    pub fn new(k: u32) -> Self {
        Self::with_resolution(k, 256, 128)
    }

    pub fn with_resolution(k: u32, warmup: usize, fine: usize) -> Self {
        assert!(k >= 2, "need at least 2 bins");
        assert!(warmup >= 2 && fine >= k as usize);
        Discretizer { k, warmup, fine, summaries: Vec::new() }
    }

    /// Bin index for attribute `j` and raw value `x` under current stats.
    #[inline]
    fn bin(&self, j: usize, x: f64) -> u32 {
        match &self.summaries[j] {
            Some(s) => ((s.rank(x) * self.k as f64) as u32).min(self.k - 1),
            None => 0,
        }
    }
}

impl Transform for Discretizer {
    fn bind(&mut self, input: &Schema) -> Schema {
        self.summaries = input
            .attributes
            .iter()
            .map(|a| matches!(a, AttributeKind::Numeric).then(AttrSummary::new))
            .collect();
        input.with_attributes(
            &format!("{}|discretize{}", input.name, self.k),
            input
                .attributes
                .iter()
                .map(|a| match a {
                    AttributeKind::Numeric => AttributeKind::Categorical { n_values: self.k },
                    c => c.clone(),
                })
                .collect(),
        )
    }

    fn transform(&mut self, mut inst: Instance) -> Option<Instance> {
        let (warmup, fine) = (self.warmup, self.fine);
        match &mut inst.values {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    let x = *val as f64;
                    if let Some(s) = &mut self.summaries[j] {
                        s.add(x, warmup, fine);
                    } else {
                        continue;
                    }
                    *val = self.bin(j, x) as f32;
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    let j = j as usize;
                    let x = *val as f64;
                    if let Some(s) = &mut self.summaries[j] {
                        s.add(x, warmup, fine);
                    } else {
                        continue;
                    }
                    *val = self.bin(j, x) as f32;
                }
            }
        }
        Some(inst)
    }

    fn name(&self) -> &'static str {
        "discretizer"
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .summaries
                .iter()
                .flatten()
                .map(|s| {
                    std::mem::size_of::<AttrSummary>()
                        + vec_flat_bytes(&s.buffer)
                        + vec_flat_bytes(&s.counts)
                })
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    fn occupancy(dist: &str, k: u32) -> Vec<u64> {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut d = Discretizer::new(k);
        d.bind(&schema);
        let mut rng = Rng::new(11);
        let mut occ = vec![0u64; k as usize];
        for i in 0..12_000 {
            let x = match dist {
                "uniform" => rng.f64() * 40.0 - 7.0,
                _ => rng.gaussian() * 3.0 + 1.0,
            };
            let out = d.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let b = out.value(0) as usize;
            assert!(b < k as usize);
            if i >= 2000 {
                occ[b] += 1; // skip the adaptation prefix
            }
        }
        occ
    }

    #[test]
    fn equal_frequency_on_uniform() {
        let occ = occupancy("uniform", 8);
        let total: u64 = occ.iter().sum();
        let expect = total as f64 / 8.0;
        for (b, &c) in occ.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.7 && (c as f64) < expect * 1.3,
                "bin {b}: {c} vs expected {expect} ({occ:?})"
            );
        }
    }

    #[test]
    fn equal_frequency_on_gaussian() {
        // equal-frequency (not equal-width): a skew-free gaussian must
        // still fill every bin roughly evenly
        let occ = occupancy("gaussian", 6);
        let total: u64 = occ.iter().sum();
        let expect = total as f64 / 6.0;
        for (b, &c) in occ.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.6 && (c as f64) < expect * 1.4,
                "bin {b}: {c} vs expected {expect} ({occ:?})"
            );
        }
    }

    #[test]
    fn schema_becomes_categorical() {
        let schema = Schema::classification("t", Schema::all_numeric(3), 2);
        let mut d = Discretizer::new(4);
        let out = d.bind(&schema);
        for a in &out.attributes {
            assert_eq!(*a, AttributeKind::Categorical { n_values: 4 });
        }
        assert_eq!(out.n_classes(), 2);
    }

    #[test]
    fn categorical_input_passes_through() {
        let schema = Schema::classification("t", Schema::all_categorical(1, 3), 2);
        let mut d = Discretizer::new(4);
        let out = d.bind(&schema);
        assert_eq!(out.attributes, schema.attributes);
        let i = d.transform(Instance::dense(vec![2.0], Label::None)).unwrap();
        assert_eq!(i.value(0), 2.0);
    }
}
