//! Mergeable operator state — the property that makes parallel pipelines
//! converge (paper §6: the VHT local-stat aggregators keep *mergeable*
//! sufficient statistics; Benczúr et al. 2018 survey the same idea for
//! general distributed online learning).
//!
//! A [`MergeableState`] is a bounded-memory summary with a commutative,
//! associative (up to f64 rounding where the summary is exact, up to the
//! summary's own approximation bound where it is not) binary `merge`, an
//! identity element (`reset`), and a flat serialization (`delta` /
//! `apply_delta`) so it can ride inside topology event payloads.
//!
//! The delta-sync protocol built on top (see
//! [`super::sync::StatsSyncProcessor`]) ships each shard's *pending*
//! increment — the state accumulated since the shard's last emission —
//! to an aggregator, which merges every increment into a master state
//! exactly once and broadcasts the merged snapshot back. Because `merge`
//! is commutative and associative, the master converges to the same
//! state regardless of shard count or arrival order; `tests/merge_properties.rs`
//! pins those laws for every implementation in this crate:
//!
//! * [`super::scalers::StandardScaler`] — Chan/parallel-Welford moment
//!   merge (exact up to f64 rounding),
//! * [`super::scalers::MinMaxScaler`] — elementwise min/max (exact,
//!   idempotent),
//! * [`super::discretize::Discretizer`] — fine-bin histogram merge
//!   (exact while ranges agree; re-bins by cell center otherwise),
//! * [`super::sketch::CountMinSketch`] — pointwise counter addition
//!   (exact),
//! * [`super::sketch::MisraGries`] — counter addition + (k+1)-th-largest
//!   decrement (the Agarwal et al. mergeable-summary rule; estimates stay
//!   within the composed N/k bound).

/// Bounded-memory summary with a merge operation.
///
/// Laws (checked by `tests/merge_properties.rs`):
/// * **commutativity** — `a.merge(&b)` and `b.merge(&a)` yield equal
///   states (identical `delta()` payloads up to f64 tolerance);
/// * **associativity** — `(a ⊕ b) ⊕ c` equals `a ⊕ (b ⊕ c)` exactly for
///   exact summaries (moments, min/max, CountMin, equal-range
///   histograms), and within the summary's approximation bound for lossy
///   ones (Misra-Gries, re-binned histograms);
/// * **identity** — merging a `reset()` state is a no-op;
/// * **round trip** — `apply_delta(&delta())` reproduces the state.
pub trait MergeableState {
    /// Fold `other`'s state into `self`. Both sides must be configured
    /// identically (same dimensionality / width / depth / bin layout) —
    /// shards built by the same pipeline factory always are.
    fn merge(&mut self, other: &Self);

    /// Serialize the full mergeable state as a flat `f64` payload (the
    /// wire format of `Event::StatsDelta` / `Event::StatsGlobal`).
    fn delta(&self) -> Vec<f64>;

    /// Rebuild state from a payload produced by [`MergeableState::delta`].
    /// Malformed payloads are ignored (the state is left unchanged).
    fn apply_delta(&mut self, payload: &[f64]);

    /// Clear to the empty state — the identity element of `merge`.
    fn reset(&mut self);
}

/// `true` when two payloads are elementwise equal within `tol` (relative
/// for large magnitudes, absolute near zero). Shared by the property
/// tests and debug assertions.
pub fn payloads_close(a: &[f64], b: &[f64], tol: f64) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(&x, &y)| {
            let scale = x.abs().max(y.abs()).max(1.0);
            (x == y) || (x - y).abs() <= tol * scale
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payloads_close_handles_infinities_and_scale() {
        assert!(payloads_close(&[f64::INFINITY, 1.0], &[f64::INFINITY, 1.0 + 1e-12], 1e-9));
        assert!(!payloads_close(&[1.0], &[1.1], 1e-9));
        assert!(!payloads_close(&[1.0, 2.0], &[1.0], 1e-9));
        // relative comparison at large magnitude
        assert!(payloads_close(&[1e12], &[1e12 + 1.0], 1e-9));
    }
}
