//! Deterministic, dependency-free PRNG (xoshiro256**) with the samplers the
//! stream generators need (uniform, Gaussian, Poisson, choice).
//!
//! Determinism matters: the paper averages 10 differently-seeded streams per
//! configuration; our experiment harness reproduces that by seeding one
//! `Rng` per run, so every figure is replayable bit-for-bit.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box-Muller
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 expansion (any u64 seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent child stream (for per-shard/per-processor rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style; modulo bias negligible for our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Poisson(lambda) via Knuth's method — fine for the small λ (≈1) used
    /// by online bagging/boosting.
    pub fn poisson(&mut self, lambda: f64) -> u32 {
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 1000 {
                return k; // numeric safety for absurd λ
            }
        }
    }

    /// Sample an index proportionally to `weights` (need not be normalized).
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let sum: u64 = (0..n).map(|_| r.poisson(1.0) as u64).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn weighted_choice_distribution() {
        let mut r = Rng::new(8);
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..10_000 {
            if r.choice_weighted(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
