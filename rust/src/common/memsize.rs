//! Deep memory-size estimation for model state.
//!
//! Reproduces Tables 6–7 of the paper (memory consumption of MAMR/VAMR) as
//! *model state size*: the bytes held by trees, counter tables and rule
//! sets. JVM object-header overhead from the original is intentionally not
//! mimicked; DESIGN.md documents this substitution.

/// Types that can report (an estimate of) their deep heap footprint.
pub trait MemSize {
    /// Estimated bytes of owned state, including heap allocations.
    fn mem_bytes(&self) -> usize;
}

impl MemSize for f32 {
    fn mem_bytes(&self) -> usize {
        4
    }
}

impl MemSize for f64 {
    fn mem_bytes(&self) -> usize {
        8
    }
}

impl MemSize for u32 {
    fn mem_bytes(&self) -> usize {
        4
    }
}

impl MemSize for usize {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<usize>()
    }
}

impl<T: MemSize> MemSize for Vec<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.iter().map(|x| x.mem_bytes()).sum::<usize>()
            + (self.capacity() - self.len()) * std::mem::size_of::<T>()
    }
}

impl<T: MemSize> MemSize for Option<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.as_ref().map_or(0, |x| x.mem_bytes())
    }
}

impl<T: MemSize> MemSize for Box<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + (**self).mem_bytes()
    }
}

/// Helper: bytes of a flat numeric Vec (no per-element recursion).
pub fn vec_flat_bytes<T>(v: &Vec<T>) -> usize {
    std::mem::size_of::<Vec<T>>() + v.capacity() * std::mem::size_of::<T>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_of_f32() {
        let v = vec![0f32; 100];
        assert!(v.mem_bytes() >= 400);
    }

    #[test]
    fn flat_bytes_counts_capacity() {
        let mut v = Vec::with_capacity(64);
        v.push(1u64);
        assert!(vec_flat_bytes(&v) >= 64 * 8);
    }
}
