//! Deep memory-size estimation for model state.
//!
//! Reproduces Tables 6–7 of the paper (memory consumption of MAMR/VAMR) as
//! *model state size*: the bytes held by trees, counter tables and rule
//! sets. JVM object-header overhead from the original is intentionally not
//! mimicked; DESIGN.md documents this substitution.
//!
//! # Arc-shared payloads
//!
//! The zero-copy data plane shares large buffers (instance values, event
//! payloads) behind `Arc`. The accounting convention is: **each holder is
//! charged `payload / strong_count`**, so summing `mem_bytes` over every
//! holder counts the payload exactly once — a sole owner is charged in
//! full, and `k` sharers are charged `1/k` each (plus their own pointer).
//! This keeps aggregate model-state reports (Tables 6–7) honest under
//! sharing: a broadcast that reaches `p` consumers does not inflate total
//! memory `p`-fold, and the payload never silently vanishes from the
//! books either.

/// Types that can report (an estimate of) their deep heap footprint.
pub trait MemSize {
    /// Estimated bytes of owned state, including heap allocations.
    fn mem_bytes(&self) -> usize;
}

impl MemSize for f32 {
    fn mem_bytes(&self) -> usize {
        4
    }
}

impl MemSize for f64 {
    fn mem_bytes(&self) -> usize {
        8
    }
}

impl MemSize for u32 {
    fn mem_bytes(&self) -> usize {
        4
    }
}

impl MemSize for usize {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<usize>()
    }
}

impl<T: MemSize> MemSize for Vec<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.iter().map(|x| x.mem_bytes()).sum::<usize>()
            + (self.capacity() - self.len()) * std::mem::size_of::<T>()
    }
}

impl<T: MemSize> MemSize for Option<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.as_ref().map_or(0, |x| x.mem_bytes())
    }
}

impl<T: MemSize> MemSize for Box<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + (**self).mem_bytes()
    }
}

impl<T: MemSize> MemSize for std::sync::Arc<T> {
    /// Amortized over sharers: the payload is counted once across all
    /// holders (see the module docs).
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + (**self).mem_bytes() / std::sync::Arc::strong_count(self)
    }
}

/// Helper: bytes of a flat numeric Vec (no per-element recursion).
pub fn vec_flat_bytes<T>(v: &Vec<T>) -> usize {
    std::mem::size_of::<Vec<T>>() + v.capacity() * std::mem::size_of::<T>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_of_f32() {
        let v = vec![0f32; 100];
        assert!(v.mem_bytes() >= 400);
    }

    #[test]
    fn flat_bytes_counts_capacity() {
        let mut v = Vec::with_capacity(64);
        v.push(1u64);
        assert!(vec_flat_bytes(&v) >= 64 * 8);
    }

    /// Pins the Arc accounting convention: payload counted exactly once
    /// across all sharers, in full at a sole owner.
    #[test]
    fn arc_payload_counted_once_across_sharers() {
        let a = std::sync::Arc::new(vec![0f32; 100]);
        let ptr = std::mem::size_of::<std::sync::Arc<Vec<f32>>>();
        let payload = (*a).mem_bytes();
        assert_eq!(a.mem_bytes(), ptr + payload, "sole owner charged in full");
        let b = std::sync::Arc::clone(&a);
        assert_eq!(a.mem_bytes(), ptr + payload / 2, "sharer charged half");
        assert_eq!(
            a.mem_bytes() + b.mem_bytes(),
            2 * ptr + payload / 2 * 2,
            "sum over holders counts the payload once"
        );
        drop(b);
        assert_eq!(a.mem_bytes(), ptr + payload, "full charge restored after drop");
    }
}
