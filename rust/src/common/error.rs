//! Crate-local error type — the tiny `anyhow` subset this crate uses,
//! with no external dependency.
//!
//! The repository must build in offline containers whose cargo registry
//! caches cannot be assumed to hold any particular crate version, and a
//! committed `Cargo.lock` (needed so CI cache keys react to dependency
//! changes) pins exact versions. Rather than gamble the lockfile on a
//! registry snapshot, the one external dependency (`anyhow`) is replaced
//! by this module: a string-backed [`Error`], a [`Result`] alias, a
//! [`Context`] extension trait, and `anyhow!` / `bail!` / `ensure!`
//! macros with the same shapes. Error *chains*, downcasting and
//! backtraces — the parts of `anyhow` this crate never used — are
//! deliberately out of scope.

use std::fmt;

/// String-backed error value. Like `anyhow::Error` it deliberately does
/// NOT implement `std::error::Error`: that keeps the blanket
/// `From<E: std::error::Error>` conversion below coherent (the standard
/// library's reflexive `From<T> for T` would otherwise overlap).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (the `anyhow!` macro
    /// lowers to this).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string() }
    }

    /// Prefix the message with context, innermost cause last — same
    /// reading order as `anyhow`'s `{:#}` chain rendering.
    pub fn context(self, context: impl fmt::Display) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Debug renders the plain message (not a struct dump) so that
/// `.unwrap()` / `.expect()` failures stay readable, as with `anyhow`.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (re-exported as `crate::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on any `Result` whose error is
/// displayable — including foreign error types, which are converted into
/// [`Error`] with the context prefixed.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Construct an [`Error`](crate::common::error::Error) from a format
/// string: `anyhow!("bad value {v}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::common::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn foreign_errors_convert_and_take_context() {
        let e = fail_io().unwrap_err();
        assert_eq!(e.to_string(), "gone");
        let e = fail_io().context("reading data").unwrap_err();
        assert_eq!(e.to_string(), "reading data: gone");
        let e = fail_io().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "pass 2: gone");
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(inner(-2).unwrap_err().to_string(), "negative input -2");
        assert_eq!(anyhow!("v={}", 7).to_string(), "v=7");
        assert_eq!(format!("{:#}", anyhow!("alt")), "alt");
        assert_eq!(format!("{:?}", anyhow!("dbg")), "dbg");
    }
}
