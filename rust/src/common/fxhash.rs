//! Dependency-free FxHash-style hasher for the hot-path tables (the LS
//! counter table sees one lookup per attribute event; SipHash's keyed
//! strength is wasted there — keys are internal ids, not attacker input).
//!
//! §Perf: switching the LS table and the MA leaf index to this hasher is
//! one of the recorded optimization steps (EXPERIMENTS.md §Perf).

use std::hash::{BuildHasherDefault, Hasher};

/// Firefox-style multiply-rotate hasher (word-at-a-time).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut word = [0u8; 8];
            word[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(word));
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// `HashMap` build-hasher alias.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Fast HashMap for internal integer keys.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_sequential_keys() {
        let mut buckets = [0u32; 16];
        for k in 0..10_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(k);
            buckets[(h.finish() % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!(b > 400 && b < 900, "skewed bucket: {buckets:?}");
        }
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for k in 0..1000 {
            m.insert(k, k as u32 * 2);
        }
        assert_eq!(m.get(&500), Some(&1000));
        assert_eq!(m.len(), 1000);
    }
}
