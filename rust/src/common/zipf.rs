//! Zipf-distributed sampling over ranks 0..n — the random-tweet generator
//! (paper §6.3, sparse synthetic data) selects bag-of-words tokens with a
//! Zipf skew of z = 1.5.
//!
//! Uses the inverse-CDF method over a precomputed cumulative table: O(n)
//! setup, O(log n) per sample, exact (no rejection).

use super::rng::Rng;

/// Zipf(n, z): P(rank = k) ∝ 1 / (k+1)^z.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, z: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(z);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first index with cdf >= u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_zero_most_frequent() {
        let z = Zipf::new(100, 1.5);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn skew_matches_theory() {
        // P(0)/P(1) = 2^1.5 ≈ 2.83
        let z = Zipf::new(1000, 1.5);
        let mut rng = Rng::new(2);
        let (mut c0, mut c1) = (0f64, 0f64);
        for _ in 0..200_000 {
            match z.sample(&mut rng) {
                0 => c0 += 1.0,
                1 => c1 += 1.0,
                _ => {}
            }
        }
        let ratio = c0 / c1;
        assert!((ratio - 2.83).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn all_ranks_reachable_small() {
        let z = Zipf::new(5, 1.5);
        let mut rng = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..10_000 {
            seen[z.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
