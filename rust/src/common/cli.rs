//! Minimal argument parsing for the `samoa` CLI and experiment harness.
//!
//! No external crates are available offline, so this is a tiny typed
//! key-value parser: `samoa exp fig4 --instances 1000000 --p 2,4,8`.

use std::collections::BTreeMap;

/// Parsed `--key value` / `--flag` arguments plus positional args.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (after the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usize, e.g. `--p 2,4,8`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("exp fig4 --instances 500 --quiet");
        assert_eq!(a.positional, vec!["exp", "fig4"]);
        assert_eq!(a.usize("instances", 0), 500);
        assert!(a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--delta=1e-7 --p=2,4");
        assert_eq!(a.f64("delta", 0.0), 1e-7);
        assert_eq!(a.usize_list("p", &[]), vec![2, 4]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.get_or("name", "x"), "x");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
