//! Shared utilities: deterministic RNG, samplers, sizing, tiny CLI parsing.

pub mod rng;
pub mod zipf;
pub mod cli;
pub mod error;
pub mod memsize;
pub mod fxhash;

pub use memsize::MemSize;
pub use rng::Rng;
