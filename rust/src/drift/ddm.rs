//! DDM — Drift Detection Method (Gama et al. 2004): monitors the error
//! rate p_t and its std σ_t of a classifier; warns at p+σ > p_min+2σ_min,
//! detects at p+σ > p_min+3σ_min.

use super::ChangeDetector;

/// DDM detector. Feed 1.0 for a misclassification, 0.0 for a correct one.
#[derive(Clone, Debug)]
pub struct Ddm {
    n: f64,
    p: f64,
    s: f64,
    p_min: f64,
    s_min: f64,
    warning: bool,
    detected: bool,
    /// Minimum observations before detection can fire.
    pub min_n: f64,
}

impl Default for Ddm {
    fn default() -> Self {
        Ddm {
            n: 1.0,
            p: 1.0,
            s: 0.0,
            p_min: f64::MAX,
            s_min: f64::MAX,
            warning: false,
            detected: false,
            min_n: 30.0,
        }
    }
}

impl Ddm {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn warning(&self) -> bool {
        self.warning
    }
}

impl ChangeDetector for Ddm {
    fn add(&mut self, error: f64) {
        self.p += (error - self.p) / self.n;
        self.s = (self.p * (1.0 - self.p) / self.n).sqrt();
        self.n += 1.0;
        if self.n < self.min_n {
            return;
        }
        if self.p + self.s <= self.p_min + self.s_min {
            self.p_min = self.p;
            self.s_min = self.s;
        }
        let level = self.p + self.s;
        self.detected = level > self.p_min + 3.0 * self.s_min;
        self.warning = level > self.p_min + 2.0 * self.s_min;
    }

    fn detected(&self) -> bool {
        self.detected
    }

    fn reset(&mut self) {
        *self = Ddm { min_n: self.min_n, ..Ddm::default() };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn improving_then_degrading_detected() {
        let mut ddm = Ddm::new();
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            ddm.add(if rng.bool(0.1) { 1.0 } else { 0.0 });
        }
        assert!(!ddm.detected());
        let mut fired = false;
        for _ in 0..2000 {
            ddm.add(if rng.bool(0.6) { 1.0 } else { 0.0 });
            if ddm.detected() {
                fired = true;
                break;
            }
        }
        assert!(fired);
    }

    #[test]
    fn stable_error_rate_silent() {
        let mut ddm = Ddm::new();
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            ddm.add(if rng.bool(0.2) { 1.0 } else { 0.0 });
        }
        assert!(!ddm.detected());
    }
}
