//! ADWIN — ADaptive WINdowing (Bifet & Gavaldà 2007): maintains a window
//! of recent values in an exponential bucket histogram and drops the
//! oldest buckets whenever two sub-windows have significantly different
//! means. The workhorse change detector behind the paper's adaptive
//! bagging/boosting (§5).

use super::ChangeDetector;

const MAX_BUCKETS_PER_ROW: usize = 5;

/// One row of buckets, each summarizing 2^row values.
#[derive(Clone, Debug, Default)]
struct Row {
    /// (sum, count-of-buckets-used); every bucket in row i holds 2^i items
    sums: Vec<f64>,
}

/// ADWIN with confidence δ.
#[derive(Clone, Debug)]
pub struct Adwin {
    pub delta: f64,
    rows: Vec<Row>,
    total: f64,
    width: f64,
    detected: bool,
    n_since_check: u32,
    /// check for cuts every this many additions (MOA: 32)
    check_every: u32,
}

impl Adwin {
    pub fn new(delta: f64) -> Self {
        Adwin {
            delta,
            rows: vec![Row::default()],
            total: 0.0,
            width: 0.0,
            detected: false,
            n_since_check: 0,
            check_every: 32,
        }
    }

    pub fn mean(&self) -> f64 {
        if self.width == 0.0 {
            0.0
        } else {
            self.total / self.width
        }
    }

    pub fn width(&self) -> f64 {
        self.width
    }

    fn insert(&mut self, value: f64) {
        self.rows[0].sums.insert(0, value);
        self.total += value;
        self.width += 1.0;
        // compress: merge oldest pairs upward when a row overflows
        let mut row = 0;
        while self.rows[row].sums.len() > MAX_BUCKETS_PER_ROW {
            if self.rows.len() <= row + 1 {
                self.rows.push(Row::default());
            }
            let b2 = self.rows[row].sums.pop().unwrap();
            let b1 = self.rows[row].sums.pop().unwrap();
            self.rows[row + 1].sums.insert(0, b1 + b2);
            row += 1;
        }
    }

    /// ADWIN cut check: compare every prefix/suffix split of the bucket
    /// sequence (oldest first) with the Hoeffding-style bound.
    fn detect_and_shrink(&mut self) {
        self.detected = false;
        if self.width < 10.0 {
            return;
        }
        loop {
            let mut cut = false;
            // walk buckets oldest → newest, accumulating the "old" window
            let mut w0 = 0.0;
            let mut s0 = 0.0;
            'outer: for row in (0..self.rows.len()).rev() {
                let size = (1u64 << row) as f64;
                // oldest buckets are at the END of each row's vec
                for b in (0..self.rows[row].sums.len()).rev() {
                    w0 += size;
                    s0 += self.rows[row].sums[b];
                    let w1 = self.width - w0;
                    if w0 < 1.0 || w1 < 1.0 {
                        continue;
                    }
                    let s1 = self.total - s0;
                    let m0 = s0 / w0;
                    let m1 = s1 / w1;
                    let m = 1.0 / (1.0 / w0 + 1.0 / w1); // harmonic mean
                    let dd = (4.0 * self.width / self.delta).ln();
                    let eps =
                        (2.0 / m * self.mean_variance() * dd).sqrt() + 2.0 / (3.0 * m) * dd;
                    if (m0 - m1).abs() > eps {
                        cut = true;
                        self.detected = true;
                        self.drop_oldest();
                        break 'outer;
                    }
                }
            }
            if !cut {
                break;
            }
        }
    }

    fn mean_variance(&self) -> f64 {
        // variance estimate for bounded [0,1] inputs: p(1-p)
        let m = self.mean();
        (m * (1.0 - m)).max(1e-6)
    }

    fn drop_oldest(&mut self) {
        for row in (0..self.rows.len()).rev() {
            if let Some(b) = self.rows[row].sums.pop() {
                self.total -= b;
                self.width -= (1u64 << row) as f64;
                return;
            }
        }
    }
}

impl Default for Adwin {
    fn default() -> Self {
        Adwin::new(0.002)
    }
}

impl ChangeDetector for Adwin {
    fn add(&mut self, value: f64) {
        self.insert(value);
        self.n_since_check += 1;
        if self.n_since_check >= self.check_every {
            self.n_since_check = 0;
            self.detect_and_shrink();
        } else {
            self.detected = false;
        }
    }

    fn detected(&self) -> bool {
        self.detected
    }

    fn reset(&mut self) {
        let delta = self.delta;
        *self = Adwin::new(delta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn stable_bernoulli_silent() {
        let mut a = Adwin::default();
        let mut rng = Rng::new(1);
        let mut fired = false;
        for _ in 0..10_000 {
            a.add(if rng.bool(0.2) { 1.0 } else { 0.0 });
            fired |= a.detected();
        }
        assert!(!fired);
        assert!((a.mean() - 0.2).abs() < 0.05, "mean={}", a.mean());
    }

    #[test]
    fn abrupt_change_detected_and_window_shrinks() {
        let mut a = Adwin::default();
        let mut rng = Rng::new(2);
        for _ in 0..5000 {
            a.add(if rng.bool(0.1) { 1.0 } else { 0.0 });
        }
        let w_before = a.width();
        let mut fired = false;
        for _ in 0..3000 {
            a.add(if rng.bool(0.9) { 1.0 } else { 0.0 });
            if a.detected() {
                fired = true;
            }
        }
        assert!(fired, "no detection");
        assert!(a.width() < w_before + 3000.0, "window did not shrink");
        // mean tracks the new regime
        assert!(a.mean() > 0.5, "mean={}", a.mean());
    }

    #[test]
    fn width_tracks_insertions() {
        let mut a = Adwin::default();
        for i in 0..100 {
            a.add((i % 2) as f64);
        }
        assert_eq!(a.width(), 100.0);
    }
}
