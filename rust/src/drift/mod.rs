//! Change detectors (paper §5): ADWIN, DDM, EDDM, Page-Hinkley.
pub mod adwin;
pub mod ddm;
pub mod eddm;
pub mod page_hinkley;

/// Common interface: feed a bounded input (error indicator or value),
/// learn its mean, and report detected change.
pub trait ChangeDetector: Send {
    fn add(&mut self, value: f64);
    fn detected(&self) -> bool;
    fn reset(&mut self);
}
