//! EDDM — Early Drift Detection Method (Baena-García et al. 2006):
//! monitors the *distance between errors* rather than the error rate,
//! which reacts earlier to gradual drift.

use super::ChangeDetector;

/// EDDM detector. Feed 1.0 for a misclassification, 0.0 otherwise.
#[derive(Clone, Debug)]
pub struct Eddm {
    n: u64,
    last_error_at: u64,
    n_errors: u64,
    mean_dist: f64,
    var_acc: f64,
    max_metric: f64,
    below: u32,
    detected: bool,
    warning: bool,
}

const ALPHA_WARN: f64 = 0.90;
const ALPHA_DRIFT: f64 = 0.80;
const MIN_ERRORS: u64 = 30;
/// consecutive below-threshold error events required (fading statistics
/// fluctuate; a single dip is noise)
const PERSISTENCE: u32 = 3;

impl Default for Eddm {
    fn default() -> Self {
        Eddm {
            n: 0,
            last_error_at: 0,
            n_errors: 0,
            mean_dist: 0.0,
            var_acc: 0.0,
            max_metric: 0.0,
            below: 0,
            detected: false,
            warning: false,
        }
    }
}

impl Eddm {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn warning(&self) -> bool {
        self.warning
    }
}

impl ChangeDetector for Eddm {
    fn add(&mut self, error: f64) {
        self.n += 1;
        if error <= 0.0 {
            return;
        }
        let dist = (self.n - self.last_error_at) as f64;
        self.last_error_at = self.n;
        self.n_errors += 1;
        // fading statistics: react to recent error spacing, not the full
        // history (a cumulative mean would wash bursts out)
        const FADE: f64 = 0.05;
        if self.n_errors == 1 {
            self.mean_dist = dist;
        } else {
            let delta = dist - self.mean_dist;
            self.mean_dist += FADE * delta;
            self.var_acc = (1.0 - FADE) * (self.var_acc + FADE * delta * delta);
        }
        if self.n_errors < MIN_ERRORS {
            return;
        }
        let sd = self.var_acc.sqrt();
        let metric = self.mean_dist + 2.0 * sd;
        // decaying peak: during a stable regime the reference max
        // re-normalizes toward the current level, so estimator noise can
        // never hold the ratio down permanently; an actual burst drops
        // `metric` far faster than the decay
        self.max_metric *= 0.995;
        if metric > self.max_metric {
            self.max_metric = metric;
            self.below = 0;
            self.warning = false;
            self.detected = false;
        } else {
            let ratio = metric / self.max_metric;
            self.below = if ratio < ALPHA_DRIFT { self.below + 1 } else { 0 };
            self.detected = self.below >= PERSISTENCE;
            self.warning = ratio < ALPHA_WARN;
        }
    }

    fn detected(&self) -> bool {
        self.detected
    }

    fn reset(&mut self) {
        *self = Eddm::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn error_burst_detected() {
        let mut e = Eddm::new();
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            e.add(if rng.bool(0.05) { 1.0 } else { 0.0 });
        }
        let calm = e.detected();
        for _ in 0..3000 {
            e.add(if rng.bool(0.5) { 1.0 } else { 0.0 });
            if e.detected() {
                break;
            }
        }
        assert!(!calm);
        assert!(e.detected());
    }
}
