//! Page–Hinkley test (Page 1954), as modified for streaming in AMRules
//! (paper §7): detects an upward change in the mean of a sequence —
//! here, of a rule's absolute prediction error.

use super::ChangeDetector;

/// Page–Hinkley change detector.
#[derive(Clone, Debug)]
pub struct PageHinkley {
    /// Minimum magnitude of change to care about.
    pub alpha: f64,
    /// Detection threshold λ.
    pub lambda: f64,
    n: u64,
    mean: f64,
    cum: f64,
    min_cum: f64,
    detected: bool,
}

impl PageHinkley {
    pub fn new(alpha: f64, lambda: f64) -> Self {
        PageHinkley { alpha, lambda, n: 0, mean: 0.0, cum: 0.0, min_cum: 0.0, detected: false }
    }
}

impl Default for PageHinkley {
    fn default() -> Self {
        // MOA defaults for AMRules drift detection
        PageHinkley::new(0.005, 35.0)
    }
}

impl ChangeDetector for PageHinkley {
    fn add(&mut self, value: f64) {
        self.n += 1;
        self.mean += (value - self.mean) / self.n as f64;
        self.cum += value - self.mean - self.alpha;
        self.min_cum = self.min_cum.min(self.cum);
        self.detected = self.cum - self.min_cum > self.lambda;
    }

    fn detected(&self) -> bool {
        self.detected
    }

    fn reset(&mut self) {
        self.n = 0;
        self.mean = 0.0;
        self.cum = 0.0;
        self.min_cum = 0.0;
        self.detected = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn stable_stream_no_detection() {
        let mut ph = PageHinkley::new(0.005, 35.0);
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            ph.add(0.5 + 0.1 * rng.gaussian());
        }
        assert!(!ph.detected());
    }

    #[test]
    fn mean_shift_detected() {
        let mut ph = PageHinkley::new(0.005, 35.0);
        let mut rng = Rng::new(2);
        for _ in 0..2000 {
            ph.add(0.5 + 0.1 * rng.gaussian());
        }
        for _ in 0..2000 {
            ph.add(1.5 + 0.1 * rng.gaussian());
            if ph.detected() {
                break;
            }
        }
        assert!(ph.detected());
    }

    #[test]
    fn reset_clears() {
        let mut ph = PageHinkley::new(0.005, 5.0);
        for _ in 0..100 {
            ph.add(10.0);
        }
        ph.reset();
        assert!(!ph.detected());
    }
}
