//! Online ensembles (paper §5): OzaBag, OzaBoost, and ADWIN-adaptive bagging.
pub mod oza_bag;
pub mod oza_boost;
pub mod topology;
