//! Distributed online bagging (paper §5 / StormMOA comparison): the
//! incoming stream is broadcast to p ensemble workers, each hosting one
//! base learner with its own Poisson(1) resampling seed; a voter
//! processor aggregates per-instance votes by weighted majority and emits
//! the ensemble prediction.
//!
//! ```text
//!            instance (all)              vote (key: instance id)
//!   source ─────────────► workers × p ═══════════════► voter ─► evaluator
//! ```
//!
//! This is the design the paper attributes to StormMOA ("only allows to
//! run a single model in each Storm bolt... restricts the kind of models
//! that can be run in parallel to ensembles") — included both as a usable
//! ensemble runner and as the horizontal-parallelism comparison point.

use crate::common::Rng;
use crate::core::instance::Label;
use crate::core::model::Classifier;
use crate::core::Schema;
use crate::topology::{
    Ctx, Event, Grouping, Output, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

/// One ensemble member: predicts every instance, trains with Poisson(1)
/// weight, sends its vote to the voter keyed by instance id.
pub struct BaggingWorker {
    model: Box<dyn Classifier>,
    rng: Rng,
    out: StreamId,
}

impl BaggingWorker {
    pub fn new(model: Box<dyn Classifier>, seed: u64, out: StreamId) -> Self {
        BaggingWorker { model, rng: Rng::new(seed), out }
    }
}

impl Processor for BaggingWorker {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, inst } = event {
            let output = match self.model.predict(&inst) {
                Some(c) => Output::Class(c),
                None => Output::None,
            };
            ctx.emit(self.out, id, Event::Prediction { id, truth: inst.label, output });
            let k = self.rng.poisson(1.0);
            if k > 0 && inst.class().is_some() {
                let mut weighted = inst;
                weighted.weight = k as f32;
                self.model.train(&weighted);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.model.model_bytes()
    }

    fn name(&self) -> &'static str {
        "bagging-worker"
    }
}

/// Majority voter: collects p votes per instance id, emits the ensemble
/// prediction once all (or `p` distinct) votes arrived.
pub struct Voter {
    expected: usize,
    n_classes: usize,
    out: StreamId,
    /// (instance id, truth, votes) — small in-flight window
    pending: Vec<(u64, Label, Vec<u32>, usize)>,
}

impl Voter {
    pub fn new(expected: usize, n_classes: u32, out: StreamId) -> Self {
        Voter { expected, n_classes: n_classes as usize, out, pending: Vec::new() }
    }
}

impl Processor for Voter {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Prediction { id, truth, output } = event {
            let pos = match self.pending.iter().position(|(pid, ..)| *pid == id) {
                Some(p) => p,
                None => {
                    self.pending.push((id, truth, vec![0; self.n_classes], 0));
                    self.pending.len() - 1
                }
            };
            {
                let (_, _, votes, seen) = &mut self.pending[pos];
                if let Output::Class(c) = output {
                    if (c as usize) < votes.len() {
                        votes[c as usize] += 1;
                    }
                }
                *seen += 1;
            }
            if self.pending[pos].3 >= self.expected {
                let (id, truth, votes, _) = self.pending.swap_remove(pos);
                let best = votes
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v > 0)
                    .max_by_key(|(_, &v)| v)
                    .map(|(c, _)| c as u32);
                let output = match best {
                    Some(c) => Output::Class(c),
                    None => Output::None,
                };
                ctx.emit_any(self.out, Event::Prediction { id, truth, output });
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.pending.len() * (24 + 4 * self.n_classes)
    }

    fn name(&self) -> &'static str {
        "bagging-voter"
    }
}

/// Handles of an assembled distributed-bagging topology.
#[derive(Clone, Copy, Debug)]
pub struct BaggingHandles {
    pub entry: StreamId,
    pub votes: StreamId,
    pub prediction: StreamId,
    pub workers: ProcessorId,
    pub voter: ProcessorId,
    pub evaluator: ProcessorId,
}

/// Build a distributed bagging ensemble of `p` base learners.
pub fn build_topology(
    schema: &Schema,
    p: usize,
    seed: u64,
    base: impl Fn(usize) -> Box<dyn Classifier> + 'static,
    evaluator: impl Fn(usize) -> Box<dyn crate::topology::Processor> + 'static,
) -> (Topology, BaggingHandles) {
    let mut b = TopologyBuilder::new("dist-bagging");
    let eval = b.add_processor("evaluator", 1, evaluator);
    // stream order: 0 entry, 1 votes, 2 prediction
    let votes = StreamId(1);
    let prediction = StreamId(2);
    let workers = b.add_processor("bagging-worker", p, move |i| {
        Box::new(BaggingWorker::new(base(i), seed ^ (i as u64 + 1), votes))
    });
    let n_classes = schema.n_classes();
    let voter =
        b.add_processor("voter", 1, move |_| Box::new(Voter::new(p, n_classes, prediction)));

    let entry = b.stream("instance", None, workers, Grouping::All);
    let v = b.stream("votes", Some(workers), voter, Grouping::Key);
    let pr = b.stream("prediction", Some(voter), eval, Grouping::Shuffle);
    debug_assert_eq!((v, pr), (votes, prediction));

    (b.build(), BaggingHandles { entry, votes, prediction, workers, voter, evaluator: eval })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::core::instance::Instance;
    use crate::engine::{LocalEngine, ThreadedEngine};
    use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use std::sync::Arc;

    fn schema() -> Schema {
        let mut attrs = vec![crate::core::AttributeKind::Categorical { n_values: 2 }];
        attrs.extend(Schema::all_numeric(3));
        Schema::classification("e", attrs, 2)
    }

    fn source(n: u64, seed: u64) -> impl Iterator<Item = Event> {
        let mut rng = Rng::new(seed);
        (0..n).map(move |id| {
            let a = rng.below(2) as f32;
            let inst = Instance::dense(
                vec![a, rng.f32(), rng.f32(), rng.f32()],
                Label::Class(a as u32),
            );
            Event::Instance { id, inst }
        })
    }

    fn build(p: usize) -> (Topology, BaggingHandles, Arc<EvalSink>) {
        let s = schema();
        let sink = EvalSink::new(2, 1.0, 100_000);
        let sink2 = Arc::clone(&sink);
        let s_base = s.clone();
        let (topo, handles) = build_topology(
            &s,
            p,
            7,
            move |_| {
                Box::new(HoeffdingTree::new(
                    s_base.clone(),
                    HTConfig { grace_period: 100, ..Default::default() },
                ))
            },
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        (topo, handles, sink)
    }

    #[test]
    fn distributed_bagging_learns_local() {
        let (topo, handles, sink) = build(5);
        let m = LocalEngine::new().run(&topo, handles.entry, source(6000, 1), |_| {});
        assert_eq!(m.streams[handles.votes.0].events, 6000 * 5);
        assert_eq!(m.streams[handles.prediction.0].events, 6000);
        assert!(sink.accuracy() > 0.9, "acc={}", sink.accuracy());
    }

    #[test]
    fn distributed_bagging_learns_threaded() {
        let (topo, handles, sink) = build(3);
        let m = ThreadedEngine::default().run(&topo, handles.entry, source(4000, 2), |_, _, _| {});
        assert_eq!(m.source_instances, 4000);
        // votes may still be partially in-flight windows at shutdown for
        // the last few ids, but the vast majority must be evaluated
        let evaluated = m.streams[handles.prediction.0].events;
        assert!(evaluated >= 3900, "evaluated={evaluated}");
        assert!(sink.accuracy() > 0.85, "acc={}", sink.accuracy());
    }
}
