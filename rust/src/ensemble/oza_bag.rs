//! OzaBag — online bagging (Oza & Russell 2001), plus the ADWIN-adaptive
//! variant used by SAMOA's adaptive bagging (§5): each base learner sees
//! each instance Poisson(1) times; the adaptive variant replaces the
//! worst-performing learner when its ADWIN detects drift.

use crate::common::Rng;
use crate::core::instance::Instance;
use crate::core::model::Classifier;
use crate::core::Schema;
use crate::drift::adwin::Adwin;
use crate::drift::ChangeDetector;

/// Factory for base learners.
pub type BaseFactory = Box<dyn Fn() -> Box<dyn Classifier> + Send>;

/// Online bagging ensemble.
pub struct OzaBag {
    members: Vec<Box<dyn Classifier>>,
    factory: BaseFactory,
    rng: Rng,
    n_classes: u32,
    /// per-member ADWIN on the 0/1 error (None = plain OzaBag)
    detectors: Option<Vec<Adwin>>,
    pub replacements: u64,
}

impl OzaBag {
    pub fn new(schema: &Schema, size: usize, seed: u64, factory: BaseFactory) -> Self {
        OzaBag {
            members: (0..size).map(|_| factory()).collect(),
            factory,
            rng: Rng::new(seed),
            n_classes: schema.n_classes(),
            detectors: None,
            replacements: 0,
        }
    }

    /// ADWIN-adaptive variant (replaces drifting members).
    pub fn adaptive(schema: &Schema, size: usize, seed: u64, factory: BaseFactory) -> Self {
        let mut s = Self::new(schema, size, seed, factory);
        s.detectors = Some((0..size).map(|_| Adwin::default()).collect());
        s
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }
}

impl Classifier for OzaBag {
    fn predict(&self, inst: &Instance) -> Option<u32> {
        let mut votes = vec![0u32; self.n_classes as usize];
        for m in &self.members {
            if let Some(c) = m.predict(inst) {
                votes[c as usize] += 1;
            }
        }
        votes
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0)
            .max_by_key(|(_, &v)| v)
            .map(|(c, _)| c as u32)
    }

    fn train(&mut self, inst: &Instance) {
        let truth = inst.class();
        for i in 0..self.members.len() {
            // adaptive: track error before training
            if let (Some(dets), Some(t)) = (&mut self.detectors, truth) {
                let err = match self.members[i].predict(inst) {
                    Some(p) => (p != t) as u32 as f64,
                    None => 1.0,
                };
                dets[i].add(err);
                if dets[i].detected() {
                    self.members[i] = (self.factory)();
                    dets[i].reset();
                    self.replacements += 1;
                }
            }
            let k = self.rng.poisson(1.0);
            if k > 0 {
                let mut weighted = inst.clone();
                weighted.weight = k as f32;
                self.members[i].train(&weighted);
            }
        }
    }

    fn model_bytes(&self) -> usize {
        self.members.iter().map(|m| m.model_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::core::instance::Label;
    use crate::core::AttributeKind;

    fn schema() -> Schema {
        let mut attrs = vec![AttributeKind::Categorical { n_values: 2 }];
        attrs.extend(Schema::all_numeric(3));
        Schema::classification("s", attrs, 2)
    }

    fn factory(schema: Schema) -> BaseFactory {
        Box::new(move || {
            let cfg = HTConfig { grace_period: 100, ..Default::default() };
            Box::new(HoeffdingTree::new(schema.clone(), cfg))
        })
    }

    fn easy(rng: &mut Rng) -> Instance {
        let a = rng.below(2) as f32;
        Instance::dense(vec![a, rng.f32(), rng.f32(), rng.f32()], Label::Class(a as u32))
    }

    #[test]
    fn bagging_learns() {
        let s = schema();
        let mut bag = OzaBag::new(&s, 5, 1, factory(s.clone()));
        let mut rng = Rng::new(2);
        for _ in 0..3000 {
            bag.train(&easy(&mut rng));
        }
        let mut correct = 0;
        for _ in 0..200 {
            let i = easy(&mut rng);
            if bag.predict(&i) == i.class() {
                correct += 1;
            }
        }
        assert!(correct > 190, "correct={correct}");
    }

    #[test]
    fn adaptive_replaces_on_drift() {
        let s = schema();
        let mut bag = OzaBag::adaptive(&s, 3, 3, factory(s.clone()));
        let mut rng = Rng::new(4);
        for _ in 0..3000 {
            bag.train(&easy(&mut rng));
        }
        // invert the concept: label = 1 - a
        for _ in 0..4000 {
            let mut i = easy(&mut rng);
            i.label = Label::Class(1 - i.class().unwrap());
            bag.train(&i);
        }
        assert!(bag.replacements > 0, "no adaptive replacement happened");
    }
}
