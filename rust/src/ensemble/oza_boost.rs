//! OzaBoost — online boosting (Oza & Russell 2001): sequential members;
//! the Poisson λ of each instance grows for members that got it wrong
//! upstream and shrinks for those that got it right, concentrating later
//! members on the hard instances.

use crate::common::Rng;
use crate::core::instance::Instance;
use crate::core::model::Classifier;
use crate::core::Schema;

use super::oza_bag::BaseFactory;

/// Online boosting ensemble.
pub struct OzaBoost {
    members: Vec<Box<dyn Classifier>>,
    /// λ mass routed to correct/wrong per member (for member weights)
    lambda_correct: Vec<f64>,
    lambda_wrong: Vec<f64>,
    rng: Rng,
    n_classes: u32,
}

impl OzaBoost {
    pub fn new(schema: &Schema, size: usize, seed: u64, factory: BaseFactory) -> Self {
        OzaBoost {
            members: (0..size).map(|_| factory()).collect(),
            lambda_correct: vec![1e-9; size],
            lambda_wrong: vec![1e-9; size],
            rng: Rng::new(seed),
            n_classes: schema.n_classes(),
        }
    }

    /// log((1-ε)/ε) member weight, clamped.
    fn member_weight(&self, i: usize) -> f64 {
        let eps = self.lambda_wrong[i] / (self.lambda_correct[i] + self.lambda_wrong[i]);
        let eps = eps.clamp(1e-6, 1.0 - 1e-6);
        ((1.0 - eps) / eps).ln().max(0.0)
    }
}

impl Classifier for OzaBoost {
    fn predict(&self, inst: &Instance) -> Option<u32> {
        let mut votes = vec![0f64; self.n_classes as usize];
        for (i, m) in self.members.iter().enumerate() {
            if let Some(c) = m.predict(inst) {
                votes[c as usize] += self.member_weight(i);
            }
        }
        votes
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0.0)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c as u32)
    }

    fn train(&mut self, inst: &Instance) {
        let Some(truth) = inst.class() else { return };
        let mut lambda = 1.0f64;
        for i in 0..self.members.len() {
            let k = self.rng.poisson(lambda);
            if k > 0 {
                let mut weighted = inst.clone();
                weighted.weight = k as f32;
                self.members[i].train(&weighted);
            }
            let correct = self.members[i].predict(inst) == Some(truth);
            if correct {
                self.lambda_correct[i] += lambda;
                let denom = 2.0 * (self.lambda_correct[i]
                    / (self.lambda_correct[i] + self.lambda_wrong[i]));
                lambda /= denom.max(1e-9);
            } else {
                self.lambda_wrong[i] += lambda;
                let denom = 2.0 * (self.lambda_wrong[i]
                    / (self.lambda_correct[i] + self.lambda_wrong[i]));
                lambda /= denom.max(1e-9);
            }
            lambda = lambda.clamp(1e-6, 1e3);
        }
    }

    fn model_bytes(&self) -> usize {
        self.members.iter().map(|m| m.model_bytes()).sum::<usize>() + 16 * self.members.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use crate::core::instance::Label;
    use crate::core::AttributeKind;

    #[test]
    fn boosting_learns_xor_better_than_single_stump() {
        // XOR of two categorical attributes: hard for a depth-limited tree,
        // boosting should still get most of it
        let mut attrs = vec![AttributeKind::Categorical { n_values: 2 }; 2];
        attrs.push(AttributeKind::Categorical { n_values: 2 });
        let schema = Schema::classification("xor", attrs, 2);
        let s2 = schema.clone();
        let mut boost = OzaBoost::new(
            &schema,
            10,
            1,
            Box::new(move || {
                Box::new(HoeffdingTree::new(
                    s2.clone(),
                    HTConfig { grace_period: 50, ..Default::default() },
                ))
            }),
        );
        let mut rng = Rng::new(2);
        for _ in 0..6000 {
            let a = rng.below(2) as u32;
            let b = rng.below(2) as u32;
            let inst = Instance::dense(
                vec![a as f32, b as f32, rng.below(2) as f32],
                Label::Class(a ^ b),
            );
            boost.train(&inst);
        }
        let mut correct = 0;
        for _ in 0..400 {
            let a = rng.below(2) as u32;
            let b = rng.below(2) as u32;
            let inst = Instance::dense(
                vec![a as f32, b as f32, rng.below(2) as f32],
                Label::Class(a ^ b),
            );
            if boost.predict(&inst) == inst.class() {
                correct += 1;
            }
        }
        assert!(correct > 300, "correct={correct}/400");
    }
}
