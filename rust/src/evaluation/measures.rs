//! Evaluation measures: accuracy/kappa for classification, MAE/RMSE
//! (optionally normalized by label range, as in Figs 14-16) for regression.

/// Online classification measure (cumulative + windowed).
#[derive(Clone, Debug)]
pub struct ClassificationMeasure {
    pub n: u64,
    pub correct: u64,
    /// confusion[truth][pred] for kappa
    confusion: Vec<Vec<u64>>,
    n_classes: usize,
    /// measurement checkpoints: (instances seen, cumulative accuracy)
    pub curve: Vec<(u64, f64)>,
    window: u64,
}

impl ClassificationMeasure {
    pub fn new(n_classes: u32, curve_every: u64) -> Self {
        ClassificationMeasure {
            n: 0,
            correct: 0,
            confusion: vec![vec![0; n_classes as usize]; n_classes as usize],
            n_classes: n_classes as usize,
            curve: Vec::new(),
            window: curve_every.max(1),
        }
    }

    pub fn add(&mut self, truth: u32, pred: Option<u32>) {
        self.n += 1;
        if let Some(p) = pred {
            if p == truth {
                self.correct += 1;
            }
            if (truth as usize) < self.n_classes && (p as usize) < self.n_classes {
                self.confusion[truth as usize][p as usize] += 1;
            }
        }
        if self.n % self.window == 0 {
            self.curve.push((self.n, self.accuracy()));
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.correct as f64 / self.n as f64
    }

    /// Cohen's kappa from the confusion matrix.
    pub fn kappa(&self) -> f64 {
        let total: u64 = self.confusion.iter().flatten().sum();
        if total == 0 {
            return 0.0;
        }
        let t = total as f64;
        let po = (0..self.n_classes)
            .map(|i| self.confusion[i][i] as f64)
            .sum::<f64>()
            / t;
        let pe = (0..self.n_classes)
            .map(|i| {
                let row: f64 = self.confusion[i].iter().map(|&x| x as f64).sum();
                let col: f64 = (0..self.n_classes).map(|j| self.confusion[j][i] as f64).sum();
                (row / t) * (col / t)
            })
            .sum::<f64>();
        if (1.0 - pe).abs() < 1e-12 {
            return 0.0;
        }
        (po - pe) / (1.0 - pe)
    }
}

/// Online regression measure.
#[derive(Clone, Debug)]
pub struct RegressionMeasure {
    pub n: u64,
    abs_sum: f64,
    sq_sum: f64,
    /// (instances, mae, rmse) checkpoints
    pub curve: Vec<(u64, f64, f64)>,
    window: u64,
    /// label range for normalized reporting (paper Figs 14-16)
    pub label_range: f64,
}

impl RegressionMeasure {
    pub fn new(label_range: f64, curve_every: u64) -> Self {
        RegressionMeasure {
            n: 0,
            abs_sum: 0.0,
            sq_sum: 0.0,
            curve: Vec::new(),
            window: curve_every.max(1),
            label_range: label_range.max(1e-12),
        }
    }

    pub fn add(&mut self, truth: f64, pred: f64) {
        self.n += 1;
        let e = truth - pred;
        self.abs_sum += e.abs();
        self.sq_sum += e * e;
        if self.n % self.window == 0 {
            self.curve.push((self.n, self.mae(), self.rmse()));
        }
    }

    pub fn mae(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.abs_sum / self.n as f64
    }

    pub fn rmse(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        (self.sq_sum / self.n as f64).sqrt()
    }

    pub fn nmae(&self) -> f64 {
        self.mae() / self.label_range
    }

    pub fn nrmse(&self) -> f64 {
        self.rmse() / self.label_range
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let mut m = ClassificationMeasure::new(2, 100);
        m.add(1, Some(1));
        m.add(0, Some(1));
        m.add(0, None); // no prediction counts as wrong
        assert!((m.accuracy() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn kappa_perfect_and_random() {
        let mut perfect = ClassificationMeasure::new(2, 100);
        for i in 0..100 {
            perfect.add(i % 2, Some(i % 2));
        }
        assert!((perfect.kappa() - 1.0).abs() < 1e-9);

        let mut random = ClassificationMeasure::new(2, 100);
        for i in 0..1000u32 {
            random.add(i % 2, Some((i / 2) % 2));
        }
        assert!(random.kappa().abs() < 0.1);
    }

    #[test]
    fn curve_records_checkpoints() {
        let mut m = ClassificationMeasure::new(2, 10);
        for i in 0..35 {
            m.add(0, Some((i % 2) as u32));
        }
        assert_eq!(m.curve.len(), 3);
        assert_eq!(m.curve[0].0, 10);
    }

    #[test]
    fn regression_errors() {
        let mut m = RegressionMeasure::new(10.0, 100);
        m.add(5.0, 3.0);
        m.add(1.0, 1.0);
        assert!((m.mae() - 1.0).abs() < 1e-12);
        assert!((m.rmse() - (2.0f64).sqrt()).abs() < 1e-12);
        assert!((m.nmae() - 0.1).abs() < 1e-12);
    }
}
