//! Evaluation measures: accuracy/kappa for classification, MAE/RMSE
//! (optionally normalized by label range, as in Figs 14-16) for regression.

/// Online classification measure (cumulative + windowed).
#[derive(Clone, Debug)]
pub struct ClassificationMeasure {
    pub n: u64,
    pub correct: u64,
    /// confusion[truth][pred] for kappa
    confusion: Vec<Vec<u64>>,
    n_classes: usize,
    /// measurement checkpoints: (instances seen, cumulative accuracy)
    pub curve: Vec<(u64, f64)>,
    window: u64,
}

impl ClassificationMeasure {
    pub fn new(n_classes: u32, curve_every: u64) -> Self {
        ClassificationMeasure {
            n: 0,
            correct: 0,
            confusion: vec![vec![0; n_classes as usize]; n_classes as usize],
            n_classes: n_classes as usize,
            curve: Vec::new(),
            window: curve_every.max(1),
        }
    }

    pub fn add(&mut self, truth: u32, pred: Option<u32>) {
        self.n += 1;
        if let Some(p) = pred {
            if p == truth {
                self.correct += 1;
            }
            if (truth as usize) < self.n_classes && (p as usize) < self.n_classes {
                self.confusion[truth as usize][p as usize] += 1;
            }
        }
        if self.n % self.window == 0 {
            self.curve.push((self.n, self.accuracy()));
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.correct as f64 / self.n as f64
    }

    /// Flatten the whole measure into one checkpoint section
    /// (`engine::checkpoint`): counters, shape, curve pairs, then the
    /// confusion matrix row-major. Everything is either a small integer
    /// (exact in f64) or an f64 already, so the round trip through
    /// [`ClassificationMeasure::restore_payload`] is bit-exact.
    pub fn state_payload(&self) -> Vec<f64> {
        let mut p = vec![
            self.n as f64,
            self.correct as f64,
            self.n_classes as f64,
            self.window as f64,
            self.curve.len() as f64,
        ];
        for (at, acc) in &self.curve {
            p.push(*at as f64);
            p.push(*acc);
        }
        for row in &self.confusion {
            for &c in row {
                p.push(c as f64);
            }
        }
        p
    }

    /// Adopt a [`ClassificationMeasure::state_payload`] snapshot,
    /// replacing all current state.
    pub fn restore_payload(&mut self, p: &[f64]) -> crate::Result<()> {
        crate::ensure!(p.len() >= 5, "measure restore: header truncated");
        let n_classes = p[2] as usize;
        let curve_len = p[4] as usize;
        let need = 5 + 2 * curve_len + n_classes * n_classes;
        crate::ensure!(p.len() == need, "measure restore: got {} f64s, need {need}", p.len());
        self.n = p[0] as u64;
        self.correct = p[1] as u64;
        self.n_classes = n_classes;
        self.window = (p[3] as u64).max(1);
        self.curve = (0..curve_len)
            .map(|i| (p[5 + 2 * i] as u64, p[6 + 2 * i]))
            .collect();
        let base = 5 + 2 * curve_len;
        self.confusion = (0..n_classes)
            .map(|i| {
                (0..n_classes)
                    .map(|j| p[base + i * n_classes + j] as u64)
                    .collect()
            })
            .collect();
        Ok(())
    }

    /// Cohen's kappa from the confusion matrix.
    pub fn kappa(&self) -> f64 {
        let total: u64 = self.confusion.iter().flatten().sum();
        if total == 0 {
            return 0.0;
        }
        let t = total as f64;
        let po = (0..self.n_classes)
            .map(|i| self.confusion[i][i] as f64)
            .sum::<f64>()
            / t;
        let pe = (0..self.n_classes)
            .map(|i| {
                let row: f64 = self.confusion[i].iter().map(|&x| x as f64).sum();
                let col: f64 = (0..self.n_classes).map(|j| self.confusion[j][i] as f64).sum();
                (row / t) * (col / t)
            })
            .sum::<f64>();
        if (1.0 - pe).abs() < 1e-12 {
            return 0.0;
        }
        (po - pe) / (1.0 - pe)
    }
}

/// Online regression measure.
#[derive(Clone, Debug)]
pub struct RegressionMeasure {
    pub n: u64,
    abs_sum: f64,
    sq_sum: f64,
    /// (instances, mae, rmse) checkpoints
    pub curve: Vec<(u64, f64, f64)>,
    window: u64,
    /// label range for normalized reporting (paper Figs 14-16)
    pub label_range: f64,
}

impl RegressionMeasure {
    pub fn new(label_range: f64, curve_every: u64) -> Self {
        RegressionMeasure {
            n: 0,
            abs_sum: 0.0,
            sq_sum: 0.0,
            curve: Vec::new(),
            window: curve_every.max(1),
            label_range: label_range.max(1e-12),
        }
    }

    pub fn add(&mut self, truth: f64, pred: f64) {
        self.n += 1;
        let e = truth - pred;
        self.abs_sum += e.abs();
        self.sq_sum += e * e;
        if self.n % self.window == 0 {
            self.curve.push((self.n, self.mae(), self.rmse()));
        }
    }

    pub fn mae(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.abs_sum / self.n as f64
    }

    pub fn rmse(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        (self.sq_sum / self.n as f64).sqrt()
    }

    /// Checkpoint section twin of
    /// [`ClassificationMeasure::state_payload`]: counters, label range,
    /// then `(at, mae, rmse)` curve triples. `abs_sum`/`sq_sum` are
    /// carried as raw f64 words, so restore is bit-exact.
    pub fn state_payload(&self) -> Vec<f64> {
        let mut p = vec![
            self.n as f64,
            self.abs_sum,
            self.sq_sum,
            self.window as f64,
            self.label_range,
            self.curve.len() as f64,
        ];
        for (at, mae, rmse) in &self.curve {
            p.push(*at as f64);
            p.push(*mae);
            p.push(*rmse);
        }
        p
    }

    /// Adopt a [`RegressionMeasure::state_payload`] snapshot.
    pub fn restore_payload(&mut self, p: &[f64]) -> crate::Result<()> {
        crate::ensure!(p.len() >= 6, "measure restore: header truncated");
        let curve_len = p[5] as usize;
        let need = 6 + 3 * curve_len;
        crate::ensure!(p.len() == need, "measure restore: got {} f64s, need {need}", p.len());
        self.n = p[0] as u64;
        self.abs_sum = p[1];
        self.sq_sum = p[2];
        self.window = (p[3] as u64).max(1);
        self.label_range = p[4];
        self.curve = (0..curve_len)
            .map(|i| (p[6 + 3 * i] as u64, p[7 + 3 * i], p[8 + 3 * i]))
            .collect();
        Ok(())
    }

    pub fn nmae(&self) -> f64 {
        self.mae() / self.label_range
    }

    pub fn nrmse(&self) -> f64 {
        self.rmse() / self.label_range
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let mut m = ClassificationMeasure::new(2, 100);
        m.add(1, Some(1));
        m.add(0, Some(1));
        m.add(0, None); // no prediction counts as wrong
        assert!((m.accuracy() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn kappa_perfect_and_random() {
        let mut perfect = ClassificationMeasure::new(2, 100);
        for i in 0..100 {
            perfect.add(i % 2, Some(i % 2));
        }
        assert!((perfect.kappa() - 1.0).abs() < 1e-9);

        let mut random = ClassificationMeasure::new(2, 100);
        for i in 0..1000u32 {
            random.add(i % 2, Some((i / 2) % 2));
        }
        assert!(random.kappa().abs() < 0.1);
    }

    #[test]
    fn curve_records_checkpoints() {
        let mut m = ClassificationMeasure::new(2, 10);
        for i in 0..35 {
            m.add(0, Some((i % 2) as u32));
        }
        assert_eq!(m.curve.len(), 3);
        assert_eq!(m.curve[0].0, 10);
    }

    #[test]
    fn regression_errors() {
        let mut m = RegressionMeasure::new(10.0, 100);
        m.add(5.0, 3.0);
        m.add(1.0, 1.0);
        assert!((m.mae() - 1.0).abs() < 1e-12);
        assert!((m.rmse() - (2.0f64).sqrt()).abs() < 1e-12);
        assert!((m.nmae() - 0.1).abs() < 1e-12);
    }
}
