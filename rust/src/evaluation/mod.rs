//! Prequential evaluation (test-then-train) and its measures (paper §6.3/7.3).
pub mod measures;
pub mod prequential;
