//! Prequential evaluation (paper §4's `PrequentialEvaluation` task and
//! §7.3's methodology): each instance is used for testing first, then for
//! training.
//!
//! Two forms:
//! * [`prequential_run`] / [`prequential_run_regression`] — sequential
//!   drivers for models implementing [`Classifier`]/[`Regressor`]
//!   (moa baseline, sharding, MAMR, local variants).
//! * [`EvaluatorProcessor`] — the evaluator node of a distributed
//!   topology; collects `Prediction` content events and publishes results
//!   through a shared [`EvalSink`].

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::core::instance::Label;
use crate::core::model::{Classifier, Regressor};
use crate::streams::StreamSource;
use crate::topology::{Ctx, Event, Output, Processor, StreamId};

use super::measures::{ClassificationMeasure, RegressionMeasure};

/// Sequential prequential configuration.
#[derive(Clone, Debug)]
pub struct PrequentialConfig {
    pub max_instances: u64,
    /// Record an accuracy checkpoint every N instances (paper: 100k).
    pub report_every: u64,
}

impl Default for PrequentialConfig {
    fn default() -> Self {
        PrequentialConfig { max_instances: 1_000_000, report_every: 100_000 }
    }
}

/// Result of a sequential prequential run.
#[derive(Clone, Debug)]
pub struct PrequentialResult {
    pub measure: ClassificationMeasure,
    pub wall_ns: u64,
    pub instances: u64,
    pub model_bytes: usize,
}

impl PrequentialResult {
    pub fn final_accuracy(&self) -> f64 {
        self.measure.accuracy()
    }

    pub fn throughput(&self) -> f64 {
        self.instances as f64 / (self.wall_ns.max(1) as f64 * 1e-9)
    }
}

/// Test-then-train a classifier over a stream.
pub fn prequential_run(
    model: &mut dyn Classifier,
    stream: &mut dyn StreamSource,
    config: &PrequentialConfig,
) -> PrequentialResult {
    let n_classes = stream.schema().n_classes();
    let mut measure = ClassificationMeasure::new(n_classes, config.report_every);
    let started = Instant::now();
    let mut seen = 0u64;
    while seen < config.max_instances {
        let Some(inst) = stream.next_instance() else { break };
        if let Some(truth) = inst.class() {
            measure.add(truth, model.predict(&inst));
        }
        model.train(&inst);
        seen += 1;
    }
    PrequentialResult {
        measure,
        wall_ns: started.elapsed().as_nanos() as u64,
        instances: seen,
        model_bytes: model.model_bytes(),
    }
}

/// Result of a sequential regression run.
#[derive(Clone, Debug)]
pub struct RegressionResult {
    pub measure: RegressionMeasure,
    pub wall_ns: u64,
    pub instances: u64,
    pub model_bytes: usize,
}

impl RegressionResult {
    pub fn throughput(&self) -> f64 {
        self.instances as f64 / (self.wall_ns.max(1) as f64 * 1e-9)
    }
}

/// Test-then-train a regressor over a stream.
pub fn prequential_run_regression(
    model: &mut dyn Regressor,
    stream: &mut dyn StreamSource,
    config: &PrequentialConfig,
) -> RegressionResult {
    let range = stream.schema().label_range();
    let mut measure = RegressionMeasure::new(range, config.report_every);
    let started = Instant::now();
    let mut seen = 0u64;
    while seen < config.max_instances {
        let Some(inst) = stream.next_instance() else { break };
        if let Some(truth) = inst.numeric_label() {
            measure.add(truth, model.predict(&inst));
        }
        model.train(&inst);
        seen += 1;
    }
    RegressionResult {
        measure,
        wall_ns: started.elapsed().as_nanos() as u64,
        instances: seen,
        model_bytes: model.model_bytes(),
    }
}

/// Shared sink the topology evaluator publishes into (thread-safe: the
/// threaded engine runs the evaluator on its own thread).
///
/// Every lock site recovers from poisoning: a panicking task (e.g. an
/// injected fault in the threaded engine's recovery mode) must not turn
/// the collect phase into a second, misleading `PoisonError` panic —
/// the measures are plain counters, valid after any interrupted `add`,
/// and the *original* panic is the failure that should surface.
#[derive(Debug)]
pub struct EvalSink {
    pub classification: Mutex<ClassificationMeasure>,
    pub regression: Mutex<RegressionMeasure>,
}

/// Lock recovering the value from a poisoned mutex (see [`EvalSink`]).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl EvalSink {
    pub fn new(n_classes: u32, label_range: f64, curve_every: u64) -> Arc<Self> {
        Arc::new(EvalSink {
            classification: Mutex::new(ClassificationMeasure::new(n_classes, curve_every)),
            regression: Mutex::new(RegressionMeasure::new(label_range, curve_every)),
        })
    }

    pub fn accuracy(&self) -> f64 {
        lock_unpoisoned(&self.classification).accuracy()
    }

    pub fn mae(&self) -> f64 {
        lock_unpoisoned(&self.regression).mae()
    }

    pub fn rmse(&self) -> f64 {
        lock_unpoisoned(&self.regression).rmse()
    }
}

/// Test-then-train topology node wrapping any sequential [`Classifier`]:
/// predicts each inbound instance, emits the `Prediction` (so an
/// [`EvaluatorProcessor`] downstream scores it), then trains. This is how
/// sequential learners ride behind topology-level preprocessing
/// ([`crate::preprocess::PipelineProcessor`]) without a bespoke
/// distributed implementation.
pub struct ClassifierProcessor {
    model: Box<dyn Classifier>,
    out: StreamId,
}

impl ClassifierProcessor {
    pub fn new(model: Box<dyn Classifier>, out: StreamId) -> Self {
        ClassifierProcessor { model, out }
    }
}

impl Processor for ClassifierProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, inst } = event {
            let output = match self.model.predict(&inst) {
                Some(c) => Output::Class(c),
                None => Output::None,
            };
            ctx.emit(self.out, id, Event::Prediction { id, truth: inst.label, output });
            if inst.class().is_some() {
                self.model.train(&inst);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.model.model_bytes()
    }

    fn name(&self) -> &'static str {
        "classifier"
    }
}

/// Test-then-train topology node wrapping any sequential [`Regressor`] —
/// the regression twin of [`ClassifierProcessor`], so AMRules (and any
/// future regressor) rides behind topology-level preprocessing too.
/// Predicts each inbound instance, emits the `Prediction`, then trains on
/// instances carrying a numeric label.
pub struct RegressorProcessor {
    model: Box<dyn Regressor>,
    out: StreamId,
}

impl RegressorProcessor {
    pub fn new(model: Box<dyn Regressor>, out: StreamId) -> Self {
        RegressorProcessor { model, out }
    }
}

impl Processor for RegressorProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, inst } = event {
            let output = Output::Numeric(self.model.predict(&inst));
            ctx.emit(self.out, id, Event::Prediction { id, truth: inst.label, output });
            if inst.numeric_label().is_some() {
                self.model.train(&inst);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.model.model_bytes()
    }

    fn name(&self) -> &'static str {
        "regressor"
    }
}

/// Evaluator node: consumes `Prediction` events.
pub struct EvaluatorProcessor {
    pub sink: Arc<EvalSink>,
}

impl Processor for EvaluatorProcessor {
    fn process(&mut self, event: Event, _ctx: &mut Ctx) {
        if let Event::Prediction { truth, output, .. } = event {
            match (truth, output) {
                (Label::Class(t), Output::Class(p)) => {
                    lock_unpoisoned(&self.sink.classification).add(t, Some(p));
                }
                (Label::Class(t), Output::None) => {
                    lock_unpoisoned(&self.sink.classification).add(t, None);
                }
                (Label::Numeric(t), Output::Numeric(p)) => {
                    lock_unpoisoned(&self.sink.regression).add(t, p);
                }
                (Label::Numeric(t), Output::None) => {
                    lock_unpoisoned(&self.sink.regression).add(t, 0.0);
                }
                _ => {}
            }
        }
    }

    fn name(&self) -> &'static str {
        "evaluator"
    }

    /// Final prequential measures, readable across process boundaries
    /// (the cluster engine collects these from worker processes where
    /// the `Arc<EvalSink>` handle is unreachable).
    fn report(&self) -> Vec<(&'static str, f64)> {
        let c = lock_unpoisoned(&self.sink.classification);
        let r = lock_unpoisoned(&self.sink.regression);
        vec![
            ("n", c.n as f64),
            ("correct", c.correct as f64),
            ("accuracy", c.accuracy()),
            ("kappa", c.kappa()),
            ("reg_n", r.n as f64),
            ("mae", r.mae()),
            ("rmse", r.rmse()),
        ]
    }

    /// Two sections: the classification and regression measures'
    /// flattened state. The sink is `Arc`-shared, so a respawned
    /// evaluator's `restore` *rewinds* the shared measures to the
    /// checkpoint cut and the engine's replay re-applies the delta —
    /// the same convergence path as owned state.
    fn snapshot(&self) -> Option<Vec<u8>> {
        use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
        let c = lock_unpoisoned(&self.sink.classification).state_payload();
        let r = lock_unpoisoned(&self.sink.regression).state_payload();
        Some(encode_frame(&[(TAG_META_BASE, c), (TAG_META_BASE + 1, r)]))
    }

    fn restore(&mut self, frame: &[u8]) -> crate::Result<()> {
        use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
        let sections = decode_frame(frame)?;
        let c = section(&sections, TAG_META_BASE)
            .ok_or_else(|| crate::anyhow!("evaluator restore: classification section missing"))?;
        let r = section(&sections, TAG_META_BASE + 1)
            .ok_or_else(|| crate::anyhow!("evaluator restore: regression section missing"))?;
        lock_unpoisoned(&self.sink.classification).restore_payload(c)?;
        lock_unpoisoned(&self.sink.regression).restore_payload(r)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Instance;

    struct Always(u32);
    impl Classifier for Always {
        fn predict(&self, _i: &Instance) -> Option<u32> {
            Some(self.0)
        }
        fn train(&mut self, _i: &Instance) {}
        fn model_bytes(&self) -> usize {
            4
        }
    }

    struct ConstStream {
        schema: crate::core::Schema,
        n: u64,
    }
    impl StreamSource for ConstStream {
        fn schema(&self) -> &crate::core::Schema {
            &self.schema
        }
        fn next_instance(&mut self) -> Option<Instance> {
            if self.n == 0 {
                return None;
            }
            self.n -= 1;
            Some(Instance::dense(vec![0.0], Label::Class((self.n % 2) as u32)))
        }
    }

    #[test]
    fn prequential_accuracy_of_constant_model() {
        let schema =
            crate::core::Schema::classification("c", crate::core::Schema::all_numeric(1), 2);
        let mut model = Always(0);
        let mut stream = ConstStream { schema, n: 1000 };
        let r = prequential_run(&mut model, &mut stream, &PrequentialConfig::default());
        assert_eq!(r.instances, 1000);
        assert!((r.final_accuracy() - 0.5).abs() < 1e-12);
    }

    struct ConstReg(f64);
    impl Regressor for ConstReg {
        fn predict(&self, _i: &Instance) -> f64 {
            self.0
        }
        fn train(&mut self, _i: &Instance) {}
        fn model_bytes(&self) -> usize {
            8
        }
    }

    #[test]
    fn regressor_processor_emits_numeric_predictions() {
        let sink = EvalSink::new(0, 2.0, 100);
        let mut reg = RegressorProcessor::new(Box::new(ConstReg(1.0)), StreamId(0));
        let mut ev = EvaluatorProcessor { sink: Arc::clone(&sink) };
        let mut ctx = Ctx::new(0, 1);
        for i in 0..10u64 {
            reg.process(
                Event::Instance {
                    id: i,
                    inst: Instance::dense(vec![0.0], Label::Numeric(2.0)),
                },
                &mut ctx,
            );
        }
        let emitted = ctx.take();
        assert_eq!(emitted.len(), 10);
        for (_, _, e) in emitted {
            assert!(matches!(
                &e,
                Event::Prediction { truth: Label::Numeric(t), output: Output::Numeric(p), .. }
                if *t == 2.0 && *p == 1.0
            ));
            ev.process(e, &mut ctx);
        }
        assert!((sink.mae() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn evaluator_processor_collects() {
        let sink = EvalSink::new(2, 1.0, 100);
        let mut ev = EvaluatorProcessor { sink: Arc::clone(&sink) };
        let mut ctx = Ctx::new(0, 1);
        for i in 0..10u64 {
            ev.process(
                Event::Prediction {
                    id: i,
                    truth: Label::Class((i % 2) as u32),
                    output: Output::Class(0),
                },
                &mut ctx,
            );
        }
        assert!((sink.accuracy() - 0.5).abs() < 1e-12);
    }
}
