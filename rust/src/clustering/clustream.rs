//! CluStream (Aggarwal et al. 2003), as distributed in SAMOA (paper §5):
//! online **micro-clusters** (cluster-feature vectors) absorbing points
//! within a boundary, periodically compressed into **macro-clusters** by
//! weighted k-means (triggered every `macro_period` points, e.g. 10 000).
//!
//! The nearest-centroid distance scans — batch flush and the per-point
//! worker path alike — go through the backend-selected kernel registry
//! ([`crate::runtime::cluster::assign`]: native, SIMD or XLA artifact);
//! the distributed form runs assignment on worker processors against
//! broadcast centroid snapshots with the aggregator applying updates.

use std::sync::Arc;

use crate::common::memsize::vec_flat_bytes;
use crate::common::Rng;
use crate::core::instance::Instance;
use crate::core::Schema;
use crate::runtime::cluster as rt_cluster;
use crate::topology::{Ctx, Event, Processor, StreamId};

use super::kmeans::kmeans;

/// One micro-cluster: CF vector (n, linear sum, square sum, timestamps).
#[derive(Clone, Debug)]
pub struct MicroCluster {
    pub n: f64,
    pub ls: Vec<f64>,
    pub ss: f64,
    pub t_sum: f64,
}

impl MicroCluster {
    fn new(d: usize) -> Self {
        MicroCluster { n: 0.0, ls: vec![0.0; d], ss: 0.0, t_sum: 0.0 }
    }

    fn seed(x: &[f32], t: f64) -> Self {
        let ls: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let ss = ls.iter().map(|v| v * v).sum();
        MicroCluster { n: 1.0, ls, ss, t_sum: t }
    }

    #[inline]
    pub fn center(&self, out: &mut [f32]) {
        let n = self.n.max(1e-12);
        for (o, &l) in out.iter_mut().zip(&self.ls) {
            *o = (l / n) as f32;
        }
    }

    /// RMS deviation of members from the center (the absorb boundary).
    pub fn radius(&self) -> f64 {
        if self.n < 1.0 {
            return 0.0;
        }
        let mean_sq = self.ss / self.n;
        let center_sq: f64 = self.ls.iter().map(|l| (l / self.n) * (l / self.n)).sum();
        (mean_sq - center_sq).max(0.0).sqrt()
    }

    fn absorb(&mut self, x: &[f32], t: f64) {
        self.n += 1.0;
        for (l, &v) in self.ls.iter_mut().zip(x) {
            *l += v as f64;
        }
        self.ss += x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        self.t_sum += t;
    }

    fn merge(&mut self, other: &MicroCluster) {
        self.n += other.n;
        for (l, o) in self.ls.iter_mut().zip(&other.ls) {
            *l += o;
        }
        self.ss += other.ss;
        self.t_sum += other.t_sum;
    }
}

/// CluStream configuration.
#[derive(Clone, Debug)]
pub struct CluStreamConfig {
    /// Maximum number of micro-clusters (q).
    pub max_micro: usize,
    /// Macro clusters (k of the k-means phase).
    pub k: usize,
    /// Micro-batch period: run macro clustering every this many points.
    pub macro_period: u64,
    /// Boundary factor: absorb when dist ≤ factor × radius.
    pub boundary: f64,
    /// Batch size for XLA-assisted assignment.
    pub batch: usize,
}

impl Default for CluStreamConfig {
    fn default() -> Self {
        CluStreamConfig { max_micro: 100, k: 5, macro_period: 10_000, boundary: 2.0, batch: 64 }
    }
}

/// Sequential CluStream (also the aggregator state of the distributed form).
pub struct CluStream {
    pub config: CluStreamConfig,
    d: usize,
    micro: Vec<MicroCluster>,
    /// flattened centers cache for batch assignment
    centers: Vec<f32>,
    weights: Vec<f32>,
    dirty: bool,
    t: u64,
    pending: Vec<Instance>,
    pub macro_centers: Vec<f32>,
    pub macro_runs: u64,
    rng: Rng,
}

impl CluStream {
    pub fn new(schema: &Schema, config: CluStreamConfig, seed: u64) -> Self {
        let d = schema.n_attributes();
        CluStream {
            config,
            d,
            micro: Vec::new(),
            centers: Vec::new(),
            weights: Vec::new(),
            dirty: true,
            t: 0,
            pending: Vec::new(),
            macro_centers: Vec::new(),
            macro_runs: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn n_micro(&self) -> usize {
        self.micro.len()
    }

    pub fn micro_clusters(&self) -> &[MicroCluster] {
        &self.micro
    }

    fn refresh_cache(&mut self) {
        if !self.dirty {
            return;
        }
        self.centers.resize(self.micro.len() * self.d, 0.0);
        self.weights.resize(self.micro.len(), 0.0);
        for (i, m) in self.micro.iter().enumerate() {
            m.center(&mut self.centers[i * self.d..(i + 1) * self.d]);
            self.weights[i] = m.n as f32;
        }
        self.dirty = false;
    }

    /// Add one point (buffered; batch-flushed through the XLA kernel).
    pub fn add(&mut self, inst: &Instance) {
        self.pending.push(inst.clone());
        if self.pending.len() >= self.config.batch {
            self.flush();
        }
    }

    /// Process buffered points.
    pub fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.pending);
        // batch nearest-centroid assignment (XLA artifact when available)
        let assignments: Vec<Option<(usize, f64)>> = if self.micro.is_empty() {
            vec![None; batch.len()]
        } else {
            self.refresh_cache();
            let mut pts = vec![0f32; batch.len() * self.d];
            for (i, inst) in batch.iter().enumerate() {
                for (a, v) in inst.iter_stored() {
                    if a < self.d {
                        pts[i * self.d + a] = v;
                    }
                }
            }
            rt_cluster::assign(&pts, &self.centers, &self.weights, self.d)
                .into_iter()
                .map(Some)
                .collect()
        };

        let mut point = vec![0f32; self.d];
        for (inst, assignment) in batch.iter().zip(assignments) {
            self.t += 1;
            point.iter_mut().for_each(|p| *p = 0.0);
            for (a, v) in inst.iter_stored() {
                if a < self.d {
                    point[a] = v;
                }
            }
            match assignment {
                Some((idx, d2)) if idx < self.micro.len() => {
                    let m = &self.micro[idx];
                    let r = m.radius();
                    // singleton clusters have zero radius: use distance to
                    // nearest other cluster as a proxy boundary
                    let boundary =
                        if m.n < 2.0 { r.max(d2.sqrt() * 0.5) } else { self.config.boundary * r };
                    if d2.sqrt() <= boundary.max(1e-9) {
                        self.micro[idx].absorb(&point, self.t as f64);
                    } else {
                        self.create(&point);
                    }
                }
                _ => self.create(&point),
            }
            self.dirty = true;
            if self.t % self.config.macro_period == 0 {
                self.run_macro();
            }
        }
    }

    fn create(&mut self, point: &[f32]) {
        if self.micro.len() >= self.config.max_micro {
            // merge the two closest micro-clusters to make room
            self.merge_closest();
        }
        self.micro.push(MicroCluster::seed(point, self.t as f64));
        self.dirty = true;
    }

    fn merge_closest(&mut self) {
        if self.micro.len() < 2 {
            return;
        }
        self.refresh_cache();
        let d = self.d;
        let mut best = (0usize, 1usize, f64::MAX);
        for i in 0..self.micro.len() {
            for j in (i + 1)..self.micro.len() {
                let dist: f64 = (0..d)
                    .map(|x| {
                        let e = (self.centers[i * d + x] - self.centers[j * d + x]) as f64;
                        e * e
                    })
                    .sum();
                if dist < best.2 {
                    best = (i, j, dist);
                }
            }
        }
        let (i, j, _) = best;
        let merged = self.micro[j].clone();
        self.micro[i].merge(&merged);
        self.micro.swap_remove(j);
        self.dirty = true;
    }

    /// Macro phase: weighted k-means over the micro-cluster centers.
    pub fn run_macro(&mut self) {
        if self.micro.is_empty() {
            return;
        }
        self.refresh_cache();
        let weights: Vec<f64> = self.micro.iter().map(|m| m.n).collect();
        let (centers, _sse) =
            kmeans(&self.centers, &weights, self.d, self.config.k, 10, &mut self.rng);
        self.macro_centers = centers;
        self.macro_runs += 1;
    }

    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self
                .micro
                .iter()
                .map(|m| std::mem::size_of::<MicroCluster>() + vec_flat_bytes(&m.ls))
                .sum::<usize>()
            + vec_flat_bytes(&self.centers)
            + vec_flat_bytes(&self.macro_centers)
    }
}

// ------------------------------------------------------ distributed form

/// Worker: assigns points against the latest centroid snapshot and routes
/// them (with the tentative assignment) to the aggregator.
pub struct ClustreamWorker {
    d: usize,
    snapshot_centers: Arc<Vec<f32>>,
    snapshot_weights: Arc<Vec<f32>>,
    out: StreamId,
}

impl ClustreamWorker {
    pub fn new(d: usize, out: StreamId) -> Self {
        ClustreamWorker {
            d,
            snapshot_centers: Arc::new(Vec::new()),
            snapshot_weights: Arc::new(Vec::new()),
            out,
        }
    }
}

impl Processor for ClustreamWorker {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { inst, .. } => {
                let (idx, d2) = if self.snapshot_weights.is_empty() {
                    (u32::MAX, f64::MAX)
                } else {
                    let mut pt = vec![0f32; self.d];
                    for (a, v) in inst.iter_stored() {
                        if a < self.d {
                            pt[a] = v;
                        }
                    }
                    // backend-selected single-point scan: the registry
                    // routes this to the native, SIMD or XLA kernel
                    let res = rt_cluster::assign(
                        &pt,
                        &self.snapshot_centers,
                        &self.snapshot_weights,
                        self.d,
                    );
                    (res[0].0 as u32, res[0].1)
                };
                ctx.emit_any(self.out, Event::ClusterAssign { idx, dist2: d2, inst });
            }
            Event::CentroidSnapshot { centers, weights, .. } => {
                self.snapshot_centers = centers;
                self.snapshot_weights = weights;
            }
            _ => {}
        }
    }

    fn name(&self) -> &'static str {
        "clustream-worker"
    }
}

/// Aggregator: owns the micro-clusters; applies (re-checked) assignments
/// and broadcasts fresh snapshots every `snapshot_every` points.
pub struct ClustreamAggregator {
    pub model: CluStream,
    snapshot_stream: StreamId,
    snapshot_every: u64,
    seen: u64,
    version: u64,
}

impl ClustreamAggregator {
    pub fn new(model: CluStream, snapshot_stream: StreamId, snapshot_every: u64) -> Self {
        ClustreamAggregator { model, snapshot_stream, snapshot_every, seen: 0, version: 0 }
    }
}

impl Processor for ClustreamAggregator {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::ClusterAssign { inst, .. } = event {
            // worker assignment is advisory (snapshot may be stale);
            // the aggregator re-assigns within its own batch pipeline
            self.model.add(&inst);
            self.seen += 1;
            if self.seen % self.snapshot_every == 0 {
                self.model.flush();
                self.model.refresh_cache();
                self.version += 1;
                ctx.emit_any(
                    self.snapshot_stream,
                    Event::CentroidSnapshot {
                        version: self.version,
                        k: self.model.micro.len() as u32,
                        d: self.model.d as u32,
                        centers: Arc::new(self.model.centers.clone()),
                        weights: Arc::new(self.model.weights.clone()),
                    },
                );
            }
        }
    }

    fn on_shutdown(&mut self, _ctx: &mut Ctx) {
        self.model.flush();
        self.model.run_macro();
    }

    fn mem_bytes(&self) -> usize {
        self.model.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "clustream-aggregator"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Label;

    fn blob_instance(rng: &mut Rng, center: f32, d: usize) -> Instance {
        let vals: Vec<f32> = (0..d).map(|_| center + 0.2 * rng.gaussian() as f32).collect();
        Instance::dense(vals, Label::None)
    }

    fn schema(d: usize) -> Schema {
        Schema::classification("c", Schema::all_numeric(d), 2)
    }

    #[test]
    fn micro_clusters_form_around_blobs() {
        let mut rng = Rng::new(1);
        let mut cs = CluStream::new(&schema(4), CluStreamConfig::default(), 7);
        for i in 0..3000 {
            let c = [0.0f32, 5.0, 10.0][i % 3];
            cs.add(&blob_instance(&mut rng, c, 4));
        }
        cs.flush();
        assert!(cs.n_micro() >= 3, "micro={}", cs.n_micro());
        assert!(cs.n_micro() <= cs.config.max_micro);
    }

    #[test]
    fn macro_phase_triggers_periodically() {
        let mut rng = Rng::new(2);
        let cfg = CluStreamConfig { macro_period: 500, k: 3, ..Default::default() };
        let mut cs = CluStream::new(&schema(4), cfg, 8);
        for i in 0..2100 {
            let c = [0.0f32, 5.0, 10.0][i % 3];
            cs.add(&blob_instance(&mut rng, c, 4));
        }
        cs.flush();
        assert!(cs.macro_runs >= 4, "runs={}", cs.macro_runs);
        assert_eq!(cs.macro_centers.len(), 3 * 4);
        // macro centers near the blob centers
        let mut found = [false; 3];
        for c in cs.macro_centers.chunks(4) {
            let m = c.iter().sum::<f32>() / 4.0;
            for (bi, &b) in [0.0f32, 5.0, 10.0].iter().enumerate() {
                if (m - b).abs() < 1.0 {
                    found[bi] = true;
                }
            }
        }
        assert!(found.iter().all(|&f| f), "macro centers {found:?}");
    }

    #[test]
    fn micro_count_bounded_by_merging() {
        let mut rng = Rng::new(3);
        let cfg = CluStreamConfig { max_micro: 10, ..Default::default() };
        let mut cs = CluStream::new(&schema(2), cfg, 9);
        for _ in 0..2000 {
            // uniformly scattered points force constant creation
            let vals = vec![rng.f32() * 100.0, rng.f32() * 100.0];
            cs.add(&Instance::dense(vals, Label::None));
        }
        cs.flush();
        assert!(cs.n_micro() <= 10);
    }
}
