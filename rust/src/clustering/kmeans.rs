//! Weighted k-means — the offline macro-clustering phase of CluStream.

use crate::common::Rng;

/// One k-means run on weighted points. `points` is `n × d` row-major.
/// Returns centroids (`k × d`) and the final weighted SSE.
pub fn kmeans(
    points: &[f32],
    weights: &[f64],
    d: usize,
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> (Vec<f32>, f64) {
    let n = weights.len();
    assert_eq!(points.len(), n * d);
    let k = k.min(n.max(1));
    if n == 0 {
        return (vec![0.0; k * d], 0.0);
    }

    // k-means++ style seeding (weighted)
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.choice_weighted(weights);
    centers.extend_from_slice(&points[first * d..(first + 1) * d]);
    let mut d2 = vec![f64::MAX; n];
    while centers.len() < k * d {
        let c0 = centers.len() / d - 1;
        for p in 0..n {
            let dist = sqdist(&points[p * d..(p + 1) * d], &centers[c0 * d..(c0 + 1) * d]);
            d2[p] = d2[p].min(dist);
        }
        let probs: Vec<f64> = d2.iter().zip(weights).map(|(&a, &w)| a * w + 1e-12).collect();
        let next = rng.choice_weighted(&probs);
        centers.extend_from_slice(&points[next * d..(next + 1) * d]);
    }

    let mut assign = vec![0usize; n];
    let mut sse = 0.0;
    for _ in 0..iters {
        // assignment
        sse = 0.0;
        for p in 0..n {
            let pv = &points[p * d..(p + 1) * d];
            let mut best = (0usize, f64::MAX);
            for c in 0..k {
                let dist = sqdist(pv, &centers[c * d..(c + 1) * d]);
                if dist < best.1 {
                    best = (c, dist);
                }
            }
            assign[p] = best.0;
            sse += best.1 * weights[p];
        }
        // update
        let mut acc = vec![0f64; k * d];
        let mut wsum = vec![0f64; k];
        for p in 0..n {
            let c = assign[p];
            wsum[c] += weights[p];
            for i in 0..d {
                acc[c * d + i] += points[p * d + i] as f64 * weights[p];
            }
        }
        for c in 0..k {
            if wsum[c] > 0.0 {
                for i in 0..d {
                    centers[c * d + i] = (acc[c * d + i] / wsum[c]) as f32;
                }
            }
        }
    }
    (centers, sse)
}

#[inline]
fn sqdist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let e = (x - y) as f64;
            e * e
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::new(1);
        let mut points = Vec::new();
        let mut weights = Vec::new();
        for i in 0..60 {
            let off = if i < 30 { 0.0 } else { 10.0 };
            points.push(off + rng.gaussian() as f32 * 0.3);
            points.push(off + rng.gaussian() as f32 * 0.3);
            weights.push(1.0);
        }
        let (centers, sse) = kmeans(&points, &weights, 2, 2, 10, &mut rng);
        let c0 = (centers[0] + centers[1]) / 2.0;
        let c1 = (centers[2] + centers[3]) / 2.0;
        assert!((c0 - c1).abs() > 5.0, "centers not separated: {centers:?}");
        assert!(sse < 60.0, "sse={sse}");
    }

    #[test]
    fn weights_pull_centroids() {
        let mut rng = Rng::new(2);
        // two points, one heavy: k=1 centroid lands near the heavy one
        let points = vec![0.0f32, 0.0, 10.0, 10.0];
        let weights = vec![9.0, 1.0];
        let (centers, _) = kmeans(&points, &weights, 2, 1, 5, &mut rng);
        assert!(centers[0] < 3.0, "centroid {centers:?} ignored weights");
    }
}
