//! Stream clustering: CluStream micro/macro clusters (paper §5).
pub mod clustream;
pub mod kmeans;
pub mod topology;
