//! Distributed CluStream topology (paper §5): shuffle-grouped assignment
//! workers compute tentative nearest-centroid assignments against
//! broadcast snapshots; a single aggregator owns the micro-clusters and
//! periodically re-broadcasts centroids.
//!
//! ```text
//!            instance (shuffle)            cluster-assign
//!   source ───────────────► workers × p ═══════════════► aggregator
//!                                ▲    centroid snapshot (all)   │
//!                                ╚══════════════════════════════╝
//! ```

use crate::core::Schema;
use crate::topology::{Grouping, ProcessorId, StreamId, Topology, TopologyBuilder};

use super::clustream::{CluStream, CluStreamConfig, ClustreamAggregator, ClustreamWorker};

/// Handles of an assembled CluStream topology.
#[derive(Clone, Copy, Debug)]
pub struct ClustreamHandles {
    pub entry: StreamId,
    pub assign: StreamId,
    pub snapshot: StreamId,
    pub workers: ProcessorId,
    pub aggregator: ProcessorId,
}

/// Build the distributed CluStream topology with `p` assignment workers.
pub fn build_topology(
    schema: &Schema,
    config: CluStreamConfig,
    p: usize,
    seed: u64,
    snapshot_every: u64,
) -> (Topology, ClustreamHandles) {
    let mut b = TopologyBuilder::new("clustream");
    // stream order: 0 entry, 1 assign, 2 snapshot
    let assign = StreamId(1);
    let snapshot = StreamId(2);
    let d = schema.n_attributes();
    let workers = b.add_processor("assign-worker", p, move |_| {
        Box::new(ClustreamWorker::new(d, assign))
    });
    let schema2 = schema.clone();
    let aggregator = b.add_processor("aggregator", 1, move |_| {
        let model = CluStream::new(&schema2, config.clone(), seed);
        Box::new(ClustreamAggregator::new(model, snapshot, snapshot_every))
    });

    let entry = b.stream("instance", None, workers, Grouping::Shuffle);
    let a = b.stream("cluster-assign", Some(workers), aggregator, Grouping::Shuffle);
    let s = b.stream("centroid-snapshot", Some(aggregator), workers, Grouping::All);
    debug_assert_eq!((a, s), (assign, snapshot));

    (b.build(), ClustreamHandles { entry, assign, snapshot, workers, aggregator })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::{Instance, Label};
    use crate::engine::LocalEngine;
    use crate::topology::Event;

    #[test]
    fn distributed_clustream_finds_blobs() {
        let schema = Schema::classification("b", Schema::all_numeric(4), 2);
        let config =
            CluStreamConfig { max_micro: 30, k: 3, macro_period: 100_000, ..Default::default() };
        let (topo, handles) = build_topology(&schema, config, 3, 5, 500);
        let mut rng = Rng::new(1);
        let source = (0..6000u64).map(move |id| {
            let c = [0.0f32, 5.0, 10.0][(id % 3) as usize];
            let vals: Vec<f32> = (0..4).map(|_| c + 0.2 * rng.gaussian() as f32).collect();
            Event::Instance { id, inst: Instance::dense(vals, Label::None) }
        });
        let mut micro = 0usize;
        let metrics = LocalEngine::new().run(&topo, handles.entry, source, |inst| {
            micro = inst[handles.aggregator.0][0].mem_bytes(); // proxy: state grows
        });
        assert_eq!(metrics.source_instances, 6000);
        // snapshots were broadcast back to all workers
        assert!(metrics.streams[handles.snapshot.0].events >= 3 * 3);
        assert!(metrics.streams[handles.assign.0].events == 6000);
        assert!(micro > 0);
    }
}
