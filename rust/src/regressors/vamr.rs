//! VAMR — Vertical AMRules (paper §7.1): one model aggregator holding the
//! simplified rule set (bodies + head snapshots) and the *default rule*,
//! plus `p` learner processors each hosting the full statistics of the
//! rules key-grouped to them.
//!
//! ```text
//!            instance            rule-instance (key: rule id)
//!   source ───────────► MA ═══════════════════════════► learners × p
//!                        ▲   new-rule (key) ──────────►
//!                        ╚═ rule-feature / rule-head / rule-removed ═╝
//!                        └──► prediction ──► evaluator
//! ```
//!
//! The learner re-checks coverage before updating (the MA's body copy may
//! be stale) — with ordered rules this is the temporary inconsistency the
//! paper discusses.

use std::sync::Arc;

use crate::core::instance::{Instance, Label};
use crate::core::model::Regressor;
use crate::core::Schema;
use crate::topology::{
    Ctx, Event, Grouping, Output, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

use super::amrules::{AMRulesConfig, RuleEvent, RuleLearner};
use super::rule::RuleSpec;

/// Stream ids of a VAMR topology (fixed by declaration order).
#[derive(Clone, Copy, Debug)]
pub struct VamrStreamIds {
    pub rule_instance: StreamId,
    pub new_rule: StreamId,
    pub rule_updates: StreamId,
    pub prediction: StreamId,
}

/// The VAMR model aggregator.
pub struct VamrAggregator {
    schema: Schema,
    config: AMRulesConfig,
    streams: VamrStreamIds,
    /// simplified replicated rules (ordered)
    specs: Vec<(u32, RuleSpec)>,
    /// the default rule learns fully at the MA (§7.1)
    default_rule: RuleLearner,
    next_id: u32,
    pub stats: VamrMaStats,
}

#[derive(Clone, Debug, Default)]
pub struct VamrMaStats {
    pub instances: u64,
    pub forwarded: u64,
    pub rules_created: u64,
    pub rules_removed: u64,
    pub features_applied: u64,
}

impl VamrAggregator {
    pub fn new(schema: Schema, config: AMRulesConfig, streams: VamrStreamIds) -> Self {
        let default_rule = RuleLearner::new(RuleSpec::default(), &schema, &config);
        VamrAggregator {
            schema,
            config,
            streams,
            specs: Vec::new(),
            default_rule,
            next_id: 0,
            stats: VamrMaStats::default(),
        }
    }

    fn predict(&self, inst: &Instance) -> f64 {
        for (_, spec) in &self.specs {
            if spec.covers(inst) {
                return spec.head.predict(inst);
            }
        }
        self.default_rule.predict(inst)
    }

    fn train(&mut self, inst: Instance, y: f64, ctx: &mut Ctx) {
        // ordered: first covering (by the possibly-stale bodies) forwards
        for (id, spec) in &self.specs {
            if spec.covers(&inst) {
                self.stats.forwarded += 1;
                ctx.emit(
                    self.streams.rule_instance,
                    *id as u64,
                    Event::RuleInstance { rule: *id, inst },
                );
                return;
            }
        }
        // uncovered: default rule learns here
        match self.default_rule.update(&inst, y) {
            RuleEvent::Expanded(_) => {
                let id = self.next_id;
                self.next_id += 1;
                self.stats.rules_created += 1;
                let spec = RuleSpec {
                    features: self.default_rule.spec.features.clone(),
                    head: self.default_rule.head(),
                };
                self.specs.push((id, spec.clone()));
                // hand the full rule to its learner (Arc: the event clone
                // along the way shares, not copies, the spec)
                ctx.emit(
                    self.streams.new_rule,
                    id as u64,
                    Event::NewRule { rule: id, spec: Arc::new(spec) },
                );
                // fresh default rule
                self.default_rule =
                    RuleLearner::new(RuleSpec::default(), &self.schema, &self.config);
            }
            RuleEvent::Evict => {
                self.default_rule =
                    RuleLearner::new(RuleSpec::default(), &self.schema, &self.config)
            }
            _ => {}
        }
    }
}

impl Processor for VamrAggregator {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { id, inst } => {
                self.stats.instances += 1;
                let output = Output::Numeric(self.predict(&inst));
                ctx.emit_any(
                    self.streams.prediction,
                    Event::Prediction { id, truth: inst.label, output },
                );
                if let Some(y) = inst.numeric_label() {
                    self.train(inst, y, ctx);
                }
            }
            Event::RuleFeature { rule, feature, head } => {
                if let Some((_, spec)) = self.specs.iter_mut().find(|(id, _)| *id == rule) {
                    spec.features.push(feature);
                    spec.head = Arc::try_unwrap(head).unwrap_or_else(|h| (*h).clone());
                    self.stats.features_applied += 1;
                }
            }
            Event::RuleHead { rule, head } => {
                if let Some((_, spec)) = self.specs.iter_mut().find(|(id, _)| *id == rule) {
                    spec.head = Arc::try_unwrap(head).unwrap_or_else(|h| (*h).clone());
                }
            }
            Event::RuleRemoved { rule } => {
                self.specs.retain(|(id, _)| *id != rule);
                self.stats.rules_removed += 1;
            }
            _ => {}
        }
    }

    fn mem_bytes(&self) -> usize {
        use crate::common::MemSize;
        std::mem::size_of::<Self>()
            + self
                .specs
                .iter()
                .map(|(_, s)| 64 + 16 * s.features.len())
                .sum::<usize>()
            + self.default_rule.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "vamr-model-aggregator"
    }
}

/// A VAMR/HAMR learner processor: hosts the rules key-grouped to it.
pub struct RuleLearnerProcessor {
    schema: Schema,
    config: AMRulesConfig,
    streams: VamrStreamIds,
    rules: Vec<(u32, RuleLearner)>,
    /// emit a head refresh every N covered updates per rule
    head_refresh: u32,
    pub dropped_uncovered: u64,
}

impl RuleLearnerProcessor {
    pub fn new(schema: Schema, config: AMRulesConfig, streams: VamrStreamIds) -> Self {
        RuleLearnerProcessor {
            schema,
            config,
            streams,
            rules: Vec::new(),
            head_refresh: 200,
            dropped_uncovered: 0,
        }
    }

    pub fn n_rules(&self) -> usize {
        self.rules.len()
    }
}

impl Processor for RuleLearnerProcessor {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::NewRule { rule, spec } => {
                // the learner owns its copy; unwrap the Arc without a copy
                // when this was the only (Key-routed) recipient
                let spec = Arc::try_unwrap(spec).unwrap_or_else(|s| (*s).clone());
                let mut learner = RuleLearner::new(spec, &self.schema, &self.config);
                // reset expansion counter: statistics start fresh here
                learner.total_updates = 0;
                self.rules.push((rule, learner));
            }
            Event::RuleInstance { rule, inst } => {
                let Some(y) = inst.numeric_label() else { return };
                let Some(pos) = self.rules.iter().position(|(id, _)| *id == rule) else {
                    return;
                };
                let learner = &mut self.rules[pos].1;
                // coverage re-check: MA may have been stale (§7.1)
                if !learner.spec.covers(&inst) {
                    self.dropped_uncovered += 1;
                    return;
                }
                match learner.update(&inst, y) {
                    RuleEvent::Expanded(f) => {
                        let head = Arc::new(learner.head());
                        ctx.emit_any(
                            self.streams.rule_updates,
                            Event::RuleFeature { rule, feature: f, head },
                        );
                    }
                    RuleEvent::Evict => {
                        self.rules.remove(pos);
                        ctx.emit_any(self.streams.rule_updates, Event::RuleRemoved { rule });
                    }
                    RuleEvent::None => {
                        if learner.total_updates % self.head_refresh as u64 == 0 {
                            let head = Arc::new(learner.head());
                            ctx.emit_any(self.streams.rule_updates, Event::RuleHead { rule, head });
                        }
                    }
                    RuleEvent::Anomaly => {}
                }
            }
            _ => {}
        }
    }

    fn mem_bytes(&self) -> usize {
        use crate::common::MemSize;
        std::mem::size_of::<Self>()
            + self.rules.iter().map(|(_, r)| 4 + r.mem_bytes()).sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "amrules-learner"
    }
}

/// Handles of an assembled VAMR topology.
#[derive(Clone, Copy, Debug)]
pub struct VamrHandles {
    pub entry: StreamId,
    pub streams: VamrStreamIds,
    pub ma: ProcessorId,
    pub learners: ProcessorId,
    pub evaluator: ProcessorId,
}

/// Build the VAMR topology (Fig. 10 left): 1 MA + p learners.
pub fn build_topology(
    schema: &Schema,
    config: &AMRulesConfig,
    p: usize,
    evaluator: impl Fn(usize) -> Box<dyn crate::topology::Processor> + 'static,
) -> (Topology, VamrHandles) {
    let mut b = TopologyBuilder::new("vamr");
    let eval = b.add_processor("evaluator", 1, evaluator);
    // stream order: 0 entry, 1 rule-instance, 2 new-rule, 3 rule-updates,
    // 4 prediction
    let ids = VamrStreamIds {
        rule_instance: StreamId(1),
        new_rule: StreamId(2),
        rule_updates: StreamId(3),
        prediction: StreamId(4),
    };
    let (s_ma, c_ma) = (schema.clone(), config.clone());
    let ma = b.add_processor("model-aggregator", 1, move |_| {
        Box::new(VamrAggregator::new(s_ma.clone(), c_ma.clone(), ids))
    });
    let (s_l, c_l) = (schema.clone(), config.clone());
    let learners = b.add_processor("learner", p, move |_| {
        Box::new(RuleLearnerProcessor::new(s_l.clone(), c_l.clone(), ids))
    });

    let entry = b.stream("instance", None, ma, Grouping::Shuffle);
    let ri = b.stream("rule-instance", Some(ma), learners, Grouping::Key);
    let nr = b.stream("new-rule", Some(ma), learners, Grouping::Key);
    let ru = b.stream("rule-updates", Some(learners), ma, Grouping::Shuffle);
    let pr = b.stream("prediction", Some(ma), eval, Grouping::Shuffle);
    debug_assert_eq!(
        (ri, nr, ru, pr),
        (ids.rule_instance, ids.new_rule, ids.rule_updates, ids.prediction)
    );

    (b.build(), VamrHandles { entry, streams: ids, ma, learners, evaluator: eval })
}

/// Sequential driver: runs the VAMR topology on the local engine behind
/// the [`Regressor`] interface — used for cross-checking against MAMR in
/// tests (with zero feedback delay the rule set must evolve like MAMR's).
pub struct VamrLocal {
    agg: VamrAggregator,
    learner: RuleLearnerProcessor,
}

impl VamrLocal {
    pub fn new(schema: Schema, config: AMRulesConfig) -> Self {
        let ids = VamrStreamIds {
            rule_instance: StreamId(1),
            new_rule: StreamId(2),
            rule_updates: StreamId(3),
            prediction: StreamId(4),
        };
        VamrLocal {
            agg: VamrAggregator::new(schema.clone(), config.clone(), ids),
            learner: RuleLearnerProcessor::new(schema, config, ids),
        }
    }

    /// Deliver queued emissions between MA and learner until quiescent.
    fn pump(&mut self, out: Vec<(StreamId, u64, Event)>) {
        let mut queue = out;
        while !queue.is_empty() {
            let mut next = Vec::new();
            for (stream, _key, ev) in queue.drain(..) {
                let mut ctx = Ctx::new(0, 1);
                match stream.0 {
                    1 | 2 => self.learner.process(ev, &mut ctx),
                    3 => self.agg.process(ev, &mut ctx),
                    _ => {}
                }
                next.extend(ctx.take());
            }
            queue = next;
        }
    }
}

impl Regressor for VamrLocal {
    fn predict(&self, inst: &Instance) -> f64 {
        self.agg.predict(inst)
    }

    fn train(&mut self, inst: &Instance) {
        let mut ctx = Ctx::new(0, 1);
        self.agg.process(
            Event::Instance { id: 0, inst: inst.clone() },
            &mut ctx,
        );
        self.pump(ctx.take());
    }

    fn model_bytes(&self) -> usize {
        self.agg.mem_bytes() + self.learner.mem_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    fn schema() -> Schema {
        Schema::regression("pw", Schema::all_numeric(2), -12.0, 12.0)
    }

    fn piecewise(rng: &mut Rng) -> Instance {
        let x0 = rng.f32();
        let y = if x0 <= 0.5 { 10.0 } else { -10.0 } + 0.2 * rng.gaussian();
        Instance::dense(vec![x0, rng.f32()], Label::Numeric(y))
    }

    #[test]
    fn vamr_local_learns_like_mamr() {
        let mut rng = Rng::new(1);
        let mut m = VamrLocal::new(schema(), AMRulesConfig::default());
        for _ in 0..20_000 {
            m.train(&piecewise(&mut rng));
        }
        let lo = m.predict(&Instance::dense(vec![0.2, 0.5], Label::None));
        let hi = m.predict(&Instance::dense(vec![0.8, 0.5], Label::None));
        assert!(lo > hi + 5.0, "lo={lo} hi={hi}");
        assert!(m.agg.stats.rules_created >= 1);
        assert!(m.learner.n_rules() >= 1);
    }

    #[test]
    fn learner_drops_uncovered_after_expansion() {
        // send an instance to a learner whose rule no longer covers it
        let ids = VamrStreamIds {
            rule_instance: StreamId(1),
            new_rule: StreamId(2),
            rule_updates: StreamId(3),
            prediction: StreamId(4),
        };
        let mut l = RuleLearnerProcessor::new(schema(), AMRulesConfig::default(), ids);
        let mut ctx = Ctx::new(0, 1);
        let spec = RuleSpec {
            features: vec![super::super::rule::Feature {
                attr: 0,
                op: super::super::rule::Op::Le,
                threshold: 0.5,
            }],
            head: Default::default(),
        };
        l.process(Event::NewRule { rule: 0, spec: Arc::new(spec) }, &mut ctx);
        l.process(
            Event::RuleInstance {
                rule: 0,
                inst: Instance::dense(vec![0.9, 0.0], Label::Numeric(1.0)),
            },
            &mut ctx,
        );
        assert_eq!(l.dropped_uncovered, 1);
    }
}

impl VamrLocal {
    /// Debug helper for examples (not part of the public API contract).
    pub fn debug_dump(&self) {
        println!("MA stats: {:?}", self.agg.stats);
        for (id, spec) in &self.agg.specs {
            println!("spec {id}: {:?} head.mean={}", spec.features, spec.head.mean);
        }
        println!("learner rules: {}", self.learner.n_rules());
        let (n, mean, sd, em, ep) = self.agg.default_rule.debug_state();
        println!("default: n={n} mean={mean} sd={sd} err_mean={em} err_perc={ep}");
    }
}
