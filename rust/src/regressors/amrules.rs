//! Sequential AMRules (Almeida/Ikonomovska/Gama; paper §7) — the **MAMR**
//! baseline and the building block reused by the distributed VAMR/HAMR:
//! [`RuleLearner`] (one rule's statistics + expansion + drift/anomaly
//! logic) is exactly what VAMR/HAMR learner processors host remotely.
//!
//! * Ordered-rules mode (the paper's focus): first covering rule predicts
//!   and is updated.
//! * Expansion every `n_min` updates via the SDR criterion evaluated by
//!   [`crate::runtime::sdr`]'s batch-of-attributes entry point (native,
//!   SIMD or XLA artifact, registry-selected) with the
//!   Hoeffding-bound ratio test: expand when `ratio + ε < 1` or `ε < τ`.
//! * Each rule monitors its absolute error with Page–Hinkley and is
//!   evicted on drift; covered instances failing a z-score anomaly test
//!   are skipped.

use crate::common::memsize::vec_flat_bytes;
use crate::common::MemSize;
use crate::core::criterion::VarStats;
use crate::core::instance::Instance;
use crate::core::model::Regressor;
use crate::core::observers::Binner;
use crate::core::Schema;
use crate::drift::page_hinkley::PageHinkley;
use crate::drift::ChangeDetector;
use crate::runtime::sdr;

use super::rule::{Feature, HeadSnapshot, Op, RuleSpec};

/// AMRules hyperparameters.
#[derive(Clone, Debug)]
pub struct AMRulesConfig {
    /// Updates between expansion attempts (N_m).
    pub n_min: u32,
    /// Hoeffding-bound confidence for the SDR ratio test.
    pub delta: f64,
    /// Tie threshold: expand when ε < τ.
    pub tau: f64,
    /// Histogram bins per attribute for candidate thresholds (≤ 64).
    pub bins: u32,
    /// Page–Hinkley (α, λ) for rule eviction.
    pub ph_alpha: f64,
    pub ph_lambda: f64,
    /// Covered instances with |target z-score| above this are anomalies
    /// (0 disables).
    pub anomaly_z: f64,
    /// Ordered-rules mode (the paper's setting).
    pub ordered: bool,
    /// Cap on rule-set size (0 = unlimited).
    pub max_rules: usize,
}

impl Default for AMRulesConfig {
    fn default() -> Self {
        AMRulesConfig {
            n_min: 200,
            delta: 1e-7,
            tau: 0.05,
            bins: 64,
            ph_alpha: 0.005,
            ph_lambda: 35.0,
            anomaly_z: 3.0,
            ordered: true,
            max_rules: 0,
        }
    }
}

/// What a rule decides after one update.
#[derive(Clone, Debug, PartialEq)]
pub enum RuleEvent {
    None,
    /// Expanded with a new feature (already applied to the local spec).
    Expanded(Feature),
    /// Page–Hinkley fired: evict this rule.
    Evict,
    /// Instance rejected as an anomaly (not absorbed).
    Anomaly,
}

/// One rule's full learning state (hosted in-process by MAMR, remotely by
/// the VAMR/HAMR learner processors).
pub struct RuleLearner {
    pub spec: RuleSpec,
    /// target stats of covered instances since last expansion
    target: VarStats,
    /// per-attribute per-bin target stats
    attr_bins: Vec<Vec<VarStats>>,
    binners: Vec<Binner>,
    /// linear head state
    weights: Vec<f64>,
    lr: f64,
    /// adaptive head choice: recent absolute errors of each head
    err_mean: f64,
    err_perc: f64,
    ph: PageHinkley,
    updates_since_attempt: u32,
    pub total_updates: u64,
    /// Fading fraction of updates rejected as anomalies. Outliers are
    /// rare by definition; a high sustained rate means the target
    /// distribution genuinely moved (drift) — stop skipping so
    /// Page–Hinkley can see it. (A consecutive-run counter would fail on
    /// interleaved regimes.)
    anomaly_rate: f64,
    config: AMRulesConfig,
}

impl RuleLearner {
    pub fn new(spec: RuleSpec, schema: &Schema, config: &AMRulesConfig) -> Self {
        let a = schema.n_attributes();
        RuleLearner {
            spec,
            target: VarStats::default(),
            attr_bins: vec![vec![VarStats::default(); config.bins as usize]; a],
            binners: (0..a).map(|_| Binner::new(config.bins)).collect(),
            weights: vec![0.0; a + 1],
            lr: 0.01,
            err_mean: 0.0,
            err_perc: 0.0,
            ph: PageHinkley::new(config.ph_alpha, config.ph_lambda),
            updates_since_attempt: 0,
            total_updates: 0,
            anomaly_rate: 0.0,
            config: config.clone(),
        }
    }

    /// Current prediction (adaptive head: mean vs perceptron).
    pub fn predict(&self, inst: &Instance) -> f64 {
        if self.target.n < 1.0 {
            return 0.0;
        }
        if self.err_perc < self.err_mean && self.target.n > 30.0 {
            self.perceptron(inst)
        } else {
            self.target.mean()
        }
    }

    fn perceptron(&self, inst: &Instance) -> f64 {
        let mut y = self.weights[self.weights.len() - 1];
        for (i, v) in inst.iter_stored() {
            if i < self.weights.len() - 1 {
                y += self.weights[i] * v as f64;
            }
        }
        // perceptron predicts the residual scale around the mean
        self.target.mean() + y * self.target.sd().max(1e-9)
    }

    /// Head snapshot for replication at model aggregators.
    pub fn head(&self) -> HeadSnapshot {
        HeadSnapshot { mean: self.target.mean(), weights: None }
    }

    /// Is `inst` anomalous w.r.t. this rule's past targets?
    pub fn is_anomaly(&self, y: f64) -> bool {
        if self.config.anomaly_z <= 0.0 || self.target.n < 30.0 {
            return false;
        }
        let sd = self.target.sd();
        if sd < 1e-9 {
            return false;
        }
        ((y - self.target.mean()) / sd).abs() > self.config.anomaly_z
    }

    /// Update with a covered instance; may expand or request eviction.
    pub fn update(&mut self, inst: &Instance, y: f64) -> RuleEvent {
        let anomalous = self.is_anomaly(y);
        self.anomaly_rate = 0.98 * self.anomaly_rate + if anomalous { 0.02 } else { 0.0 };
        // skip genuine outliers, but a sustained anomaly *rate* is drift —
        // let those instances through so Page–Hinkley can fire
        if anomalous && self.anomaly_rate < 0.3 {
            return RuleEvent::Anomaly;
        }
        // drift check on absolute error of the *current* prediction
        let pred = self.predict(inst);
        let abs_err = (y - pred).abs();
        self.ph.add(abs_err);
        if self.ph.detected() {
            return RuleEvent::Evict;
        }
        // head error tracking (fading)
        let e_mean = (y - self.target.mean()).abs();
        let e_perc = (y - self.perceptron(inst)).abs();
        self.err_mean = 0.99 * self.err_mean + 0.01 * e_mean;
        self.err_perc = 0.99 * self.err_perc + 0.01 * e_perc;

        // statistics
        let w = inst.weight as f64;
        self.target.add(y, w);
        for (a, v) in inst.iter_stored() {
            if a < self.attr_bins.len() {
                let bin = self.binners[a].observe(v) as usize;
                let last = self.attr_bins[a].len() - 1;
                self.attr_bins[a][bin.min(last)].add(y, w);
            }
        }
        // perceptron (residual form, normalized lr)
        let sd = self.target.sd().max(1e-9);
        let resid = (y - self.target.mean()) / sd;
        let pred_r = (self.perceptron(inst) - self.target.mean()) / sd;
        let err = resid - pred_r;
        let last = self.weights.len() - 1;
        self.weights[last] += self.lr * err;
        for (i, v) in inst.iter_stored() {
            if i < last {
                self.weights[i] += self.lr * err * (v as f64).clamp(-10.0, 10.0);
            }
        }

        self.total_updates += 1;
        self.updates_since_attempt += 1;
        if self.updates_since_attempt >= self.config.n_min {
            self.updates_since_attempt = 0;
            if let Some(f) = self.try_expand() {
                return RuleEvent::Expanded(f);
            }
        }
        RuleEvent::None
    }

    /// SDR ratio test over the best candidate of each attribute.
    ///
    /// Candidates at adjacent thresholds of the *same* attribute always
    /// have near-identical SDR, so — as in FIMT-DD — the Hoeffding ratio
    /// compares the best split of the best attribute against the best
    /// split of the runner-up *attribute*; a usefulness guard additionally
    /// requires the best SDR to be a meaningful fraction of the current
    /// target sd (blocks tie-break expansions on pure noise).
    fn try_expand(&mut self) -> Option<Feature> {
        let surfaces = sdr::sdr_surfaces(&self.attr_bins);
        // best (bin, sdr) per attribute
        let (mut best, mut second) = ((0usize, 0usize, 0.0f64), 0.0f64);
        for (a, surf) in surfaces.iter().enumerate() {
            let mut attr_best = (0usize, 0.0f64);
            for (b, &v) in surf.iter().enumerate() {
                if v > attr_best.1 {
                    attr_best = (b, v);
                }
            }
            if attr_best.1 > best.2 {
                second = best.2;
                best = (a, attr_best.0, attr_best.1);
            } else if attr_best.1 > second {
                second = attr_best.1;
            }
        }
        // usefulness guard: the split must reduce a meaningful share of
        // the current sd — noise SDR is O(sd/√n) which stays below 10%
        // after the n_min warm-up, while genuine structure is far above
        if best.2 <= 0.1 * self.target.sd().max(1e-9) {
            return None;
        }
        let ratio = second / best.2;
        let n = self.target.n;
        let eps = crate::core::hoeffding::hoeffding_bound(1.0, self.config.delta, n);
        if ratio + eps < 1.0 || eps < self.config.tau {
            let (a, b, _) = best;
            // keep the lower-sd side of the split
            let left: VarStats = self.attr_bins[a][..=b]
                .iter()
                .fold(VarStats::default(), |x, y| x.merge(y));
            let right = self.target.sub(&left);
            let threshold = self.binners[a].threshold(b as u32);
            let op = if left.sd() <= right.sd() { Op::Le } else { Op::Gt };
            let feature = Feature { attr: a as u32, op, threshold };
            self.spec.features.push(feature);
            // restart statistics (head/target keep a decayed memory via
            // the chosen side's stats)
            let kept = if op == Op::Le { left } else { right };
            self.target = kept;
            for bins in self.attr_bins.iter_mut() {
                for s in bins.iter_mut() {
                    *s = VarStats::default();
                }
            }
            self.ph.reset();
            Some(feature)
        } else {
            None
        }
    }
}

impl MemSize for RuleLearner {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.attr_bins.iter().map(vec_flat_bytes).sum::<usize>()
            + vec_flat_bytes(&self.weights)
            + self.spec.features.len() * std::mem::size_of::<Feature>()
            + self.binners.iter().map(|b| b.mem_bytes()).sum::<usize>()
    }
}

/// Statistics for Table 5.
#[derive(Clone, Debug, Default)]
pub struct AMRulesStats {
    pub rules_created: u64,
    pub rules_removed: u64,
    pub features_created: u64,
    pub anomalies: u64,
}

/// The sequential AMRules regressor (MAMR).
pub struct AMRules {
    schema: Schema,
    config: AMRulesConfig,
    rules: Vec<(u32, RuleLearner)>,
    default_rule: RuleLearner,
    next_id: u32,
    pub stats: AMRulesStats,
}

impl AMRules {
    pub fn new(schema: Schema, config: AMRulesConfig) -> Self {
        let default_rule = RuleLearner::new(RuleSpec::default(), &schema, &config);
        AMRules {
            schema,
            config,
            rules: Vec::new(),
            default_rule,
            next_id: 0,
            stats: AMRulesStats::default(),
        }
    }

    pub fn n_rules(&self) -> usize {
        self.rules.len()
    }

    pub fn rule_specs(&self) -> impl Iterator<Item = (&u32, &RuleSpec)> {
        self.rules.iter().map(|(id, r)| (id, &r.spec))
    }
}

impl Regressor for AMRules {
    /// Ordered mode: first covering rule predicts; else the default rule.
    fn predict(&self, inst: &Instance) -> f64 {
        for (_, r) in &self.rules {
            if r.spec.covers(inst) {
                return r.predict(inst);
            }
        }
        self.default_rule.predict(inst)
    }

    fn train(&mut self, inst: &Instance) {
        let Some(y) = inst.numeric_label() else { return };
        // ordered: first covering rule absorbs (anomalies fall through)
        let mut evict: Option<usize> = None;
        let mut covered = false;
        for (i, (_, r)) in self.rules.iter_mut().enumerate() {
            if r.spec.covers(inst) {
                match r.update(inst, y) {
                    RuleEvent::Anomaly => {
                        self.stats.anomalies += 1;
                        continue; // treated as not covered (paper §7)
                    }
                    RuleEvent::Evict => {
                        evict = Some(i);
                    }
                    RuleEvent::Expanded(_) => {
                        self.stats.features_created += 1;
                    }
                    RuleEvent::None => {}
                }
                covered = true;
                break;
            }
        }
        if let Some(i) = evict {
            self.rules.remove(i);
            self.stats.rules_removed += 1;
        }
        if covered {
            return;
        }
        // default rule
        match self.default_rule.update(inst, y) {
            RuleEvent::Expanded(_) => {
                // default became a normal rule; fresh default replaces it
                self.stats.rules_created += 1;
                self.stats.features_created += 1;
                let spec = self.default_rule.spec.clone();
                let fresh = RuleLearner::new(RuleSpec::default(), &self.schema, &self.config);
                let mut promoted = std::mem::replace(&mut self.default_rule, fresh);
                promoted.spec = spec;
                if self.config.max_rules == 0 || self.rules.len() < self.config.max_rules {
                    self.rules.push((self.next_id, promoted));
                    self.next_id += 1;
                }
            }
            RuleEvent::Evict => {
                self.default_rule.ph.reset();
            }
            _ => {}
        }
    }

    fn model_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.rules.iter().map(|(_, r)| 4 + r.mem_bytes()).sum::<usize>()
            + self.default_rule.mem_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    fn piecewise(rng: &mut Rng) -> Instance {
        // y = 10 if x0 <= 0.5 else -10, plus small noise
        let x0 = rng.f32();
        let x1 = rng.f32();
        let y = if x0 <= 0.5 { 10.0 } else { -10.0 } + 0.2 * rng.gaussian();
        Instance::dense(vec![x0, x1], Label::Numeric(y))
    }

    fn schema() -> Schema {
        Schema::regression("pw", Schema::all_numeric(2), -12.0, 12.0)
    }

    #[test]
    fn learns_piecewise_concept() {
        let mut rng = Rng::new(1);
        let mut m = AMRules::new(schema(), AMRulesConfig::default());
        for _ in 0..20_000 {
            m.train(&piecewise(&mut rng));
        }
        assert!(m.stats.rules_created >= 1, "no rules created");
        // predictions should separate the two regimes
        let lo = m.predict(&Instance::dense(vec![0.2, 0.5], Label::None));
        let hi = m.predict(&Instance::dense(vec![0.8, 0.5], Label::None));
        assert!(lo > hi + 5.0, "lo={lo} hi={hi}");
    }

    #[test]
    fn default_rule_predicts_before_any_rule() {
        let mut rng = Rng::new(2);
        let mut m = AMRules::new(schema(), AMRulesConfig::default());
        for _ in 0..50 {
            let mut i = piecewise(&mut rng);
            i.label = Label::Numeric(5.0);
            m.train(&i);
        }
        let p = m.predict(&Instance::dense(vec![0.5, 0.5], Label::None));
        assert!((p - 5.0).abs() < 1.0, "p={p}");
    }

    #[test]
    fn drift_evicts_rules() {
        let mut rng = Rng::new(3);
        let mut m = AMRules::new(schema(), AMRulesConfig::default());
        for _ in 0..15_000 {
            m.train(&piecewise(&mut rng));
        }
        // flip the concept violently
        for _ in 0..15_000 {
            let x0 = rng.f32();
            let y = if x0 <= 0.5 { -50.0 } else { 50.0 };
            m.train(&Instance::dense(vec![x0, rng.f32()], Label::Numeric(y)));
        }
        assert!(m.stats.rules_removed > 0, "no rule evicted after drift");
    }

    #[test]
    fn anomalies_skipped() {
        let mut rng = Rng::new(4);
        let cfg = AMRulesConfig { anomaly_z: 3.0, ..Default::default() };
        let mut m = AMRules::new(schema(), cfg);
        for i in 0..5000 {
            let mut inst = piecewise(&mut rng);
            if i % 500 == 499 {
                inst.label = Label::Numeric(1e4); // wild outlier
            }
            m.train(&inst);
        }
        assert!(m.stats.anomalies > 0);
    }

    #[test]
    fn feature_count_grows_with_complexity() {
        let mut rng = Rng::new(5);
        let mut m = AMRules::new(
            Schema::regression("c", Schema::all_numeric(4), -40.0, 40.0),
            AMRulesConfig::default(),
        );
        for _ in 0..30_000 {
            let x: Vec<f32> = (0..4).map(|_| rng.f32()).collect();
            let y = (x[0] > 0.5) as u32 as f64 * 20.0 + (x[1] > 0.3) as u32 as f64 * 10.0
                - (x[2] > 0.7) as u32 as f64 * 15.0
                + 0.3 * rng.gaussian();
            m.train(&Instance::dense(x, Label::Numeric(y)));
        }
        assert!(m.stats.features_created >= 2, "features={}", m.stats.features_created);
    }
}

impl RuleLearner {
    /// Debug introspection (examples only).
    pub fn debug_state(&self) -> (f64, f64, f64, f64, f64) {
        (self.target.n, self.target.mean(), self.target.sd(), self.err_mean, self.err_perc)
    }
}
