//! Regression on streams: AMRules (paper §7) — sequential (MAMR),
//! vertically parallel (VAMR), and hybrid (HAMR).

pub mod rule;
pub mod amrules;
pub mod vamr;
pub mod hamr;


