//! Decision rules: `IF antecedent THEN consequent` (paper §7).
//!
//! A rule body is a conjunction of [`Feature`]s (conditions on attributes);
//! the head predicts the target for covered instances. `RuleSpec` is the
//! *simplified* rule replicated at model aggregators: body + head only, no
//! statistics (§7.1).

use crate::core::Instance;

/// Comparison operator of a feature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// attribute ≤ threshold
    Le,
    /// attribute > threshold
    Gt,
    /// attribute == threshold (categorical)
    Eq,
}

/// One condition on one attribute.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Feature {
    pub attr: u32,
    pub op: Op,
    pub threshold: f64,
}

impl Feature {
    #[inline]
    pub fn covers(&self, inst: &Instance) -> bool {
        let v = inst.value(self.attr as usize) as f64;
        match self.op {
            Op::Le => v <= self.threshold,
            Op::Gt => v > self.threshold,
            Op::Eq => (v - self.threshold).abs() < 1e-9,
        }
    }
}

/// Prediction head: adaptively chooses between target-mean and perceptron
/// (the standard AMRules head; see `amrules::Perceptron`).
#[derive(Clone, Debug, Default)]
pub struct HeadSnapshot {
    /// Target mean of covered instances.
    pub mean: f64,
    /// Perceptron weights (len = n_attributes + 1 bias), if trained.
    pub weights: Option<Vec<f64>>,
}

impl HeadSnapshot {
    pub fn predict(&self, inst: &Instance) -> f64 {
        match &self.weights {
            Some(w) => {
                let mut y = w[w.len() - 1];
                for (i, v) in inst.iter_stored() {
                    if i < w.len() - 1 {
                        y += w[i] * v as f64;
                    }
                }
                y
            }
            None => self.mean,
        }
    }
}

/// Body + head, as replicated at model aggregators (no statistics).
#[derive(Clone, Debug, Default)]
pub struct RuleSpec {
    pub features: Vec<Feature>,
    pub head: HeadSnapshot,
}

impl RuleSpec {
    /// Does the rule body cover the instance?
    #[inline]
    pub fn covers(&self, inst: &Instance) -> bool {
        self.features.iter().all(|f| f.covers(inst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::Label;

    fn inst(vals: &[f32]) -> Instance {
        Instance::dense(vals.to_vec(), Label::Numeric(0.0))
    }

    #[test]
    fn feature_covers() {
        let f = Feature { attr: 1, op: Op::Le, threshold: 5.0 };
        assert!(f.covers(&inst(&[0.0, 4.0])));
        assert!(!f.covers(&inst(&[0.0, 6.0])));
        let g = Feature { attr: 0, op: Op::Gt, threshold: 1.0 };
        assert!(g.covers(&inst(&[2.0, 0.0])));
    }

    #[test]
    fn conjunction_all_must_hold() {
        let spec = RuleSpec {
            features: vec![
                Feature { attr: 0, op: Op::Gt, threshold: 1.0 },
                Feature { attr: 1, op: Op::Le, threshold: 3.0 },
            ],
            head: HeadSnapshot::default(),
        };
        assert!(spec.covers(&inst(&[2.0, 2.0])));
        assert!(!spec.covers(&inst(&[2.0, 4.0])));
        assert!(!spec.covers(&inst(&[0.0, 2.0])));
    }

    #[test]
    fn empty_body_covers_everything() {
        assert!(RuleSpec::default().covers(&inst(&[1.0])));
    }

    #[test]
    fn head_mean_vs_perceptron() {
        let mut h = HeadSnapshot { mean: 7.0, weights: None };
        assert_eq!(h.predict(&inst(&[1.0, 2.0])), 7.0);
        h.weights = Some(vec![1.0, 2.0, 0.5]); // y = x0 + 2 x1 + 0.5
        assert!((h.predict(&inst(&[1.0, 2.0])) - 5.5).abs() < 1e-9);
    }
}
