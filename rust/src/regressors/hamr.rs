//! HAMR — Hybrid AMRules (paper §7.2, Fig. 11): `r` horizontally
//! replicated model aggregators (shuffle-grouped input) + a centralized
//! **default-rule learner** that keeps rule creation consistent, + the
//! same rule learners as VAMR.
//!
//! ```text
//!          shuffle               key: rule id
//!   source ──────► MA × r ════════════════════► learners × p
//!                   │  ▲ uncovered (shuffle→DRL)      ║
//!                   ▼  ╚═ new-rule (broadcast) ═ DRL ═╝ (new-rule, key)
//!                 prediction → evaluator    rule-updates (broadcast to MAs)
//! ```

use std::sync::Arc;

use crate::core::instance::Instance;
use crate::core::model::Regressor;
use crate::core::Schema;
use crate::topology::{
    Ctx, Event, Grouping, Output, Processor, ProcessorId, StreamId, Topology, TopologyBuilder,
};

use super::amrules::{AMRulesConfig, RuleEvent, RuleLearner};
use super::rule::RuleSpec;
use super::vamr::{RuleLearnerProcessor, VamrStreamIds};

/// Stream ids of a HAMR topology (fixed by declaration order).
#[derive(Clone, Copy, Debug)]
pub struct HamrStreamIds {
    pub rule_instance: StreamId,
    pub uncovered: StreamId,
    pub new_rule_to_mas: StreamId,
    pub new_rule_to_learner: StreamId,
    pub rule_updates: StreamId,
    pub prediction: StreamId,
}

/// HAMR model aggregator replica: simplified rules only; uncovered
/// instances go to the default-rule learner.
pub struct HamrAggregator {
    streams: HamrStreamIds,
    specs: Vec<(u32, RuleSpec)>,
    pub stats: super::vamr::VamrMaStats,
}

impl HamrAggregator {
    pub fn new(streams: HamrStreamIds) -> Self {
        HamrAggregator { streams, specs: Vec::new(), stats: Default::default() }
    }

    fn predict(&self, inst: &Instance) -> Output {
        for (_, spec) in &self.specs {
            if spec.covers(inst) {
                return Output::Numeric(spec.head.predict(inst));
            }
        }
        Output::None // default rule lives at the DRL; MA has no copy
    }
}

impl Processor for HamrAggregator {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        match event {
            Event::Instance { id, inst } => {
                self.stats.instances += 1;
                let output = match self.predict(&inst) {
                    Output::None => Output::Numeric(0.0), // cold-start guess
                    o => o,
                };
                ctx.emit_any(
                    self.streams.prediction,
                    Event::Prediction { id, truth: inst.label, output },
                );
                if inst.numeric_label().is_none() {
                    return;
                }
                for (rid, spec) in &self.specs {
                    if spec.covers(&inst) {
                        self.stats.forwarded += 1;
                        ctx.emit(
                            self.streams.rule_instance,
                            *rid as u64,
                            Event::RuleInstance { rule: *rid, inst },
                        );
                        return;
                    }
                }
                // uncovered → default-rule learner
                ctx.emit_any(self.streams.uncovered, Event::Instance { id, inst });
            }
            Event::NewRule { rule, spec } => {
                // broadcast from the DRL: all replicas stay in sync (the
                // broadcast shared one Arc; each replica materializes its
                // own mutable copy here, off the routing hot path)
                let spec = Arc::try_unwrap(spec).unwrap_or_else(|s| (*s).clone());
                self.specs.push((rule, spec));
                self.stats.rules_created += 1;
            }
            Event::RuleFeature { rule, feature, head } => {
                if let Some((_, spec)) = self.specs.iter_mut().find(|(id, _)| *id == rule) {
                    spec.features.push(feature);
                    spec.head = Arc::try_unwrap(head).unwrap_or_else(|h| (*h).clone());
                    self.stats.features_applied += 1;
                }
            }
            Event::RuleHead { rule, head } => {
                if let Some((_, spec)) = self.specs.iter_mut().find(|(id, _)| *id == rule) {
                    spec.head = Arc::try_unwrap(head).unwrap_or_else(|h| (*h).clone());
                }
            }
            Event::RuleRemoved { rule } => {
                self.specs.retain(|(id, _)| *id != rule);
                self.stats.rules_removed += 1;
            }
            _ => {}
        }
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.specs.iter().map(|(_, s)| 64 + 16 * s.features.len()).sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "hamr-model-aggregator"
    }
}

/// The centralized default-rule learner (§7.2 "centralized rule creation").
pub struct DefaultRuleLearner {
    schema: Schema,
    config: AMRulesConfig,
    streams: HamrStreamIds,
    default_rule: RuleLearner,
    next_id: u32,
    pub rules_created: u64,
}

impl DefaultRuleLearner {
    pub fn new(schema: Schema, config: AMRulesConfig, streams: HamrStreamIds) -> Self {
        let default_rule = RuleLearner::new(RuleSpec::default(), &schema, &config);
        DefaultRuleLearner { schema, config, streams, default_rule, next_id: 0, rules_created: 0 }
    }
}

impl Processor for DefaultRuleLearner {
    fn process(&mut self, event: Event, ctx: &mut Ctx) {
        if let Event::Instance { inst, .. } = event {
            let Some(y) = inst.numeric_label() else { return };
            match self.default_rule.update(&inst, y) {
                RuleEvent::Expanded(_) => {
                    let id = self.next_id;
                    self.next_id += 1;
                    self.rules_created += 1;
                    let spec = Arc::new(RuleSpec {
                        features: self.default_rule.spec.features.clone(),
                        head: self.default_rule.head(),
                    });
                    // broadcast to all MAs and hand to the owning learner —
                    // one shared allocation for all r + 1 deliveries
                    ctx.emit_any(
                        self.streams.new_rule_to_mas,
                        Event::NewRule { rule: id, spec: Arc::clone(&spec) },
                    );
                    ctx.emit(
                        self.streams.new_rule_to_learner,
                        id as u64,
                        Event::NewRule { rule: id, spec },
                    );
                    self.default_rule =
                        RuleLearner::new(RuleSpec::default(), &self.schema, &self.config);
                }
                RuleEvent::Evict => {
                    self.default_rule =
                        RuleLearner::new(RuleSpec::default(), &self.schema, &self.config);
                }
                _ => {}
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        use crate::common::MemSize;
        std::mem::size_of::<Self>() + self.default_rule.mem_bytes()
    }

    fn name(&self) -> &'static str {
        "hamr-default-rule-learner"
    }
}

/// Handles of an assembled HAMR topology.
#[derive(Clone, Copy, Debug)]
pub struct HamrHandles {
    pub entry: StreamId,
    pub streams: HamrStreamIds,
    pub mas: ProcessorId,
    pub drl: ProcessorId,
    pub learners: ProcessorId,
    pub evaluator: ProcessorId,
}

/// Build the HAMR topology (Fig. 11): r MAs + 1 DRL + p learners.
pub fn build_topology(
    schema: &Schema,
    config: &AMRulesConfig,
    r: usize,
    p: usize,
    evaluator: impl Fn(usize) -> Box<dyn crate::topology::Processor> + 'static,
) -> (Topology, HamrHandles) {
    let mut b = TopologyBuilder::new("hamr");
    let eval = b.add_processor("evaluator", 1, evaluator);
    // stream order: 0 entry, 1 rule-instance, 2 uncovered, 3 new-rule→MAs,
    // 4 new-rule→learner, 5 rule-updates, 6 prediction
    let ids = HamrStreamIds {
        rule_instance: StreamId(1),
        uncovered: StreamId(2),
        new_rule_to_mas: StreamId(3),
        new_rule_to_learner: StreamId(4),
        rule_updates: StreamId(5),
        prediction: StreamId(6),
    };
    let mas = b.add_processor("model-aggregator", r, move |_| {
        Box::new(HamrAggregator::new(ids))
    });
    let (s_d, c_d) = (schema.clone(), config.clone());
    let drl = b.add_processor("default-rule-learner", 1, move |_| {
        Box::new(DefaultRuleLearner::new(s_d.clone(), c_d.clone(), ids))
    });
    // learners reuse the VAMR processor; map the stream ids it needs
    let vids = VamrStreamIds {
        rule_instance: ids.rule_instance,
        new_rule: ids.new_rule_to_learner,
        rule_updates: ids.rule_updates,
        prediction: ids.prediction,
    };
    let (s_l, c_l) = (schema.clone(), config.clone());
    let learners = b.add_processor("learner", p, move |_| {
        Box::new(RuleLearnerProcessor::new(s_l.clone(), c_l.clone(), vids))
    });

    let entry = b.stream("instance", None, mas, Grouping::Shuffle);
    let ri = b.stream("rule-instance", Some(mas), learners, Grouping::Key);
    let un = b.stream("uncovered", Some(mas), drl, Grouping::Shuffle);
    let nm = b.stream("new-rule-mas", Some(drl), mas, Grouping::All);
    let nl = b.stream("new-rule-learner", Some(drl), learners, Grouping::Key);
    let ru = b.stream("rule-updates", Some(learners), mas, Grouping::All);
    let pr = b.stream("prediction", Some(mas), eval, Grouping::Shuffle);
    debug_assert_eq!(
        (ri, un, nm, nl, ru, pr),
        (
            ids.rule_instance,
            ids.uncovered,
            ids.new_rule_to_mas,
            ids.new_rule_to_learner,
            ids.rule_updates,
            ids.prediction
        )
    );

    (
        b.build(),
        HamrHandles { entry, streams: ids, mas, drl, learners, evaluator: eval },
    )
}

/// Sequential driver over the HAMR processors (r=1, p=1) for tests.
pub struct HamrLocal {
    ma: HamrAggregator,
    drl: DefaultRuleLearner,
    learner: RuleLearnerProcessor,
    ids: HamrStreamIds,
}

impl HamrLocal {
    pub fn new(schema: Schema, config: AMRulesConfig) -> Self {
        let ids = HamrStreamIds {
            rule_instance: StreamId(1),
            uncovered: StreamId(2),
            new_rule_to_mas: StreamId(3),
            new_rule_to_learner: StreamId(4),
            rule_updates: StreamId(5),
            prediction: StreamId(6),
        };
        let vids = VamrStreamIds {
            rule_instance: ids.rule_instance,
            new_rule: ids.new_rule_to_learner,
            rule_updates: ids.rule_updates,
            prediction: ids.prediction,
        };
        HamrLocal {
            ma: HamrAggregator::new(ids),
            drl: DefaultRuleLearner::new(schema.clone(), config.clone(), ids),
            learner: RuleLearnerProcessor::new(schema, config, vids),
            ids,
        }
    }

    fn pump(&mut self, out: Vec<(StreamId, u64, Event)>) {
        let mut queue = out;
        while !queue.is_empty() {
            let mut next = Vec::new();
            for (stream, _k, ev) in queue.drain(..) {
                let mut ctx = Ctx::new(0, 1);
                match stream.0 {
                    s if s == self.ids.rule_instance.0 || s == self.ids.new_rule_to_learner.0 => {
                        self.learner.process(ev, &mut ctx)
                    }
                    s if s == self.ids.uncovered.0 => self.drl.process(ev, &mut ctx),
                    s if s == self.ids.new_rule_to_mas.0 || s == self.ids.rule_updates.0 => {
                        self.ma.process(ev, &mut ctx)
                    }
                    _ => {}
                }
                next.extend(ctx.take());
            }
            queue = next;
        }
    }
}

impl Regressor for HamrLocal {
    fn predict(&self, inst: &Instance) -> f64 {
        match self.ma.predict(inst) {
            Output::Numeric(y) => y,
            _ => self.drl.default_rule.predict(inst),
        }
    }

    fn train(&mut self, inst: &Instance) {
        let mut ctx = Ctx::new(0, 1);
        self.ma.process(Event::Instance { id: 0, inst: inst.clone() }, &mut ctx);
        self.pump(ctx.take());
    }

    fn model_bytes(&self) -> usize {
        self.ma.mem_bytes() + self.drl.mem_bytes() + self.learner.mem_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::core::instance::Label;

    fn schema() -> Schema {
        Schema::regression("pw", Schema::all_numeric(2), -12.0, 12.0)
    }

    #[test]
    fn hamr_local_learns_piecewise() {
        let mut rng = Rng::new(1);
        let mut m = HamrLocal::new(schema(), AMRulesConfig::default());
        for _ in 0..25_000 {
            let x0 = rng.f32();
            let y = if x0 <= 0.5 { 10.0 } else { -10.0 } + 0.2 * rng.gaussian();
            m.train(&Instance::dense(vec![x0, rng.f32()], Label::Numeric(y)));
        }
        assert!(m.drl.rules_created >= 1, "DRL created no rules");
        assert!(m.ma.stats.rules_created >= 1, "MA never heard about new rules");
        let lo = m.predict(&Instance::dense(vec![0.2, 0.5], Label::None));
        let hi = m.predict(&Instance::dense(vec![0.8, 0.5], Label::None));
        assert!(lo > hi + 5.0, "lo={lo} hi={hi}");
    }

    #[test]
    fn uncovered_instances_reach_drl() {
        let mut m = HamrLocal::new(schema(), AMRulesConfig::default());
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            m.train(&Instance::dense(vec![rng.f32(), rng.f32()], Label::Numeric(1.0)));
        }
        // everything is uncovered initially, so the DRL must have stats
        assert!(m.drl.default_rule.predict(&Instance::dense(vec![0.5, 0.5], Label::None)) > 0.5);
    }
}
