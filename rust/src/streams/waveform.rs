//! Waveform generator (paper §7.3): 21 signal attributes formed as convex
//! combinations of two of three triangular base waveforms, plus 19 noise
//! attributes (40 total). The label is the waveform index (0, 1, 2), used
//! by the paper as a numeric target to stress AMRules with many numeric
//! attributes.

use crate::common::Rng;
use crate::core::instance::{Instance, Label};
use crate::core::Schema;

use super::StreamSource;

/// The three classic triangular base functions over 21 points.
fn base(h: usize, i: usize) -> f64 {
    let i = i as f64;
    match h {
        0 => (6.0 - (i - 7.0).abs()).max(0.0),
        1 => (6.0 - (i - 15.0).abs()).max(0.0),
        _ => (6.0 - (i - 11.0).abs()).max(0.0),
    }
}

/// Waveform stream (regression form by default, like the paper's use).
pub struct WaveformGenerator {
    schema: Schema,
    rng: Rng,
    /// emit class labels instead of numeric (for classification tests)
    classification: bool,
}

impl WaveformGenerator {
    pub fn new(seed: u64) -> Self {
        WaveformGenerator {
            schema: Schema::regression("waveform", Schema::all_numeric(40), 0.0, 2.0),
            rng: Rng::new(seed),
            classification: false,
        }
    }

    pub fn classification(seed: u64) -> Self {
        WaveformGenerator {
            schema: Schema::classification("waveform-cls", Schema::all_numeric(40), 3),
            rng: Rng::new(seed),
            classification: true,
        }
    }
}

impl StreamSource for WaveformGenerator {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        let wave = self.rng.below(3);
        let (a, b) = match wave {
            0 => (0, 1),
            1 => (0, 2),
            _ => (1, 2),
        };
        let mix = self.rng.f64();
        let mut values = Vec::with_capacity(40);
        for i in 0..21 {
            let v = mix * base(a, i) + (1.0 - mix) * base(b, i) + self.rng.gaussian();
            values.push(v as f32);
        }
        for _ in 21..40 {
            values.push(self.rng.gaussian() as f32);
        }
        let label = if self.classification {
            Label::Class(wave as u32)
        } else {
            Label::Numeric(wave as f64)
        };
        Some(Instance::dense(values, label))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forty_attributes_three_labels() {
        let mut g = WaveformGenerator::new(1);
        let mut seen = [false; 3];
        for _ in 0..200 {
            let i = g.next_instance().unwrap();
            assert_eq!(i.n_attributes(), 40);
            seen[i.numeric_label().unwrap() as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn signal_attrs_carry_information() {
        // attribute 7 peaks for waveform pairs containing base 0
        let mut g = WaveformGenerator::new(2);
        let (mut with0, mut without0, mut n0, mut n1) = (0.0, 0.0, 0, 0);
        for _ in 0..3000 {
            let i = g.next_instance().unwrap();
            let y = i.numeric_label().unwrap() as usize;
            if y == 0 || y == 1 {
                with0 += i.value(7) as f64;
                n0 += 1;
            } else {
                without0 += i.value(7) as f64;
                n1 += 1;
            }
        }
        assert!(with0 / n0 as f64 > without0 / n1 as f64 + 0.5);
    }
}
