//! Schema-matched synthetic twins of the paper's real datasets
//! (substitution documented in DESIGN.md §3: downloads unavailable here;
//! each twin matches instance count, dimensionality, class/label structure
//! and carries a learnable concept + drift so the *relative* results
//! between algorithm variants are preserved).
//!
//! Classification (VHT experiments, Tables 3-4):
//! * `elec`     — 45 312 × 8 numeric, 2 classes (price UP/DOWN with
//!                daily/weekly periodicity + drift).
//! * `phy`      — 50 000 × 78 numeric, 2 classes (two overlapping
//!                Gaussian mixtures over correlated features).
//! * `covtype`  — 581 012 × 54 (10 numeric + 44 binary), 7 classes.
//!
//! Regression (AMRules experiments, Tables 5-7, Figs 12-16):
//! * `electricity` — 2 049 280 × 12 numeric, household power target.
//! * `airlines`    — 5 810 462 × 10 numeric, arrival-delay target.

use crate::common::Rng;
use crate::core::instance::{Instance, Label};
use crate::core::{AttributeKind, Schema};

use super::StreamSource;

// ------------------------------------------------------------------ elec

/// Electricity price direction twin (45312 × 8, 2 classes).
pub struct ElecStream {
    schema: Schema,
    rng: Rng,
    t: u64,
    limit: u64,
    demand_prev: f64,
}

impl ElecStream {
    pub fn new(seed: u64) -> Self {
        ElecStream {
            schema: Schema::classification("elec", Schema::all_numeric(8), 2),
            rng: Rng::new(seed),
            t: 0,
            limit: 45_312,
            demand_prev: 0.5,
        }
    }
}

impl StreamSource for ElecStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        if self.t >= self.limit {
            return None;
        }
        let t = self.t as f64;
        self.t += 1;
        // half-hourly measurements: daily (48) and weekly (336) cycles
        let day = (t * std::f64::consts::TAU / 48.0).sin();
        let week = (t * std::f64::consts::TAU / 336.0).sin();
        // slow concept drift in the demand baseline
        let drift = 0.3 * (t / 15_000.0).sin();
        let demand = 0.5 + 0.25 * day + 0.1 * week + drift * 0.2 + 0.05 * self.rng.gaussian();
        let transfer = 0.5 + 0.2 * week + 0.1 * self.rng.gaussian();
        let vic_demand = demand + 0.1 * self.rng.gaussian();
        // price rises when demand outpaces the recent baseline
        let up = demand + 0.08 * self.rng.gaussian() > self.demand_prev;
        self.demand_prev = 0.9 * self.demand_prev + 0.1 * demand;
        let values = vec![
            (t % 336.0 / 336.0) as f32,           // day-of-week phase
            (t % 48.0 / 48.0) as f32,             // period-of-day phase
            demand as f32,
            (demand * 0.8 + 0.1 * self.rng.gaussian()) as f32, // nsw price proxy
            vic_demand as f32,
            (vic_demand * 0.7 + 0.1 * self.rng.gaussian()) as f32,
            transfer as f32,
            self.rng.f32(),
        ];
        Some(Instance::dense(values, Label::Class(up as u32)))
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.limit)
    }
}

// ------------------------------------------------------------------- phy

/// Particle-physics twin (50 000 × 78, 2 classes).
pub struct PhyStream {
    schema: Schema,
    rng: Rng,
    t: u64,
    limit: u64,
    /// per-class feature loadings (fixed by seed)
    loadings: Vec<Vec<f64>>,
}

impl PhyStream {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let loadings = (0..2)
            .map(|_| (0..78).map(|_| rng.gaussian() * 0.35).collect())
            .collect();
        PhyStream {
            schema: Schema::classification("phy", Schema::all_numeric(78), 2),
            rng,
            t: 0,
            limit: 50_000,
            loadings,
        }
    }
}

impl StreamSource for PhyStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        if self.t >= self.limit {
            return None;
        }
        self.t += 1;
        let class = self.rng.below(2);
        // two latent factors + per-class mean shift: overlapping classes
        let f1 = self.rng.gaussian();
        let f2 = self.rng.gaussian();
        let values: Vec<f32> = (0..78)
            .map(|i| {
                let shift = self.loadings[class][i];
                let corr = if i % 2 == 0 { f1 } else { f2 };
                (shift + 0.5 * corr + 0.8 * self.rng.gaussian()) as f32
            })
            .collect();
        Some(Instance::dense(values, Label::Class(class as u32)))
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.limit)
    }
}

// ---------------------------------------------------------------- covtype

/// Forest-covertype twin (581 012 × 54, 7 classes; 10 numeric + 44 binary).
pub struct CovtypeStream {
    schema: Schema,
    rng: Rng,
    t: u64,
    limit: u64,
    /// per-class (elevation mean, slope mean, soil-group) prototypes
    protos: Vec<(f64, f64, usize)>,
}

impl CovtypeStream {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let protos = (0..7)
            .map(|c| (0.2 + 0.1 * c as f64 + 0.05 * rng.gaussian(), rng.f64(), rng.below(40)))
            .collect();
        let mut attrs = Schema::all_numeric(10);
        attrs.extend(vec![AttributeKind::Categorical { n_values: 2 }; 44]);
        CovtypeStream {
            schema: Schema::classification("covtype", attrs, 7),
            rng,
            t: 0,
            limit: 581_012,
            protos,
        }
    }
}

impl StreamSource for CovtypeStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        if self.t >= self.limit {
            return None;
        }
        self.t += 1;
        // class prior skewed like the real covtype (classes 0/1 dominate)
        let class = self.rng.choice_weighted(&[36.0, 48.0, 6.0, 0.5, 1.6, 3.0, 3.5]);
        let (elev, slope, soil) = self.protos[class];
        let mut values = Vec::with_capacity(54);
        values.push((elev + 0.04 * self.rng.gaussian()) as f32); // elevation
        values.push(self.rng.f32()); // aspect
        values.push((slope + 0.1 * self.rng.gaussian()) as f32); // slope
        for _ in 3..10 {
            values.push((0.3 * self.rng.gaussian() + elev * 0.5) as f32);
        }
        // 4 wilderness-area one-hot bits
        let wild = class % 4;
        for w in 0..4 {
            values.push((w == wild) as u32 as f32);
        }
        // 40 soil-type one-hot bits (noisy)
        let soil_obs = if self.rng.bool(0.85) { soil } else { self.rng.below(40) };
        for s in 0..40 {
            values.push((s == soil_obs) as u32 as f32);
        }
        Some(Instance::dense(values, Label::Class(class as u32)))
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.limit)
    }
}

// ------------------------------------------------------- electricity (reg)

/// Household power-consumption twin (2 049 280 × 12, regression).
pub struct ElectricityRegStream {
    schema: Schema,
    rng: Rng,
    t: u64,
    limit: u64,
}

impl ElectricityRegStream {
    pub fn new(seed: u64) -> Self {
        ElectricityRegStream {
            schema: Schema::regression("electricity", Schema::all_numeric(12), 0.0, 8.0),
            rng: Rng::new(seed),
            t: 0,
            limit: 2_049_280,
        }
    }

    /// Shorter stream for quick experiments.
    pub fn with_limit(seed: u64, limit: u64) -> Self {
        let mut s = Self::new(seed);
        s.limit = limit;
        s
    }
}

impl StreamSource for ElectricityRegStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        if self.t >= self.limit {
            return None;
        }
        let t = self.t as f64;
        self.t += 1;
        // minute-resolution: daily cycle (1440) + appliance spikes
        let day_phase = (t % 1440.0) / 1440.0;
        let season = (t * std::f64::consts::TAU / (1440.0 * 365.0)).sin();
        let base = 0.8 + 0.6 * (-((day_phase - 0.8) * 6.0).powi(2)).exp()
            + 0.4 * (-((day_phase - 0.33) * 8.0).powi(2)).exp()
            + 0.2 * season;
        let spike = if self.rng.bool(0.03) { self.rng.f64() * 4.0 } else { 0.0 };
        let power = (base + spike + 0.1 * self.rng.gaussian()).max(0.0);
        let volt = 240.0 + 3.0 * self.rng.gaussian();
        let values = vec![
            day_phase as f32,
            ((t / 1440.0) % 7.0 / 7.0) as f32,
            season as f32,
            (base) as f32,
            (volt / 250.0) as f32,
            (power * 4.0 / volt * 50.0) as f32, // current proxy
            (spike > 0.0) as u32 as f32,
            ((t % 60.0) / 60.0) as f32,
            self.rng.f32(),
            (0.3 * season + 0.1 * self.rng.gaussian()) as f32,
            (base * 0.5) as f32,
            self.rng.f32(),
        ];
        Some(Instance::dense(values, Label::Numeric(power)))
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.limit)
    }
}

// ----------------------------------------------------------- airlines (reg)

/// Flight arrival-delay twin (5 810 462 × 10, regression).
pub struct AirlinesStream {
    schema: Schema,
    rng: Rng,
    t: u64,
    limit: u64,
    /// carrier base delays (the "complex model" driver: many distinct
    /// regimes, giving AMRules many rules to create — Table 5)
    carriers: Vec<f64>,
    airports: Vec<f64>,
}

impl AirlinesStream {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // wide regime spread: many distinct carrier/airport delay regimes
        // is what makes airlines the most rule-hungry dataset (Table 5)
        let carriers = (0..20).map(|_| rng.f64() * 60.0).collect();
        let airports = (0..300).map(|_| rng.f64() * 80.0).collect();
        AirlinesStream {
            schema: Schema::regression("airlines", Schema::all_numeric(10), -30.0, 240.0),
            rng,
            t: 0,
            limit: 5_810_462,
        carriers,
            airports,
        }
    }

    pub fn with_limit(seed: u64, limit: u64) -> Self {
        let mut s = Self::new(seed);
        s.limit = limit;
        s
    }
}

impl StreamSource for AirlinesStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        if self.t >= self.limit {
            return None;
        }
        self.t += 1;
        let carrier = self.rng.below(20);
        let origin = self.rng.below(300);
        let dest = self.rng.below(300);
        let dep_hour = self.rng.below(24) as f64;
        let day = self.rng.below(7) as f64;
        let distance = 100.0 + self.rng.f64() * 2500.0;
        // congestion is a step function of departure hour (piecewise
        // regimes = rule-friendly structure); storms add heavy-tail delay
        let congestion = match dep_hour as u32 {
            0..=5 => 0.0,
            6..=9 => 25.0,
            10..=15 => 12.0,
            16..=20 => 40.0,
            _ => 8.0,
        };
        let storm = if self.rng.bool(0.05) { self.rng.f64() * 120.0 } else { 0.0 };
        let delay = self.carriers[carrier] * 0.6
            + self.airports[origin] * 0.5
            + self.airports[dest] * 0.25
            + congestion
            + storm
            + 5.0 * self.rng.gaussian()
            - 15.0;
        let values = vec![
            carrier as f32,
            origin as f32,
            dest as f32,
            dep_hour as f32,
            day as f32,
            (distance / 2600.0) as f32,
            (congestion / 35.0) as f32,
            (storm > 0.0) as u32 as f32,
            ((distance / 450.0) + 0.2 * self.rng.gaussian() as f64) as f32, // airtime hrs
            self.rng.f32(),
        ];
        Some(Instance::dense(values, Label::Numeric(delay.clamp(-30.0, 240.0))))
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.limit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elec_matches_paper_shape() {
        let mut s = ElecStream::new(1);
        let i = s.next_instance().unwrap();
        assert_eq!(i.n_attributes(), 8);
        assert_eq!(s.len_hint(), Some(45_312));
        // both classes occur
        let mut c = [0u32; 2];
        for _ in 0..2000 {
            c[s.next_instance().unwrap().class().unwrap() as usize] += 1;
        }
        assert!(c[0] > 200 && c[1] > 200, "{c:?}");
    }

    #[test]
    fn phy_shape_and_overlap() {
        let mut s = PhyStream::new(2);
        let i = s.next_instance().unwrap();
        assert_eq!(i.n_attributes(), 78);
        assert_eq!(s.len_hint(), Some(50_000));
    }

    #[test]
    fn covtype_shape_and_skew() {
        let mut s = CovtypeStream::new(3);
        let i = s.next_instance().unwrap();
        assert_eq!(i.n_attributes(), 54);
        let mut counts = [0u32; 7];
        for _ in 0..5000 {
            counts[s.next_instance().unwrap().class().unwrap() as usize] += 1;
        }
        // classes 0 and 1 dominate, like the real covtype
        assert!(counts[0] + counts[1] > 3500, "{counts:?}");
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 6);
    }

    #[test]
    fn electricity_reg_daily_structure() {
        let mut s = ElectricityRegStream::with_limit(4, 10_000);
        let mut ys = Vec::new();
        for _ in 0..10_000 {
            ys.push(s.next_instance().unwrap().numeric_label().unwrap());
        }
        assert!(s.next_instance().is_none());
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        assert!(mean > 0.5 && mean < 2.5, "mean={mean}");
        assert!(ys.iter().all(|&y| y >= 0.0));
    }

    #[test]
    fn airlines_heavy_tail() {
        let mut s = AirlinesStream::with_limit(5, 20_000);
        let mut ys = Vec::new();
        for _ in 0..20_000 {
            ys.push(s.next_instance().unwrap().numeric_label().unwrap());
        }
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let big = ys.iter().filter(|&&y| y > mean + 60.0).count();
        assert!(big > 100, "storm tail missing: {big}");
    }
}
