//! Sparse synthetic generator (paper §6.3): the random **tweet** stream.
//!
//! Attributes are a bag-of-words of dimensionality D ∈ {100, 1k, 10k};
//! each tweet has Gaussian length (mean 15 words) drawn from a Zipf(z=1.5)
//! distribution over the vocabulary; the binary class (uniform) conditions
//! the Zipf distribution used — class 1 reverses pairs of word ranks, so
//! word identity carries the signal.

use crate::common::zipf::Zipf;
use crate::common::Rng;
use crate::core::instance::{Instance, Label};
use crate::core::Schema;

use super::StreamSource;

/// Sparse tweet stream.
pub struct RandomTweetGenerator {
    schema: Schema,
    zipf: Zipf,
    rng: Rng,
    vocab: u32,
    mean_words: f64,
    sd_words: f64,
    /// class-1 permutation: swap adjacent rank pairs (rank r ↔ r^1)
    _marker: (),
}

impl RandomTweetGenerator {
    pub fn new(vocab: u32, seed: u64) -> Self {
        let schema = Schema::classification(
            &format!("random-tweet-{vocab}"),
            Schema::all_numeric(vocab as usize),
            2,
        );
        RandomTweetGenerator {
            schema,
            zipf: Zipf::new(vocab as usize, 1.5),
            rng: Rng::new(seed),
            vocab,
            mean_words: 15.0,
            sd_words: 5.0,
            _marker: (),
        }
    }

    /// Class-conditional word rank: class 1 shifts the rank→word mapping
    /// by 3, so each class has its own set of high-frequency words (the
    /// paper: the class "conditions the Zipf distribution used to
    /// generate the words").
    #[inline]
    fn word_for(&self, rank: usize, class: u32) -> u32 {
        ((rank as u32) + 3 * class) % self.vocab
    }
}

impl StreamSource for RandomTweetGenerator {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        let class = self.rng.below(2) as u32;
        let len = (self.mean_words + self.sd_words * self.rng.gaussian())
            .round()
            .clamp(1.0, 100.0) as usize;
        let mut words: Vec<u32> = (0..len)
            .map(|_| {
                let r = self.zipf.sample(&mut self.rng);
                self.word_for(r, class)
            })
            .collect();
        words.sort_unstable();
        words.dedup();
        let values = vec![1.0f32; words.len()];
        Some(Instance::sparse(words, values, self.vocab, Label::Class(class)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweets_are_sparse_with_mean_len() {
        let mut g = RandomTweetGenerator::new(1000, 1);
        let mut total = 0usize;
        for _ in 0..500 {
            let i = g.next_instance().unwrap();
            assert!(i.n_stored() <= 100);
            assert_eq!(i.n_attributes(), 1000);
            total += i.n_stored();
        }
        let mean = total as f64 / 500.0;
        // dedup trims below 15 a bit
        assert!(mean > 6.0 && mean < 16.0, "mean={mean}");
    }

    #[test]
    fn classes_roughly_balanced() {
        let mut g = RandomTweetGenerator::new(100, 2);
        let ones = (0..1000)
            .filter(|_| g.next_instance().unwrap().class() == Some(1))
            .count();
        assert!(ones > 400 && ones < 600, "ones={ones}");
    }

    #[test]
    fn class_signal_exists() {
        // word 0 should be much more common under class 0 than class 1
        let mut g = RandomTweetGenerator::new(100, 3);
        let (mut w0_c0, mut w0_c1) = (0, 0);
        for _ in 0..4000 {
            let i = g.next_instance().unwrap();
            let has0 = i.value(0) != 0.0;
            match (i.class().unwrap(), has0) {
                (0, true) => w0_c0 += 1,
                (1, true) => w0_c1 += 1,
                _ => {}
            }
        }
        assert!(
            w0_c0 as f64 > w0_c1 as f64 * 1.2,
            "w0 under c0={w0_c0} vs c1={w0_c1}"
        );
    }

    #[test]
    fn deterministic() {
        let mut a = RandomTweetGenerator::new(100, 9);
        let mut b = RandomTweetGenerator::new(100, 9);
        for _ in 0..50 {
            assert_eq!(a.next_instance().unwrap().values(), b.next_instance().unwrap().values());
        }
    }
}
