//! Minimal ARFF reader (numeric + nominal attributes) so the *real*
//! datasets can be used when available: drop e.g. `covtypeNorm.arff` into
//! `data/` and the experiment harness picks it up instead of the synthetic
//! twin (see `experiments::datasets_or_twins`).

use std::io::{BufRead, BufReader, Read};

use crate::core::instance::{Instance, Label};
use crate::core::{AttributeKind, Schema};

use super::StreamSource;

/// Fully parsed ARFF dataset (materialized; streams replay it).
pub struct ArffData {
    pub schema: Schema,
    pub instances: Vec<Instance>,
}

/// Parse an ARFF document. The last attribute is the class/target.
pub fn parse_arff<R: Read>(reader: R, name: &str) -> crate::Result<ArffData> {
    let mut attrs: Vec<AttributeKind> = Vec::new();
    let mut nominal_values: Vec<Option<Vec<String>>> = Vec::new();
    let mut in_data = false;
    let mut instances = Vec::new();
    let mut schema: Option<Schema> = None;

    for line in BufReader::new(reader).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let lower = line.to_ascii_lowercase();
        if !in_data {
            if lower.starts_with("@attribute") {
                let rest = line["@attribute".len()..].trim();
                // name may be quoted; type is the remainder
                let (_, ty) = split_attr(rest)?;
                if ty.starts_with('{') {
                    let vals: Vec<String> = ty
                        .trim_matches(|c| c == '{' || c == '}')
                        .split(',')
                        .map(|v| v.trim().trim_matches('\'').to_string())
                        .collect();
                    attrs.push(AttributeKind::Categorical { n_values: vals.len() as u32 });
                    nominal_values.push(Some(vals));
                } else {
                    attrs.push(AttributeKind::Numeric);
                    nominal_values.push(None);
                }
            } else if lower.starts_with("@data") {
                in_data = true;
                // last attribute is the class
                let class_kind = attrs.pop().ok_or_else(|| crate::anyhow!("no attributes"))?;
                let class_vals = nominal_values.pop().unwrap();
                schema = Some(match (class_kind, &class_vals) {
                    (AttributeKind::Categorical { n_values }, _) => {
                        Schema::classification(name, attrs.clone(), n_values)
                    }
                    (AttributeKind::Numeric, _) => {
                        Schema::regression(name, attrs.clone(), f64::MIN, f64::MAX)
                    }
                });
                nominal_values.push(class_vals); // keep for label lookup
            }
        } else {
            let schema = schema.as_ref().unwrap();
            let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
            if fields.len() != schema.n_attributes() + 1 {
                continue; // skip malformed rows
            }
            let mut values = Vec::with_capacity(fields.len() - 1);
            for (i, f) in fields[..fields.len() - 1].iter().enumerate() {
                let v = match &nominal_values[i] {
                    Some(vals) => vals
                        .iter()
                        .position(|x| x == f.trim_matches('\''))
                        .unwrap_or(0) as f32,
                    None => f.parse::<f32>().unwrap_or(0.0),
                };
                values.push(v);
            }
            let class_field = fields[fields.len() - 1];
            let label = match &nominal_values[nominal_values.len() - 1] {
                Some(vals) => Label::Class(
                    vals.iter()
                        .position(|x| x == class_field.trim_matches('\''))
                        .unwrap_or(0) as u32,
                ),
                None => Label::Numeric(class_field.parse().unwrap_or(0.0)),
            };
            instances.push(Instance::dense(values, label));
        }
    }
    let schema = schema.ok_or_else(|| crate::anyhow!("no @data section"))?;
    Ok(ArffData { schema, instances })
}

fn split_attr(rest: &str) -> crate::Result<(String, String)> {
    let rest = rest.trim();
    if let Some(stripped) = rest.strip_prefix('\'') {
        let end = stripped
            .find('\'')
            .ok_or_else(|| crate::anyhow!("unterminated quote"))?;
        Ok((stripped[..end].to_string(), stripped[end + 1..].trim().to_string()))
    } else {
        let mut it = rest.splitn(2, char::is_whitespace);
        let name = it.next().unwrap_or_default().to_string();
        let ty = it.next().unwrap_or_default().trim().to_string();
        Ok((name, ty))
    }
}

/// Stream replaying parsed ARFF instances.
pub struct ArffStream {
    data: ArffData,
    pos: usize,
}

impl ArffStream {
    pub fn from_file(path: &std::path::Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)?;
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("arff");
        Ok(ArffStream { data: parse_arff(f, name)?, pos: 0 })
    }

    pub fn from_data(data: ArffData) -> Self {
        ArffStream { data, pos: 0 }
    }
}

impl StreamSource for ArffStream {
    fn schema(&self) -> &Schema {
        &self.data.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        let i = self.data.instances.get(self.pos)?.clone();
        self.pos += 1;
        Some(i)
    }

    fn len_hint(&self) -> Option<u64> {
        Some(self.data.instances.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
% comment
@relation test
@attribute a1 numeric
@attribute a2 {red, green, blue}
@attribute class {yes, no}
@data
1.5, green, yes
2.0, red, no
0.1, blue, yes
";

    #[test]
    fn parses_schema_and_rows() {
        let d = parse_arff(SAMPLE.as_bytes(), "test").unwrap();
        assert_eq!(d.schema.n_attributes(), 2);
        assert_eq!(d.schema.n_classes(), 2);
        assert_eq!(d.instances.len(), 3);
        assert_eq!(d.instances[0].value(0), 1.5);
        assert_eq!(d.instances[0].value(1), 1.0); // green
        assert_eq!(d.instances[0].class(), Some(0)); // yes
        assert_eq!(d.instances[1].class(), Some(1)); // no
    }

    #[test]
    fn numeric_class_is_regression() {
        let s = "@relation r\n@attribute x numeric\n@attribute y numeric\n@data\n1,2.5\n";
        let d = parse_arff(s.as_bytes(), "r").unwrap();
        assert!(d.schema.is_regression());
        assert_eq!(d.instances[0].numeric_label(), Some(2.5));
    }

    #[test]
    fn stream_replays() {
        let d = parse_arff(SAMPLE.as_bytes(), "test").unwrap();
        let mut s = ArffStream::from_data(d);
        assert_eq!(s.len_hint(), Some(3));
        let mut n = 0;
        while s.next_instance().is_some() {
            n += 1;
        }
        assert_eq!(n, 3);
    }
}
