//! Stream sources: the synthetic generators of the paper's evaluation and
//! schema-matched twins of its real datasets (substitution documented in
//! DESIGN.md §3), plus an ARFF reader for using the real files when
//! available (drop them into `data/`).

pub mod random_tree;
pub mod random_tweet;
pub mod waveform;
pub mod datasets;
pub mod drifting;
pub mod arff;

use crate::core::{Instance, Schema};

/// A (possibly infinite) stream of instances with a fixed schema.
pub trait StreamSource: Send {
    fn schema(&self) -> &Schema;
    fn next_instance(&mut self) -> Option<Instance>;

    /// Hint for harnesses: total instances available (None = unbounded).
    fn len_hint(&self) -> Option<u64> {
        None
    }
}

/// Boxed sources are sources too — lets `TransformedStream` (and any
/// generic consumer) wrap the `Box<dyn StreamSource>` handed out by the
/// CLI stream registry.
impl StreamSource for Box<dyn StreamSource> {
    fn schema(&self) -> &Schema {
        (**self).schema()
    }

    fn next_instance(&mut self) -> Option<Instance> {
        (**self).next_instance()
    }

    fn len_hint(&self) -> Option<u64> {
        (**self).len_hint()
    }
}

/// Extension: route any source through a preprocessing pipeline
/// ([`crate::preprocess`]), e.g. `ArffStream::from_file(p)?.pipe(pl)`.
pub trait StreamSourceExt: StreamSource + Sized {
    fn pipe(
        self,
        pipeline: crate::preprocess::Pipeline,
    ) -> crate::preprocess::TransformedStream<Self> {
        crate::preprocess::TransformedStream::new(self, pipeline)
    }
}

impl<S: StreamSource + Sized> StreamSourceExt for S {}

/// Adapter: iterate a `StreamSource` (bounded by `max`).
pub struct Take<'a> {
    pub src: &'a mut dyn StreamSource,
    pub remaining: u64,
}

impl<'a> Iterator for Take<'a> {
    type Item = Instance;

    fn next(&mut self) -> Option<Instance> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.src.next_instance()
    }
}
