//! Dense synthetic generator (paper §6.3): instances labeled by a random
//! decision tree over a mix of categorical and numerical attributes — the
//! "dense" streams of Figs 3, 4, 6, 8 (configurations like 10-10 meaning
//! 10 categorical + 10 numerical).
//!
//! The concept tree is built once from the seed: internal nodes test a
//! random attribute (random threshold for numeric, value-branch for
//! categorical); leaves carry one of the (balanced) classes. Attribute
//! values are drawn uniformly, the label read off the tree, plus optional
//! class noise.

use crate::common::Rng;
use crate::core::instance::{Instance, Label};
use crate::core::{AttributeKind, Schema};

use super::StreamSource;

enum CNode {
    LeafC(u32),
    SplitCat { attr: usize, children: Vec<usize> },
    SplitNum { attr: usize, threshold: f32, low: usize, high: usize },
}

/// Random-decision-tree labeled dense stream.
pub struct RandomTreeGenerator {
    schema: Schema,
    nodes: Vec<CNode>,
    rng: Rng,
    noise: f64,
    n_categorical: usize,
    cat_values: u32,
}

impl RandomTreeGenerator {
    /// `n_categorical` categorical (5 values each) + `n_numeric` numeric
    /// attributes, `n_classes` balanced classes. Deterministic in `seed`.
    pub fn new(n_categorical: usize, n_numeric: usize, n_classes: u32, seed: u64) -> Self {
        Self::with_depth(n_categorical, n_numeric, n_classes, seed, 5, 0.0)
    }

    pub fn with_depth(
        n_categorical: usize,
        n_numeric: usize,
        n_classes: u32,
        seed: u64,
        max_depth: u32,
        noise: f64,
    ) -> Self {
        let cat_values = 5;
        let mut attrs = Schema::all_categorical(n_categorical, cat_values);
        attrs.extend(Schema::all_numeric(n_numeric));
        let schema = Schema::classification(
            &format!("random-tree-{n_categorical}-{n_numeric}"),
            attrs,
            n_classes,
        );
        let mut rng = Rng::new(seed);
        let mut gen = RandomTreeGenerator {
            schema,
            nodes: Vec::new(),
            rng: rng.fork(1),
            noise,
            n_categorical,
            cat_values,
        };
        let mut next_class = 0u32;
        gen.build(&mut rng, 0, max_depth, &mut next_class);
        gen
    }

    fn build(&mut self, rng: &mut Rng, depth: u32, max_depth: u32, next_class: &mut u32) -> usize {
        let n_attrs = self.schema.n_attributes();
        if depth >= max_depth || rng.bool(0.15 * depth as f64) {
            // balanced classes: leaves cycle through the class labels
            let c = *next_class % self.schema.n_classes();
            *next_class += 1;
            self.nodes.push(CNode::LeafC(c));
            return self.nodes.len() - 1;
        }
        let attr = rng.below(n_attrs);
        if attr < self.n_categorical {
            let children: Vec<usize> = (0..self.cat_values)
                .map(|_| self.build(rng, depth + 1, max_depth, next_class))
                .collect();
            self.nodes.push(CNode::SplitCat { attr, children });
        } else {
            let threshold = rng.f32();
            let low = self.build(rng, depth + 1, max_depth, next_class);
            let high = self.build(rng, depth + 1, max_depth, next_class);
            self.nodes.push(CNode::SplitNum { attr, threshold, low, high });
        }
        self.nodes.len() - 1
    }

    fn classify(&self, values: &[f32]) -> u32 {
        let mut node = self.nodes.len() - 1; // root pushed last
        loop {
            match &self.nodes[node] {
                CNode::LeafC(c) => return *c,
                CNode::SplitCat { attr, children } => {
                    node = children[values[*attr] as usize % children.len()];
                }
                CNode::SplitNum { attr, threshold, low, high } => {
                    node = if values[*attr] <= *threshold { *low } else { *high };
                }
            }
        }
    }
}

impl StreamSource for RandomTreeGenerator {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_instance(&mut self) -> Option<Instance> {
        let n = self.schema.n_attributes();
        let mut values = Vec::with_capacity(n);
        for a in 0..n {
            if a < self.n_categorical {
                values.push(self.rng.below(self.cat_values as usize) as f32);
            } else {
                values.push(self.rng.f32());
            }
        }
        let mut class = self.classify(&values);
        if self.noise > 0.0 && self.rng.bool(self.noise) {
            class = self.rng.below(self.schema.n_classes() as usize) as u32;
        }
        Some(Instance::dense(values, Label::Class(class)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = RandomTreeGenerator::new(5, 5, 2, 7);
        let mut b = RandomTreeGenerator::new(5, 5, 2, 7);
        for _ in 0..100 {
            let (x, y) = (a.next_instance().unwrap(), b.next_instance().unwrap());
            assert_eq!(x.values(), y.values());
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = RandomTreeGenerator::new(5, 5, 2, 1);
        let mut b = RandomTreeGenerator::new(5, 5, 2, 2);
        let same = (0..50)
            .filter(|_| {
                a.next_instance().unwrap().values() == b.next_instance().unwrap().values()
            })
            .count();
        assert!(same < 50);
    }

    #[test]
    fn labels_learnable_not_constant() {
        let mut g = RandomTreeGenerator::new(10, 10, 2, 3);
        let mut counts = [0u32; 2];
        for _ in 0..2000 {
            counts[g.next_instance().unwrap().class().unwrap() as usize] += 1;
        }
        // both classes present, neither vanishingly rare
        assert!(counts[0] > 100 && counts[1] > 100, "{counts:?}");
    }

    #[test]
    fn concept_is_a_function_of_attributes() {
        // same attribute values → same label (no noise)
        let g = RandomTreeGenerator::new(3, 3, 2, 5);
        let vals = vec![1.0, 0.0, 2.0, 0.3, 0.7, 0.1];
        assert_eq!(g.classify(&vals), g.classify(&vals));
    }

    #[test]
    fn dimensions_match_config() {
        let mut g = RandomTreeGenerator::new(100, 100, 2, 9);
        let i = g.next_instance().unwrap();
        assert_eq!(i.n_attributes(), 200);
    }
}
