//! Covariate-drift wrapper: inject abrupt distribution shifts into any
//! stream. Every `period` instances each numeric attribute's offset is
//! re-drawn from `±magnitude` (seeded, deterministic), so scalers /
//! discretizers trained on the old regime suddenly stop fitting — the
//! scenario the adaptive sync policies (`preprocess::processor::SyncPolicy`)
//! and the `samoa exp sync-cost` study exercise. Labels and the schema
//! are untouched: the drift is in the input representation, exactly
//! where preprocessing statistics live.

use crate::common::Rng;
use crate::core::instance::Values;
use crate::core::{AttributeKind, Instance, Schema};

use super::StreamSource;

/// Wraps a source with periodic abrupt mean shifts on numeric
/// attributes. `period = 0` disables drift (pass-through).
pub struct DriftingStream<S: StreamSource> {
    inner: S,
    period: u64,
    magnitude: f64,
    rng: Rng,
    /// Current per-attribute offset (zero until the first drift point).
    shift: Vec<f32>,
    numeric: Vec<bool>,
    count: u64,
    drifts: u64,
}

impl<S: StreamSource> DriftingStream<S> {
    pub fn new(inner: S, period: u64, magnitude: f64, seed: u64) -> Self {
        let numeric: Vec<bool> = inner
            .schema()
            .attributes
            .iter()
            .map(|a| matches!(a, AttributeKind::Numeric))
            .collect();
        DriftingStream {
            shift: vec![0.0; numeric.len()],
            numeric,
            inner,
            period,
            magnitude,
            rng: Rng::new(seed ^ 0xD21F_7D21),
            count: 0,
            drifts: 0,
        }
    }

    /// Drift points seen so far.
    pub fn drifts(&self) -> u64 {
        self.drifts
    }

    fn maybe_drift(&mut self) {
        if self.period > 0 && self.count > 0 && self.count % self.period == 0 {
            self.drifts += 1;
            for (j, s) in self.shift.iter_mut().enumerate() {
                if self.numeric[j] {
                    *s = ((self.rng.f64() * 2.0 - 1.0) * self.magnitude) as f32;
                }
            }
        }
    }
}

impl<S: StreamSource> StreamSource for DriftingStream<S> {
    fn schema(&self) -> &Schema {
        self.inner.schema()
    }

    fn next_instance(&mut self) -> Option<Instance> {
        self.maybe_drift();
        self.count += 1;
        let mut inst = self.inner.next_instance()?;
        match inst.values_mut() {
            Values::Dense(v) => {
                for (j, val) in v.iter_mut().enumerate() {
                    if self.numeric[j] {
                        *val += self.shift[j];
                    }
                }
            }
            Values::Sparse { indices, values, .. } => {
                for (&j, val) in indices.iter().zip(values.iter_mut()) {
                    if self.numeric[j as usize] {
                        *val += self.shift[j as usize];
                    }
                }
            }
        }
        Some(inst)
    }

    fn len_hint(&self) -> Option<u64> {
        self.inner.len_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streams::waveform::WaveformGenerator;

    #[test]
    fn shifts_kick_in_at_period_boundaries() {
        let mut plain = WaveformGenerator::classification(3);
        let mut drifty = DriftingStream::new(WaveformGenerator::classification(3), 100, 5.0, 9);
        // first window: identical to the raw stream
        for _ in 0..100 {
            let (a, b) = (plain.next_instance().unwrap(), drifty.next_instance().unwrap());
            assert_eq!(a.values(), b.values());
        }
        assert_eq!(drifty.drifts(), 0);
        // after the drift point the values diverge by a constant offset
        let (a, b) = (plain.next_instance().unwrap(), drifty.next_instance().unwrap());
        assert_eq!(drifty.drifts(), 1);
        let any_shift =
            (0..a.n_attributes()).any(|j| (b.value(j) - a.value(j)).abs() > 1e-6);
        assert!(any_shift, "no attribute shifted after the drift point");
        // labels unchanged
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn zero_period_is_passthrough() {
        let mut plain = WaveformGenerator::new(4);
        let mut drifty = DriftingStream::new(WaveformGenerator::new(4), 0, 5.0, 9);
        for _ in 0..50 {
            assert_eq!(
                plain.next_instance().unwrap().values(),
                drifty.next_instance().unwrap().values()
            );
        }
        assert_eq!(drifty.drifts(), 0);
    }
}
