//! Lane-unrolled (f64x4-style) primitives for the SIMD criterion backend.
//!
//! Pure rust, no external crates and no `std::arch` intrinsics: every
//! helper is written as a straight-line loop over fixed `[f64; LANES]`
//! arrays with branchless per-lane selects, the shape LLVM's
//! auto-vectorizer reliably turns into packed `vaddpd`/`vmulpd`/
//! `vsqrtpd`/blend sequences at `--release`. The payoff over the scalar
//! [`crate::core::criterion`] twins comes from two places:
//!
//! * **batched transcendentals** — entropy needs one `log2` per non-zero
//!   counter; the scalar path calls libm per element behind a data-
//!   dependent branch, while [`log2_lanes`] evaluates four at once with a
//!   short polynomial (exponent split + range-narrowed `atanh` series,
//!   absolute error ≲ 1e-12 — two orders below the 1e-9 equivalence
//!   budget enforced by `tests/runtime_vs_native.rs`);
//! * **wide arithmetic** — row sums, Σ x·log2 x, squared-distance and
//!   SDR-surface evaluation run four lanes per step instead of one.
//!
//! Numerical contract: every kernel built on these helpers must agree
//! with its native twin to ≤ 1e-9 relative (gains/distances) and pick the
//! same `top2` winner outside exact ties. The helpers therefore keep the
//! native EPS policy (clamped denominators, 0·log 0 = 0, no eps added to
//! counts) and only reassociate commutative sums.

/// Lane width of the unrolled kernels. Four f64s = one AVX2 register;
/// narrower targets simply see two SSE2 ops per step.
pub const LANES: usize = 4;

/// Four-lane `log2`. Inputs must be finite, normal and > 0 (callers mask
/// zero counts to 1.0, whose log is exactly 0, before calling).
///
/// Per lane: split `x = m · 2^e` with `m ∈ [1, 2)` by bit twiddling,
/// renormalize to `m ∈ [√2/2, √2)` so `t = (m−1)/(m+1)` satisfies
/// `|t| ≤ √2−1 ≈ 0.1716`, then `ln m = 2·atanh(t)` by its odd series
/// through `t¹³` (truncation < 5e-13) and `log2 x = e + ln m · log2 e`.
#[inline]
pub fn log2_lanes(x: [f64; LANES]) -> [f64; LANES] {
    const LOG2_E: f64 = std::f64::consts::LOG2_E;
    const SQRT_2: f64 = std::f64::consts::SQRT_2;
    const C3: f64 = 1.0 / 3.0;
    const C5: f64 = 1.0 / 5.0;
    const C7: f64 = 1.0 / 7.0;
    const C9: f64 = 1.0 / 9.0;
    const C11: f64 = 1.0 / 11.0;
    const C13: f64 = 1.0 / 13.0;
    let mut out = [0.0f64; LANES];
    for i in 0..LANES {
        let bits = x[i].to_bits();
        let mut e = (((bits >> 52) & 0x7ff) as i64 - 1023) as f64;
        let mut m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
        // branchless renormalization: both arms are cheap selects
        let high = m >= SQRT_2;
        m = if high { 0.5 * m } else { m };
        e = if high { e + 1.0 } else { e };
        let t = (m - 1.0) / (m + 1.0);
        let t2 = t * t;
        let series = C3 + t2 * (C5 + t2 * (C7 + t2 * (C9 + t2 * (C11 + t2 * C13))));
        let ln_m = 2.0 * t * (1.0 + t2 * series);
        out[i] = e + ln_m * LOG2_E;
    }
    out
}

/// Horizontal sum of one lane accumulator, pairwise for balance.
#[inline]
pub fn hsum(acc: [f64; LANES]) -> f64 {
    (acc[0] + acc[2]) + (acc[1] + acc[3])
}

/// One fused pass over a counter slice: `(Σ x, Σ x·log2 x)`, four lanes
/// wide, zero entries contributing exactly 0 to both sums (the native
/// `0·log 0 = 0` policy, realized as a branchless mask to 1.0).
#[inline]
pub fn sum_and_xlog2x(xs: &[f32]) -> (f64, f64) {
    let mut sum = [0.0f64; LANES];
    let mut slog = [0.0f64; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for ch in chunks.by_ref() {
        let lane = [ch[0] as f64, ch[1] as f64, ch[2] as f64, ch[3] as f64];
        accumulate_xlog2x(&mut sum, &mut slog, lane);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut lane = [0.0f64; LANES];
        for (slot, &x) in lane.iter_mut().zip(rem.iter()) {
            *slot = x as f64;
        }
        accumulate_xlog2x(&mut sum, &mut slog, lane);
    }
    (hsum(sum), hsum(slog))
}

#[inline(always)]
fn accumulate_xlog2x(sum: &mut [f64; LANES], slog: &mut [f64; LANES], lane: [f64; LANES]) {
    let mut safe = [0.0f64; LANES];
    for i in 0..LANES {
        // zero (or padded) lanes log 1.0 → contribute exactly 0.0
        safe[i] = if lane[i] > 0.0 { lane[i] } else { 1.0 };
    }
    let lg = log2_lanes(safe);
    for i in 0..LANES {
        sum[i] += lane[i];
        slog[i] += lane[i] * lg[i];
    }
}

/// Shannon entropy (bits) of an unnormalized count slice, lane-unrolled.
///
/// Uses `H = log2 N − (Σ x·log2 x)/N`, the single-pass form of the
/// scalar `−Σ p·log2 p` (identical analytically; differs only in
/// last-ulp rounding). All-zero counts yield exactly 0.
#[inline]
pub fn entropy_lanes(counts: &[f32]) -> f64 {
    let (total, slog) = sum_and_xlog2x(counts);
    if total <= 0.0 {
        return 0.0;
    }
    let lane = [total, 1.0, 1.0, 1.0];
    log2_lanes(lane)[0] - slog / total
}

/// Four-lane squared euclidean distance between f32 slices, accumulated
/// in f64. The per-element difference is computed in f32 (then squared
/// in f64) to match the native kernel's rounding exactly; only the
/// summation order differs.
#[inline]
pub fn sqdist_lanes(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ai = a.chunks_exact(LANES);
    let mut bi = b.chunks_exact(LANES);
    for (ca, cb) in ai.by_ref().zip(bi.by_ref()) {
        for i in 0..LANES {
            let diff = (ca[i] - cb[i]) as f64;
            acc[i] += diff * diff;
        }
    }
    let mut tail = 0.0f64;
    for (&xa, &xb) in ai.remainder().iter().zip(bi.remainder().iter()) {
        let diff = (xa - xb) as f64;
        tail += diff * diff;
    }
    hsum(acc) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn log2_matches_libm_to_1e12() {
        let mut rng = Rng::new(9);
        for _ in 0..4000 {
            // counts and probabilities: magnitudes from 1e-9 up to 1e9
            let exp = (rng.f64() - 0.5) * 60.0;
            let x = rng.f64().max(1e-3) * exp.exp2();
            let got = log2_lanes([x, 1.0, x * 2.0, 0.5])[0];
            let want = x.log2();
            assert!(
                (got - want).abs() <= 1e-12 * (1.0 + want.abs()),
                "log2({x}) = {got}, libm {want}"
            );
        }
    }

    #[test]
    fn log2_exact_at_powers_of_two() {
        let out = log2_lanes([1.0, 2.0, 4.0, 0.25]);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[2], 2.0);
        assert_eq!(out[3], -2.0);
    }

    #[test]
    fn entropy_lanes_matches_native() {
        use crate::core::criterion::entropy;
        let mut rng = Rng::new(17);
        for len in [1usize, 2, 3, 4, 5, 7, 8, 13, 16] {
            for _ in 0..50 {
                let counts: Vec<f32> = (0..len)
                    .map(|_| if rng.bool(0.2) { 0.0 } else { rng.f32() * 100.0 })
                    .collect();
                let native = entropy(&counts);
                let lanes = entropy_lanes(&counts);
                assert!(
                    (native - lanes).abs() <= 1e-11 * (1.0 + native.abs()),
                    "entropy mismatch on {counts:?}: native={native} lanes={lanes}"
                );
            }
        }
        assert_eq!(entropy_lanes(&[]), 0.0);
        assert_eq!(entropy_lanes(&[0.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn sqdist_lanes_matches_scalar() {
        let mut rng = Rng::new(23);
        for d in [1usize, 3, 4, 7, 8, 31, 64] {
            let a: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let scalar: f64 = a
                .iter()
                .zip(b.iter())
                .map(|(&x, &y)| {
                    let diff = (x - y) as f64;
                    diff * diff
                })
                .sum();
            let lanes = sqdist_lanes(&a, &b);
            assert!(
                (scalar - lanes).abs() <= 1e-11 * (1.0 + scalar),
                "sqdist mismatch at d={d}: scalar={scalar} lanes={lanes}"
            );
        }
    }
}
