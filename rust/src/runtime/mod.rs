//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the request path — python is never involved.
//!
//! The `xla` crate's handles wrap raw C pointers and are not `Send`/`Sync`,
//! so the runtime is **thread-local**: each engine thread that evaluates a
//! split criterion lazily builds its own `PjRtClient` and compiles the HLO
//! text once (a few ms), then reuses the loaded executables for the life of
//! the thread. Local-statistics processors call [`gain::gains`] /
//! [`sdr::sdr_surfaces`] / [`cluster::assign`], which transparently choose:
//!
//! * the **XLA path** — artifacts found and `SAMOA_BACKEND` ∈ {auto, xla};
//! * the **native path** — bit-compatible rust implementations in
//!   [`crate::core::criterion`] (also the fallback on any runtime error).
//!
//! `SAMOA_ARTIFACTS` overrides the artifact directory (default: walk up
//! from CWD looking for `artifacts/manifest.txt`).

pub mod shapes;
pub mod registry;
pub mod gain;
pub mod sdr;
pub mod cluster;

pub use registry::{backend_in_use, Backend};
