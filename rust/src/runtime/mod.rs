//! Criterion kernel runtime: one registry, three backends.
//!
//! The per-leaf criterion math — info-gain scans over VHT counter
//! blocks, AMRules SDR evaluation, CluStream distance scans — is where
//! stream-learning throughput bottoms out (paper §Fig 8/9, Table 4), so
//! all three hot loops run behind batch kernel entry points that a
//! process-wide registry binds to one of three implementations:
//!
//! | backend  | implementation | selected when |
//! |---|---|---|
//! | `native` | scalar rust ([`crate::core::criterion`]) | `SAMOA_BACKEND=native`; or the `auto` micro-probe finds no SIMD win; or any XLA runtime error (permanent fallback) |
//! | `simd`   | lane-unrolled rust ([`simd`], f64×4-style, no external crates) | `SAMOA_BACKEND=simd`; or `auto` when the one-shot micro-probe shows a ≥1.25× win on the default 16×8 block shape |
//! | `xla`    | AOT artifacts via PJRT ([`registry::XlaThreadRuntime`]) | `SAMOA_BACKEND=xla` (fails loudly if impossible); or `auto` with compatible `artifacts/` in a build carrying real PJRT bindings ([`xla::AVAILABLE`]) |
//!
//! **Decision order** (`registry::backend_in_use`, latched process-wide
//! on first use): explicit `SAMOA_BACKEND` always wins — `native` and
//! `simd` bind directly, `xla` panics with a diagnostic when artifacts
//! are missing/stale or the build only has the in-tree [`xla`] stub
//! (silent fallback on an explicit request is the worst failure mode
//! for a benchmark run). `auto` (or unset) prefers executable XLA
//! artifacts, then runs the one-shot native-vs-SIMD micro-probe and
//! falls back to native when lane kernels don't clearly win (small
//! blocks, narrow targets). The decision sticks for the life of the
//! process so every leaf evaluation in a run uses one backend; tests
//! that need to re-decide use `registry::reset_for_tests` under
//! `registry::backend_test_lock`.
//!
//! **Fallback rules**: any XLA runtime error force-latches native and
//! logs once. The SIMD kernels have no failure mode (pure rust, any
//! shape) and agree with native to ≤ 1e-9 relative with identical top-2
//! winners outside exact ties (`tests/runtime_vs_native.rs` pins this
//! on every run; the XLA legs additionally pin the artifacts when they
//! exist).
//!
//! Entry points — the *batched* kernel API the algorithm layers call
//! instead of `criterion::*` (VHT model aggregator + local statistics,
//! the sequential Hoeffding tree, AMRules, CluStream):
//!
//! * [`gain::gains`]`(&[&CounterBlock]) -> Vec<f64>` and [`gain::top2`];
//! * [`sdr::sdr_surfaces`]`(&[AttrBins]) -> Vec<Vec<f64>>`;
//! * [`cluster::assign`]`(points, centers, weights, d)`.
//!
//! The XLA path loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them through the PJRT CPU
//! client; its handles wrap raw C pointers and are not `Send`/`Sync`,
//! so that runtime is **thread-local** (each engine thread compiles the
//! HLO text once and reuses the executables). `SAMOA_ARTIFACTS`
//! overrides the artifact directory (default: walk up from CWD looking
//! for `artifacts/manifest.txt`). Dependency-free builds compile the
//! same call sites against the in-tree [`xla`] stub, which reports
//! itself unavailable to the registry and fails cleanly if reached.

pub mod shapes;
pub mod registry;
pub mod simd;
pub mod xla;
pub mod gain;
pub mod sdr;
pub mod cluster;

pub use registry::{backend_in_use, Backend};
