//! Backend registry: the process-wide criterion-backend decision, plus
//! the thread-local PJRT artifact cache for the XLA path.
//!
//! The decision order (see the table in [`super`]) is: an explicit
//! `SAMOA_BACKEND` always wins (and `xla` fails loudly when it cannot
//! run); `auto`/unset prefers executable XLA artifacts, then a one-shot
//! micro-probe between the SIMD and native kernels, cached for the life
//! of the process so every caller sees one consistent backend.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::anyhow;
use crate::common::error::{Context, Result};

use super::shapes::Manifest;
use super::xla;

/// Which criterion backend is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust scalar implementations (core::criterion).
    Native,
    /// AOT XLA artifacts through PJRT.
    Xla,
    /// Lane-unrolled pure-rust kernels (runtime::simd) — no artifacts,
    /// no external crates, selected when the micro-probe shows a win.
    Simd,
}

// 0 = undecided, 1 = native, 2 = xla, 3 = simd
static BACKEND: AtomicU8 = AtomicU8::new(0);

fn encode(b: Backend) -> u8 {
    match b {
        Backend::Native => 1,
        Backend::Xla => 2,
        Backend::Simd => 3,
    }
}

fn decode(v: u8) -> Option<Backend> {
    match v {
        1 => Some(Backend::Native),
        2 => Some(Backend::Xla),
        3 => Some(Backend::Simd),
        _ => None,
    }
}

/// Resolve (and cache) the global backend decision.
///
/// The first caller decides; concurrent first calls race the probe but
/// only one result is latched (compare-exchange), so every subsequent
/// call — on any thread — sees the same backend for the process life.
pub fn backend_in_use() -> Backend {
    if let Some(b) = decode(BACKEND.load(Ordering::Acquire)) {
        return b;
    }
    let choice = decide_backend();
    match BACKEND.compare_exchange(0, encode(choice), Ordering::AcqRel, Ordering::Acquire) {
        Ok(_) => choice,
        // someone else latched first (or a test forced a backend
        // mid-probe): their decision is the sticky one
        Err(prev) => decode(prev).unwrap_or(choice),
    }
}

/// Force a backend (tests, benches, `--backend` CLI flag).
pub fn force_backend(b: Backend) {
    BACKEND.store(encode(b), Ordering::Release);
}

/// Reset the latched decision so the next [`backend_in_use`] re-decides.
///
/// Test-only by intent: the latch is process-global, so tests that
/// [`force_backend`] would otherwise leak their choice into every test
/// that runs after them in the same binary. Integration tests link the
/// non-`cfg(test)` build of this crate, hence `pub` + `doc(hidden)`
/// rather than `#[cfg(test)]`. Pair with [`backend_test_lock`].
#[doc(hidden)]
pub fn reset_for_tests() {
    BACKEND.store(0, Ordering::Release);
}

/// Serialize tests that mutate the global backend latch.
///
/// `cargo test` runs tests on many threads of one binary; two tests
/// calling [`force_backend`]/[`reset_for_tests`] concurrently would
/// observe each other's half-configured state. Every such test takes
/// this lock first (and restores the latch before dropping it), making
/// backend tests order- and schedule-independent. Read-only tests that
/// merely call the criterion wrappers need no lock: they are correct
/// under every backend.
#[doc(hidden)]
pub fn backend_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        // a panicked backend test must not cascade into every later one
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Blocks × shape of the one-shot micro-probe: the default VHT counter
/// block (16 bins × 8 classes), enough blocks to amortize call overhead.
const PROBE_BLOCKS: usize = 64;
/// SIMD must beat native by this factor to be selected under `auto`.
/// The margin keeps the decision stable run-to-run (and, for the
/// cluster engine, process-to-process): machines sitting exactly at the
/// crossover would otherwise flap between backends on scheduler noise.
const PROBE_MARGIN: f64 = 1.25;

/// One-shot micro-probe: time the native and SIMD info-gain kernels on
/// the default 16×8 block shape and pick SIMD only on a clear win —
/// when blocks are too small (or the target too narrow) for the lane
/// kernels to pay off, `auto` falls back to Native.
fn probe_simd_vs_native() -> Backend {
    use crate::core::observers::CounterBlock;
    let mut rng = crate::common::Rng::new(0x5eed);
    let blocks: Vec<CounterBlock> = (0..PROBE_BLOCKS)
        .map(|_| {
            let mut b = CounterBlock::new(16, 8);
            for _ in 0..200 {
                b.add(rng.below(16) as u32, rng.below(8) as u32, 1.0);
            }
            b
        })
        .collect();
    let refs: Vec<&CounterBlock> = blocks.iter().collect();
    // one warmup apiece (page in code, settle the branch predictor),
    // then best-of-3 so a single preemption cannot decide the backend
    std::hint::black_box(super::gain::gains_native(&refs));
    std::hint::black_box(super::gain::gains_simd(&refs));
    let mut best_native = u128::MAX;
    let mut best_simd = u128::MAX;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(super::gain::gains_native(&refs));
        best_native = best_native.min(t0.elapsed().as_nanos());
        let t0 = Instant::now();
        std::hint::black_box(super::gain::gains_simd(&refs));
        best_simd = best_simd.min(t0.elapsed().as_nanos());
    }
    if (best_simd as f64) * PROBE_MARGIN < best_native as f64 {
        Backend::Simd
    } else {
        Backend::Native
    }
}

fn decide_backend() -> Backend {
    // `xla` used to share the `auto` arm here, so an explicit request
    // silently fell back to native when artifacts were absent or stale —
    // the worst failure mode for a benchmark run. Explicit `xla` now
    // aborts with a diagnostic; only `auto` (and unset) keep the quiet
    // fallback. Explicit `native`/`simd` skip probing entirely.
    let explicit_xla = match std::env::var("SAMOA_BACKEND").as_deref() {
        Ok("native") => return Backend::Native,
        Ok("simd") => return Backend::Simd,
        Ok("xla") => true,
        Ok("auto") | Err(_) => false,
        Ok(other) => {
            eprintln!("[samoa] unknown SAMOA_BACKEND={other}, using auto");
            false
        }
    };
    if !xla::AVAILABLE {
        if explicit_xla {
            panic!(
                "SAMOA_BACKEND=xla but this build carries only the in-tree XLA stub \
                 (PJRT bindings not vendored) — use SAMOA_BACKEND=simd|native|auto, \
                 or build with the real `xla` crate"
            );
        }
        // auto: XLA can never execute here, so don't even look for
        // artifacts — go straight to the native/simd probe
        return probe_simd_vs_native();
    }
    match artifacts_dir() {
        Some(dir) => {
            let path = dir.join("manifest.txt");
            let manifest = std::fs::read_to_string(&path).ok();
            match manifest.and_then(|t| Manifest::parse(&t)) {
                Some(m) if m.compatible() => Backend::Xla,
                Some(_) if explicit_xla => {
                    panic!(
                        "SAMOA_BACKEND=xla but {} has an incompatible shape set — \
                         rebuild with `make artifacts`",
                        path.display()
                    );
                }
                Some(_) => {
                    eprintln!(
                        "[samoa] artifact manifest shape mismatch — rebuild with `make artifacts`; probing native/simd"
                    );
                    probe_simd_vs_native()
                }
                None if explicit_xla => {
                    panic!(
                        "SAMOA_BACKEND=xla but {} is missing or unparsable — \
                         run `make artifacts` first",
                        path.display()
                    );
                }
                None => probe_simd_vs_native(),
            }
        }
        None if explicit_xla => {
            panic!(
                "SAMOA_BACKEND=xla but no artifacts directory was found \
                 (set SAMOA_ARTIFACTS or run `make artifacts` at the repo root)"
            );
        }
        None => probe_simd_vs_native(),
    }
}

/// Locate the artifacts directory: `SAMOA_ARTIFACTS`, else walk up from CWD.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("SAMOA_ARTIFACTS") {
        let p = PathBuf::from(p);
        return p.join("manifest.txt").exists().then_some(p);
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Thread-local compiled-executable cache.
pub struct XlaThreadRuntime {
    client: xla::PjRtClient,
    exes: HashMap<&'static str, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl XlaThreadRuntime {
    fn new() -> Result<Self> {
        let dir = artifacts_dir().ok_or_else(|| anyhow!("no artifacts directory found"))?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(XlaThreadRuntime { client, exes: HashMap::new(), dir })
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&mut self, name: &'static str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
            self.exes.insert(name, exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Execute `name` on literal inputs, returning the decomposed output
    /// tuple (artifacts are lowered with return_tuple=True).
    pub fn execute_tuple(
        &mut self,
        name: &'static str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        result.to_tuple()
    }
}

thread_local! {
    static RUNTIME: RefCell<Option<XlaThreadRuntime>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's XLA runtime (created on first use).
pub fn with_runtime<T>(f: impl FnOnce(&mut XlaThreadRuntime) -> Result<T>) -> Result<T> {
    RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(XlaThreadRuntime::new()?);
        }
        f(slot.as_mut().unwrap())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_discoverable_from_repo() {
        // test runs from the crate root, which contains artifacts/
        if artifacts_dir().is_none() {
            eprintln!("artifacts/ not built; skipping");
            return;
        }
        let dir = artifacts_dir().unwrap();
        assert!(dir.join("infogain.hlo.txt").exists());
    }

    #[test]
    fn backend_decision_is_sticky() {
        let _guard = backend_test_lock();
        reset_for_tests();
        let b1 = backend_in_use();
        let b2 = backend_in_use();
        assert_eq!(b1, b2);
        reset_for_tests();
    }

    #[test]
    fn force_and_reset_are_observed() {
        let _guard = backend_test_lock();
        for b in [Backend::Simd, Backend::Native] {
            force_backend(b);
            assert_eq!(backend_in_use(), b);
        }
        reset_for_tests();
        // a fresh decision never selects XLA in the stub build
        assert_ne!(backend_in_use(), Backend::Xla);
        reset_for_tests();
    }

    #[test]
    fn probe_selects_native_or_simd() {
        let b = probe_simd_vs_native();
        assert!(b == Backend::Native || b == Backend::Simd);
    }
}
