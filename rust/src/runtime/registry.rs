//! Thread-local artifact registry: PJRT client + compiled executables.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::anyhow;
use crate::common::error::{Context, Result};

use super::shapes::Manifest;

/// Which criterion backend is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust implementations (core::criterion).
    Native,
    /// AOT XLA artifacts through PJRT.
    Xla,
}

// 0 = undecided, 1 = native, 2 = xla
static BACKEND: AtomicU8 = AtomicU8::new(0);

/// Resolve (and cache) the global backend decision.
pub fn backend_in_use() -> Backend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => Backend::Native,
        2 => Backend::Xla,
        _ => {
            let choice = decide_backend();
            BACKEND.store(if choice == Backend::Xla { 2 } else { 1 }, Ordering::Relaxed);
            choice
        }
    }
}

/// Force a backend (tests, benches, `--backend` CLI flag).
pub fn force_backend(b: Backend) {
    BACKEND.store(if b == Backend::Xla { 2 } else { 1 }, Ordering::Relaxed);
}

fn decide_backend() -> Backend {
    // `xla` used to share the `auto` arm here, so an explicit request
    // silently fell back to native when artifacts were absent or stale —
    // the worst failure mode for a benchmark run. Explicit `xla` now
    // aborts with the manifest diagnostic; only `auto` (and unset) keep
    // the quiet fallback.
    let explicit_xla = match std::env::var("SAMOA_BACKEND").as_deref() {
        Ok("native") => return Backend::Native,
        Ok("xla") => true,
        Ok("auto") | Err(_) => false,
        Ok(other) => {
            eprintln!("[samoa] unknown SAMOA_BACKEND={other}, using auto");
            false
        }
    };
    match artifacts_dir() {
        Some(dir) => {
            let path = dir.join("manifest.txt");
            let manifest = std::fs::read_to_string(&path).ok();
            match manifest.and_then(|t| Manifest::parse(&t)) {
                Some(m) if m.compatible() => Backend::Xla,
                Some(_) if explicit_xla => {
                    panic!(
                        "SAMOA_BACKEND=xla but {} has an incompatible shape set — \
                         rebuild with `make artifacts`",
                        path.display()
                    );
                }
                Some(_) => {
                    eprintln!(
                        "[samoa] artifact manifest shape mismatch — rebuild with `make artifacts`; using native backend"
                    );
                    Backend::Native
                }
                None if explicit_xla => {
                    panic!(
                        "SAMOA_BACKEND=xla but {} is missing or unparsable — \
                         run `make artifacts` first",
                        path.display()
                    );
                }
                None => Backend::Native,
            }
        }
        None if explicit_xla => {
            panic!(
                "SAMOA_BACKEND=xla but no artifacts directory was found \
                 (set SAMOA_ARTIFACTS or run `make artifacts` at the repo root)"
            );
        }
        None => Backend::Native,
    }
}

/// Locate the artifacts directory: `SAMOA_ARTIFACTS`, else walk up from CWD.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("SAMOA_ARTIFACTS") {
        let p = PathBuf::from(p);
        return p.join("manifest.txt").exists().then_some(p);
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Thread-local compiled-executable cache.
pub struct XlaThreadRuntime {
    client: xla::PjRtClient,
    exes: HashMap<&'static str, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl XlaThreadRuntime {
    fn new() -> Result<Self> {
        let dir = artifacts_dir().ok_or_else(|| anyhow!("no artifacts directory found"))?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(XlaThreadRuntime { client, exes: HashMap::new(), dir })
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&mut self, name: &'static str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
            self.exes.insert(name, exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Execute `name` on literal inputs, returning the decomposed output
    /// tuple (artifacts are lowered with return_tuple=True).
    pub fn execute_tuple(
        &mut self,
        name: &'static str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

thread_local! {
    static RUNTIME: RefCell<Option<XlaThreadRuntime>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's XLA runtime (created on first use).
pub fn with_runtime<T>(f: impl FnOnce(&mut XlaThreadRuntime) -> Result<T>) -> Result<T> {
    RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(XlaThreadRuntime::new()?);
        }
        f(slot.as_mut().unwrap())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_discoverable_from_repo() {
        // test runs from the crate root, which contains artifacts/
        if artifacts_dir().is_none() {
            eprintln!("artifacts/ not built; skipping");
            return;
        }
        let dir = artifacts_dir().unwrap();
        assert!(dir.join("infogain.hlo.txt").exists());
    }

    #[test]
    fn backend_decision_is_sticky() {
        let b1 = backend_in_use();
        let b2 = backend_in_use();
        assert_eq!(b1, b2);
    }
}
