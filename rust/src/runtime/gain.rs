//! Split-criterion gains: XLA artifact or native fallback.
//!
//! The local-statistics processor hands over the counter blocks of the
//! attributes it tracks for one leaf; this module returns the information
//! gain of each, chunking the blocks through the fixed-shape
//! `infogain.hlo.txt` artifact (`[IG_A, IG_V, IG_C]`, zero-padded — padding
//! attributes yield gain exactly 0 by kernel construction).

use crate::Result;

use crate::core::criterion;
use crate::core::observers::CounterBlock;

use super::registry::{self, Backend};
use super::shapes::{IG_A, IG_C, IG_V};

/// Information gain for each block, backend-selected.
pub fn gains(blocks: &[&CounterBlock]) -> Vec<f64> {
    match registry::backend_in_use() {
        Backend::Native => gains_native(blocks),
        Backend::Xla => match gains_xla(blocks) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("[samoa] XLA gain path failed ({e:#}); falling back to native");
                registry::force_backend(Backend::Native);
                gains_native(blocks)
            }
        },
    }
}

/// Native path (also the oracle for the integration test).
pub fn gains_native(blocks: &[&CounterBlock]) -> Vec<f64> {
    blocks.iter().map(|b| criterion::info_gain(b)).collect()
}

/// XLA path: chunk blocks into `[IG_A, IG_V, IG_C]` tensors.
pub fn gains_xla(blocks: &[&CounterBlock]) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(blocks.len());
    let mut buf = vec![0f32; IG_A * IG_V * IG_C];
    for chunk in blocks.chunks(IG_A) {
        buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, b) in chunk.iter().enumerate() {
            crate::ensure!(
                b.v() as usize <= IG_V && b.c() as usize <= IG_C,
                "counter block [{}x{}] exceeds artifact shape [{IG_V}x{IG_C}]",
                b.v(),
                b.c()
            );
            b.copy_padded(&mut buf[i * IG_V * IG_C..(i + 1) * IG_V * IG_C], IG_V, IG_C);
        }
        let gain_vec = registry::with_runtime(|rt| {
            let lit = xla::Literal::vec1(&buf).reshape(&[IG_A as i64, IG_V as i64, IG_C as i64])?;
            let outs = rt.execute_tuple("infogain", &[lit])?;
            // outputs: (gain[IG_A], best_idx, best, second)
            Ok(outs[0].to_vec::<f32>()?)
        })?;
        out.extend(gain_vec[..chunk.len()].iter().map(|&g| g as f64));
    }
    Ok(out)
}

/// Top-2 (index, gain) from a gain vector — shared by MA and LS logic.
pub fn top2(gains: &[f64]) -> (usize, f64, usize, f64) {
    let (mut bi, mut b, mut si, mut s) = (0usize, f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY);
    for (i, &g) in gains.iter().enumerate() {
        if g > b {
            si = bi;
            s = b;
            bi = i;
            b = g;
        } else if g > s {
            si = i;
            s = g;
        }
    }
    if gains.len() < 2 {
        (bi, b.max(0.0), bi, 0.0)
    } else {
        (bi, b, si, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    fn random_block(rng: &mut Rng, v: u32, c: u32) -> CounterBlock {
        let mut b = CounterBlock::new(v, c);
        for _ in 0..200 {
            b.add(rng.below(v as usize) as u32, rng.below(c as usize) as u32, 1.0);
        }
        b
    }

    #[test]
    fn native_gains_match_direct() {
        let mut rng = Rng::new(1);
        let blocks: Vec<CounterBlock> = (0..10).map(|_| random_block(&mut rng, 16, 8)).collect();
        let refs: Vec<&CounterBlock> = blocks.iter().collect();
        let g = gains_native(&refs);
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(g[i], criterion::info_gain(b));
        }
    }

    #[test]
    fn top2_basic() {
        let (bi, b, si, s) = top2(&[0.1, 0.9, 0.5]);
        assert_eq!((bi, si), (1, 2));
        assert!((b - 0.9).abs() < 1e-12 && (s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn top2_single() {
        let (bi, b, _, s) = top2(&[0.4]);
        assert_eq!(bi, 0);
        assert!((b - 0.4).abs() < 1e-12);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn top2_ties() {
        let (bi, _, si, _) = top2(&[0.5, 0.5, 0.1]);
        assert_ne!(bi, si);
    }
}
