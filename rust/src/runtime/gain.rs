//! Split-criterion gains: the batch-of-blocks kernel entry point.
//!
//! The local-statistics processor (and the sequential Hoeffding tree)
//! hands over the counter blocks of the attributes it tracks for one
//! leaf; [`gains`] returns the information gain of each through the
//! backend the registry selected: the scalar native twin, the
//! lane-unrolled SIMD kernel, or the fixed-shape `infogain.hlo.txt` XLA
//! artifact (`[IG_A, IG_V, IG_C]`, zero-padded — padding attributes
//! yield gain exactly 0 by kernel construction).

use crate::Result;

use crate::core::criterion;
use crate::core::observers::CounterBlock;

use super::registry::{self, Backend};
use super::shapes::{IG_A, IG_C, IG_V};
use super::simd;
use super::xla;

/// Information gain for each block, backend-selected. The single entry
/// point for the VHT model aggregator / local-statistics processors and
/// the sequential Hoeffding tree — callers never touch
/// `criterion::info_gain` directly, so one registry decision covers
/// every split evaluation in the process.
pub fn gains(blocks: &[&CounterBlock]) -> Vec<f64> {
    match registry::backend_in_use() {
        Backend::Native => gains_native(blocks),
        Backend::Simd => gains_simd(blocks),
        Backend::Xla => match gains_xla(blocks) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("[samoa] XLA gain path failed ({e:#}); falling back to native");
                registry::force_backend(Backend::Native);
                gains_native(blocks)
            }
        },
    }
}

/// Native path (also the oracle for the integration test).
pub fn gains_native(blocks: &[&CounterBlock]) -> Vec<f64> {
    blocks.iter().map(|b| criterion::info_gain(b)).collect()
}

/// SIMD path: four-lane unrolled entropy over each block's rows.
///
/// Agrees with [`gains_native`] to ≤ 1e-9 relative with identical
/// top-2 winners outside exact ties (`tests/runtime_vs_native.rs`).
pub fn gains_simd(blocks: &[&CounterBlock]) -> Vec<f64> {
    blocks.iter().map(|b| info_gain_simd(b)).collect()
}

/// Lane-unrolled information gain of one block.
///
/// Same EPS policy as the native twin (empty block ⇒ exactly 0, empty
/// rows skipped, 0·log 0 = 0); uses the single-pass entropy identity
/// `Σ_v (N_v/N)·H(row_v) = (Σ_v N_v·log2 N_v − Σ_vc x·log2 x)/N` so one
/// fused sweep per row feeds the 4-wide `log2`.
pub fn info_gain_simd(block: &CounterBlock) -> f64 {
    let total = block.total() as f64;
    if total <= 0.0 {
        return 0.0;
    }
    let h_before = simd::entropy_lanes(&block.class_counts());
    let c = block.c() as usize;
    let raw = block.raw();
    // Σ_v (N_v·log2 N_v − Σ_c x·log2 x): the numerator of H(class|attr)·N
    let mut h_after_num = 0.0f64;
    for v in 0..block.v() as usize {
        let row = &raw[v * c..(v + 1) * c];
        let (nv, slog) = simd::sum_and_xlog2x(row);
        if nv > 0.0 {
            let log_nv = simd::log2_lanes([nv, 1.0, 1.0, 1.0])[0];
            h_after_num += nv * log_nv - slog;
        }
    }
    h_before - h_after_num / total
}

/// XLA path: chunk blocks into `[IG_A, IG_V, IG_C]` tensors.
pub fn gains_xla(blocks: &[&CounterBlock]) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(blocks.len());
    let mut buf = vec![0f32; IG_A * IG_V * IG_C];
    for chunk in blocks.chunks(IG_A) {
        buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, b) in chunk.iter().enumerate() {
            crate::ensure!(
                b.v() as usize <= IG_V && b.c() as usize <= IG_C,
                "counter block [{}x{}] exceeds artifact shape [{IG_V}x{IG_C}]",
                b.v(),
                b.c()
            );
            b.copy_padded(&mut buf[i * IG_V * IG_C..(i + 1) * IG_V * IG_C], IG_V, IG_C);
        }
        let gain_vec = registry::with_runtime(|rt| {
            let lit = xla::Literal::vec1(&buf).reshape(&[IG_A as i64, IG_V as i64, IG_C as i64])?;
            let outs = rt.execute_tuple("infogain", &[lit])?;
            // outputs: (gain[IG_A], best_idx, best, second)
            outs[0].to_vec::<f32>()
        })?;
        out.extend(gain_vec[..chunk.len()].iter().map(|&g| g as f64));
    }
    Ok(out)
}

/// Top-2 (index, gain) from a gain vector — shared by MA and LS logic.
///
/// Returns `(best_idx, best, second_idx, second)`. With fewer than two
/// candidates the *true* best value is returned unclamped (a rounding-
/// negative gain used to be floored to 0 here, hiding it from the
/// caller's `best > 0` pre-pruning check); the missing runner-up
/// reports index = best_idx and gain 0 — the no-split scenario it
/// competes against. An empty slice yields `(0, 0.0, 0, 0.0)`.
pub fn top2(gains: &[f64]) -> (usize, f64, usize, f64) {
    if gains.is_empty() {
        return (0, 0.0, 0, 0.0);
    }
    let (mut bi, mut b, mut si, mut s) = (0usize, f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY);
    for (i, &g) in gains.iter().enumerate() {
        if g > b {
            si = bi;
            s = b;
            bi = i;
            b = g;
        } else if g > s {
            si = i;
            s = g;
        }
    }
    if gains.len() < 2 {
        (bi, b, bi, 0.0)
    } else {
        (bi, b, si, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    fn random_block(rng: &mut Rng, v: u32, c: u32) -> CounterBlock {
        let mut b = CounterBlock::new(v, c);
        for _ in 0..200 {
            b.add(rng.below(v as usize) as u32, rng.below(c as usize) as u32, 1.0);
        }
        b
    }

    #[test]
    fn native_gains_match_direct() {
        let mut rng = Rng::new(1);
        let blocks: Vec<CounterBlock> = (0..10).map(|_| random_block(&mut rng, 16, 8)).collect();
        let refs: Vec<&CounterBlock> = blocks.iter().collect();
        let g = gains_native(&refs);
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(g[i], criterion::info_gain(b));
        }
    }

    #[test]
    fn simd_gains_match_native_on_default_shape() {
        let mut rng = Rng::new(2);
        let blocks: Vec<CounterBlock> = (0..32).map(|_| random_block(&mut rng, 16, 8)).collect();
        let refs: Vec<&CounterBlock> = blocks.iter().collect();
        let native = gains_native(&refs);
        let simd = gains_simd(&refs);
        for (i, (n, s)) in native.iter().zip(simd.iter()).enumerate() {
            assert!(
                (n - s).abs() <= 1e-9 * (1.0 + n.abs()),
                "block {i}: native={n} simd={s}"
            );
        }
    }

    #[test]
    fn simd_gain_degenerate_blocks() {
        let empty = CounterBlock::new(16, 8);
        assert_eq!(info_gain_simd(&empty), 0.0);
        let mut pure = CounterBlock::new(16, 8);
        for v in 0..16 {
            pure.add(v, 2, 5.0);
        }
        assert!(info_gain_simd(&pure).abs() < 1e-10);
        // perfect split: gain = H(class) = 1 bit
        let mut b = CounterBlock::new(4, 2);
        for v in 0..4 {
            b.add(v, v % 2, 10.0);
        }
        assert!((info_gain_simd(&b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn forced_simd_backend_dispatches_to_simd_kernel() {
        let _guard = registry::backend_test_lock();
        let mut rng = Rng::new(3);
        let blocks: Vec<CounterBlock> = (0..6).map(|_| random_block(&mut rng, 16, 8)).collect();
        let refs: Vec<&CounterBlock> = blocks.iter().collect();
        registry::force_backend(Backend::Simd);
        let dispatched = gains(&refs);
        assert_eq!(dispatched, gains_simd(&refs));
        registry::force_backend(Backend::Native);
        let dispatched = gains(&refs);
        assert_eq!(dispatched, gains_native(&refs));
        registry::reset_for_tests();
    }

    #[test]
    fn top2_basic() {
        let (bi, b, si, s) = top2(&[0.1, 0.9, 0.5]);
        assert_eq!((bi, si), (1, 2));
        assert!((b - 0.9).abs() < 1e-12 && (s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn top2_single() {
        let (bi, b, _, s) = top2(&[0.4]);
        assert_eq!(bi, 0);
        assert!((b - 0.4).abs() < 1e-12);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn top2_single_negative_not_clamped() {
        // regression: a single rounding-negative gain used to be floored
        // to 0.0, making the caller's `best > 0` pre-pruning check see a
        // phantom zero-gain candidate
        let (bi, b, si, s) = top2(&[-1e-12]);
        assert_eq!((bi, si), (0, 0));
        assert_eq!(b, -1e-12);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn top2_empty() {
        assert_eq!(top2(&[]), (0, 0.0, 0, 0.0));
    }

    #[test]
    fn top2_ties() {
        let (bi, _, si, _) = top2(&[0.5, 0.5, 0.1]);
        assert_ne!(bi, si);
    }
}
