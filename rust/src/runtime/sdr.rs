//! SDR surfaces for AMRules expansion — batch-of-attributes entry point.
//!
//! [`sdr_surfaces`] is the single route every AMRules learner variant
//! (sequential, VAMR, HAMR) takes to evaluate candidate splits; the
//! registry picks the scalar native twin, the lane-unrolled SIMD kernel,
//! or the XLA artifact.

use crate::Result;

use crate::core::criterion::{self, VarStats, EPS};

use super::registry::{self, Backend};
use super::shapes::{SDR_A, SDR_B};
use super::simd::LANES;
use super::xla;

/// Per-attribute candidate-split statistics: one `VarStats` per bin.
pub type AttrBins = Vec<VarStats>;

/// SDR surface (`[bins]` per attribute) for every attribute's bins.
pub fn sdr_surfaces(attrs: &[AttrBins]) -> Vec<Vec<f64>> {
    match registry::backend_in_use() {
        Backend::Native => sdr_native(attrs),
        Backend::Simd => sdr_simd(attrs),
        Backend::Xla => match sdr_xla(attrs) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[samoa] XLA sdr path failed ({e:#}); falling back to native");
                registry::force_backend(Backend::Native);
                sdr_native(attrs)
            }
        },
    }
}

pub fn sdr_native(attrs: &[AttrBins]) -> Vec<Vec<f64>> {
    attrs.iter().map(|bins| criterion::sdr_surface(bins)).collect()
}

/// SIMD path: four thresholds per step over the prefix-merged stats.
pub fn sdr_simd(attrs: &[AttrBins]) -> Vec<Vec<f64>> {
    attrs.iter().map(|bins| sdr_surface_simd(bins)).collect()
}

/// Lane-unrolled SDR surface over cumulative per-bin stats.
///
/// The prefix merge runs sequentially in the native accumulation order;
/// the per-threshold `sdr(total, left, right)` evaluation — two
/// divisions and two square roots per bin on the scalar path — then
/// proceeds four thresholds at a time with the guards (`left.n ≤ 0` or
/// `right.n ≤ 0` ⇒ 0) as branchless selects. Per-threshold the exact
/// native operation sequence is preserved, so results match the scalar
/// twin to the last ulp.
pub fn sdr_surface_simd(bins: &[VarStats]) -> Vec<f64> {
    let n_bins = bins.len();
    if n_bins == 0 {
        return Vec::new();
    }
    let total = bins.iter().fold(VarStats::default(), |a, b| a.merge(b));
    // prefix (left-side) stats, native merge order
    let mut ln = vec![0.0f64; n_bins];
    let mut lsum = vec![0.0f64; n_bins];
    let mut lsq = vec![0.0f64; n_bins];
    let mut left = VarStats::default();
    for (i, b) in bins.iter().enumerate() {
        left = left.merge(b);
        ln[i] = left.n;
        lsum[i] = left.sum;
        lsq[i] = left.sq;
    }
    let t_n = total.n.max(EPS);
    let t_sd = total.sd();

    // per-lane sd(): n/sum/sq → sqrt(max(sq/n' − mean², 0)), n' = max(n, EPS)
    #[inline(always)]
    fn sd_lanes(n: [f64; LANES], sum: [f64; LANES], sq: [f64; LANES]) -> [f64; LANES] {
        let mut out = [0.0f64; LANES];
        for i in 0..LANES {
            let nc = n[i].max(EPS);
            let mean = sum[i] / nc;
            out[i] = (sq[i] / nc - mean * mean).max(0.0).sqrt();
        }
        out
    }

    let mut out = vec![0.0f64; n_bins];
    let mut i = 0usize;
    while i < n_bins {
        let mut l_n = [0.0f64; LANES];
        let mut l_sum = [0.0f64; LANES];
        let mut l_sq = [0.0f64; LANES];
        let mut r_n = [0.0f64; LANES];
        let mut r_sum = [0.0f64; LANES];
        let mut r_sq = [0.0f64; LANES];
        let width = LANES.min(n_bins - i);
        for k in 0..width {
            l_n[k] = ln[i + k];
            l_sum[k] = lsum[i + k];
            l_sq[k] = lsq[i + k];
            r_n[k] = total.n - l_n[k];
            r_sum[k] = total.sum - l_sum[k];
            r_sq[k] = total.sq - l_sq[k];
        }
        let l_sd = sd_lanes(l_n, l_sum, l_sq);
        let r_sd = sd_lanes(r_n, r_sum, r_sq);
        for k in 0..width {
            let sdr = t_sd - (l_n[k] / t_n) * l_sd[k] - (r_n[k] / t_n) * r_sd[k];
            // empty side ⇒ 0, the native guard, as a select
            out[i + k] = if l_n[k] <= 0.0 || r_n[k] <= 0.0 { 0.0 } else { sdr };
        }
        i += width;
    }
    out
}

/// XLA path: chunk attributes into `[SDR_A, SDR_B, 3]` tensors.
pub fn sdr_xla(attrs: &[AttrBins]) -> Result<Vec<Vec<f64>>> {
    let mut out = Vec::with_capacity(attrs.len());
    let mut buf = vec![0f32; SDR_A * SDR_B * 3];
    for chunk in attrs.chunks(SDR_A) {
        buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, bins) in chunk.iter().enumerate() {
            crate::ensure!(
                bins.len() <= SDR_B,
                "attribute has {} bins, artifact supports {SDR_B}",
                bins.len()
            );
            for (bidx, st) in bins.iter().enumerate() {
                let off = i * SDR_B * 3 + bidx * 3;
                buf[off] = st.n as f32;
                buf[off + 1] = st.sum as f32;
                buf[off + 2] = st.sq as f32;
            }
        }
        let flat = registry::with_runtime(|rt| {
            let lit = xla::Literal::vec1(&buf).reshape(&[SDR_A as i64, SDR_B as i64, 3])?;
            let outs = rt.execute_tuple("sdr", &[lit])?;
            // outputs: (sdr[SDR_A, SDR_B], best_flat_idx, best, second)
            outs[0].to_vec::<f32>()
        })?;
        for (i, bins) in chunk.iter().enumerate() {
            out.push(
                flat[i * SDR_B..i * SDR_B + bins.len()]
                    .iter()
                    .map(|&x| x as f64)
                    .collect(),
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn native_matches_direct_surface() {
        let mut bins = vec![VarStats::default(); 8];
        for (i, b) in bins.iter_mut().enumerate() {
            b.add(i as f64, 2.0);
        }
        let s = sdr_native(&[bins.clone()]);
        assert_eq!(s[0], criterion::sdr_surface(&bins));
    }

    #[test]
    fn simd_surface_matches_native() {
        let mut rng = Rng::new(5);
        for bins_len in [1usize, 2, 3, 4, 5, 8, 17, 64] {
            let bins: AttrBins = (0..bins_len)
                .map(|_| {
                    let mut s = VarStats::default();
                    for _ in 0..rng.below(12) {
                        s.add(rng.gaussian() * 4.0 - 1.0, 1.0);
                    }
                    s
                })
                .collect();
            let native = criterion::sdr_surface(&bins);
            let simd = sdr_surface_simd(&bins);
            assert_eq!(native.len(), simd.len());
            for (b, (n, s)) in native.iter().zip(simd.iter()).enumerate() {
                assert!(
                    (n - s).abs() <= 1e-9 * (1.0 + n.abs()),
                    "bins={bins_len} bin {b}: native={n} simd={s}"
                );
            }
        }
    }

    #[test]
    fn simd_surface_empty_and_degenerate() {
        assert!(sdr_surface_simd(&[]).is_empty());
        // all-empty bins: every threshold has an empty side → all zeros
        let empty = vec![VarStats::default(); 6];
        assert_eq!(sdr_surface_simd(&empty), vec![0.0; 6]);
    }
}
