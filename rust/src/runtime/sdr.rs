//! SDR surfaces for AMRules expansion: XLA artifact or native fallback.

use crate::Result;

use crate::core::criterion::{self, VarStats};

use super::registry::{self, Backend};
use super::shapes::{SDR_A, SDR_B};

/// Per-attribute candidate-split statistics: one `VarStats` per bin.
pub type AttrBins = Vec<VarStats>;

/// SDR surface (`[bins]` per attribute) for every attribute's bins.
pub fn sdr_surfaces(attrs: &[AttrBins]) -> Vec<Vec<f64>> {
    match registry::backend_in_use() {
        Backend::Native => sdr_native(attrs),
        Backend::Xla => match sdr_xla(attrs) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[samoa] XLA sdr path failed ({e:#}); falling back to native");
                registry::force_backend(Backend::Native);
                sdr_native(attrs)
            }
        },
    }
}

pub fn sdr_native(attrs: &[AttrBins]) -> Vec<Vec<f64>> {
    attrs.iter().map(|bins| criterion::sdr_surface(bins)).collect()
}

/// XLA path: chunk attributes into `[SDR_A, SDR_B, 3]` tensors.
pub fn sdr_xla(attrs: &[AttrBins]) -> Result<Vec<Vec<f64>>> {
    let mut out = Vec::with_capacity(attrs.len());
    let mut buf = vec![0f32; SDR_A * SDR_B * 3];
    for chunk in attrs.chunks(SDR_A) {
        buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, bins) in chunk.iter().enumerate() {
            crate::ensure!(
                bins.len() <= SDR_B,
                "attribute has {} bins, artifact supports {SDR_B}",
                bins.len()
            );
            for (bidx, st) in bins.iter().enumerate() {
                let off = i * SDR_B * 3 + bidx * 3;
                buf[off] = st.n as f32;
                buf[off + 1] = st.sum as f32;
                buf[off + 2] = st.sq as f32;
            }
        }
        let flat = registry::with_runtime(|rt| {
            let lit =
                xla::Literal::vec1(&buf).reshape(&[SDR_A as i64, SDR_B as i64, 3])?;
            let outs = rt.execute_tuple("sdr", &[lit])?;
            // outputs: (sdr[SDR_A, SDR_B], best_flat_idx, best, second)
            Ok(outs[0].to_vec::<f32>()?)
        })?;
        for (i, bins) in chunk.iter().enumerate() {
            out.push(
                flat[i * SDR_B..i * SDR_B + bins.len()]
                    .iter()
                    .map(|&x| x as f64)
                    .collect(),
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_matches_direct_surface() {
        let mut bins = vec![VarStats::default(); 8];
        for (i, b) in bins.iter_mut().enumerate() {
            b.add(i as f64, 2.0);
        }
        let s = sdr_native(&[bins.clone()]);
        assert_eq!(s[0], criterion::sdr_surface(&bins));
    }
}
