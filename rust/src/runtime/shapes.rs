//! Compile-time shapes of the AOT artifacts.
//!
//! Must match `python/compile/model.py` (the AOT manifest is checked at
//! load time; a mismatch disables the XLA path with a warning rather than
//! corrupting results).

/// Info-gain artifact: `n[IG_A, IG_V, IG_C] → (gain[IG_A], idx, best, 2nd)`.
pub const IG_A: usize = 64;
pub const IG_V: usize = 16;
pub const IG_C: usize = 8;

/// SDR artifact: `stats[SDR_A, SDR_B, 3] → (sdr[SDR_A, SDR_B], idx, best, 2nd)`.
pub const SDR_A: usize = 32;
pub const SDR_B: usize = 64;

/// Cluster artifact: `x[CL_N, CL_D], c[CL_K, CL_D], w[CL_K] → (idx[CL_N], d2[CL_N])`.
pub const CL_N: usize = 128;
pub const CL_K: usize = 128;
pub const CL_D: usize = 64;

/// Parsed `manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub ig: (usize, usize, usize),
    pub sdr: (usize, usize),
    pub cluster: (usize, usize, usize),
}

impl Manifest {
    pub fn parse(text: &str) -> Option<Manifest> {
        let mut ig = None;
        let mut sdr = None;
        let mut cluster = None;
        for line in text.lines() {
            let f: Vec<&str> = line.split_whitespace().collect();
            match f.as_slice() {
                ["ig_shape", a, v, c] => {
                    ig = Some((a.parse().ok()?, v.parse().ok()?, c.parse().ok()?))
                }
                ["sdr_shape", a, b] => sdr = Some((a.parse().ok()?, b.parse().ok()?)),
                ["cluster_shape", n, k, d] => {
                    cluster = Some((n.parse().ok()?, k.parse().ok()?, d.parse().ok()?))
                }
                _ => {}
            }
        }
        Some(Manifest { ig: ig?, sdr: sdr?, cluster: cluster? })
    }

    /// Does the manifest match this build's constants?
    pub fn compatible(&self) -> bool {
        self.ig == (IG_A, IG_V, IG_C)
            && self.sdr == (SDR_A, SDR_B)
            && self.cluster == (CL_N, CL_K, CL_D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(
            "ig_shape 64 16 8\nsdr_shape 32 64\ncluster_shape 128 128 64\nartifact x y 1\n",
        )
        .unwrap();
        assert!(m.compatible());
    }

    #[test]
    fn incompatible_shapes_detected() {
        let m = Manifest::parse("ig_shape 32 16 8\nsdr_shape 32 64\ncluster_shape 128 128 64\n")
            .unwrap();
        assert!(!m.compatible());
    }

    #[test]
    fn missing_lines_none() {
        assert!(Manifest::parse("ig_shape 64 16 8\n").is_none());
    }
}
