//! In-tree stub of the `xla` crate surface the runtime uses.
//!
//! The crate is dependency-free by policy (see `common::error` for the
//! rationale), and the real PJRT bindings are a heavyweight native
//! dependency that offline builds cannot fetch. This module keeps every
//! XLA call site compiling with the exact API shapes of the `xla` crate
//! (`PjRtClient::cpu`, `Literal::vec1(..).reshape(..)`,
//! `execute::<Literal>(..)`, …); each entry point fails at runtime with a
//! clear "built without XLA support" error, which the kernel wrappers in
//! [`super::gain`] / [`super::sdr`] / [`super::cluster`] already treat as
//! "fall back to the native backend".
//!
//! A build that vendors the real bindings replaces this module and flips
//! [`AVAILABLE`]; the backend decision in [`super::registry`] consults
//! that flag so `SAMOA_BACKEND=auto` never selects a backend that cannot
//! execute, and `SAMOA_BACKEND=xla` fails loudly instead of silently
//! degrading.

use crate::anyhow;
use crate::common::error::Result;

/// Whether this build can actually execute XLA artifacts. The stub
/// cannot; the backend decision in [`super::registry`] treats the XLA
/// backend as unavailable when this is false.
pub const AVAILABLE: bool = false;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(anyhow!("built without XLA support ({what}: PJRT bindings not vendored)"))
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub build — the first call any XLA path makes.
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<ExecuteOutput>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of the per-device buffer an execution returns.
pub struct ExecuteOutput;

impl ExecuteOutput {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("ExecuteOutput::to_literal_sync")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Element types a [`Literal`] can decompose into.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Stub of `xla::Literal` (host tensor).
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("built without XLA support"), "unhelpful stub error: {msg}");
    }

    #[test]
    fn stub_is_marked_unavailable() {
        assert!(!AVAILABLE);
    }
}
