//! CluStream nearest-centroid assignment — batch-of-points entry point.
//!
//! [`assign`] is the single route CluStream (batch flush and the
//! distributed worker processors) takes to the distance scan; the
//! registry picks the scalar native twin, the lane-unrolled SIMD
//! kernel, or the XLA artifact.

use crate::Result;

use super::registry::{self, Backend};
use super::shapes::{CL_D, CL_K, CL_N};
use super::simd;
use super::xla;

/// Assign each point to its nearest live centroid.
///
/// `points`: `n × d` row-major, `centers`: `k × d` row-major, `weights[k]`
/// (weight 0 ⇒ dead slot). Returns (index, squared distance) per point.
pub fn assign(
    points: &[f32],
    centers: &[f32],
    weights: &[f32],
    d: usize,
) -> Vec<(usize, f64)> {
    let n = points.len() / d;
    let k = weights.len();
    debug_assert_eq!(centers.len(), k * d);
    match registry::backend_in_use() {
        Backend::Native => assign_native(points, centers, weights, d),
        Backend::Simd => assign_simd(points, centers, weights, d),
        Backend::Xla if n <= CL_N && k <= CL_K && d <= CL_D => {
            match assign_xla(points, centers, weights, d) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("[samoa] XLA cluster path failed ({e:#}); falling back to native");
                    registry::force_backend(Backend::Native);
                    assign_native(points, centers, weights, d)
                }
            }
        }
        // shapes exceed the artifact: native handles arbitrary sizes
        Backend::Xla => assign_native(points, centers, weights, d),
    }
}

/// Native brute-force assignment.
pub fn assign_native(
    points: &[f32],
    centers: &[f32],
    weights: &[f32],
    d: usize,
) -> Vec<(usize, f64)> {
    let n = points.len() / d;
    let k = weights.len();
    let mut out = Vec::with_capacity(n);
    for p in 0..n {
        let pv = &points[p * d..(p + 1) * d];
        let mut best = (usize::MAX, f64::INFINITY);
        for c in 0..k {
            if weights[c] <= 0.0 {
                continue;
            }
            let cv = &centers[c * d..(c + 1) * d];
            let mut acc = 0f64;
            for i in 0..d {
                let diff = (pv[i] - cv[i]) as f64;
                acc += diff * diff;
            }
            if acc < best.1 {
                best = (c, acc);
            }
        }
        out.push(best);
    }
    out
}

/// SIMD brute-force assignment: the inner distance loop runs four f64
/// lanes wide ([`simd::sqdist_lanes`]). Per-element rounding matches the
/// native kernel (f32 difference, f64 square); only the accumulation
/// order differs, so distances agree to ≤ 1e-9 relative and the winning
/// index can move only between exactly (to that tolerance) tied
/// centroids. Dead slots (`weight ≤ 0`) are skipped identically.
pub fn assign_simd(
    points: &[f32],
    centers: &[f32],
    weights: &[f32],
    d: usize,
) -> Vec<(usize, f64)> {
    let n = points.len() / d;
    let k = weights.len();
    let mut out = Vec::with_capacity(n);
    for p in 0..n {
        let pv = &points[p * d..(p + 1) * d];
        let mut best = (usize::MAX, f64::INFINITY);
        for c in 0..k {
            if weights[c] <= 0.0 {
                continue;
            }
            let cv = &centers[c * d..(c + 1) * d];
            let acc = simd::sqdist_lanes(pv, cv);
            if acc < best.1 {
                best = (c, acc);
            }
        }
        out.push(best);
    }
    out
}

/// XLA path: single padded `[CL_N, CL_D] × [CL_K, CL_D]` invocation.
pub fn assign_xla(
    points: &[f32],
    centers: &[f32],
    weights: &[f32],
    d: usize,
) -> Result<Vec<(usize, f64)>> {
    let n = points.len() / d;
    let k = weights.len();
    let mut px = vec![0f32; CL_N * CL_D];
    let mut cx = vec![0f32; CL_K * CL_D];
    let mut wx = vec![0f32; CL_K];
    for p in 0..n {
        px[p * CL_D..p * CL_D + d].copy_from_slice(&points[p * d..(p + 1) * d]);
    }
    for c in 0..k {
        cx[c * CL_D..c * CL_D + d].copy_from_slice(&centers[c * d..(c + 1) * d]);
    }
    wx[..k].copy_from_slice(weights);

    let (idx, d2) = registry::with_runtime(|rt| {
        let pl = xla::Literal::vec1(&px).reshape(&[CL_N as i64, CL_D as i64])?;
        let cl = xla::Literal::vec1(&cx).reshape(&[CL_K as i64, CL_D as i64])?;
        let wl = xla::Literal::vec1(&wx);
        let outs = rt.execute_tuple("cluster", &[pl, cl, wl])?;
        Ok((outs[0].to_vec::<i32>()?, outs[1].to_vec::<f32>()?))
    })?;
    Ok((0..n).map(|p| (idx[p] as usize, d2[p] as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;

    #[test]
    fn native_picks_nearest() {
        let points = [0.0, 0.0, 10.0, 10.0];
        let centers = [0.0, 1.0, 9.0, 9.0];
        let weights = [1.0, 1.0];
        let a = assign_native(&points, &centers, &weights, 2);
        assert_eq!(a[0].0, 0);
        assert_eq!(a[1].0, 1);
        assert!((a[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn native_skips_dead_slots() {
        let points = [0.0, 0.0];
        let centers = [0.0, 0.0, 5.0, 5.0];
        let weights = [0.0, 1.0]; // exact-match centroid is dead
        let a = assign_native(&points, &centers, &weights, 2);
        assert_eq!(a[0].0, 1);
    }

    #[test]
    fn simd_matches_native_across_dims() {
        let mut rng = Rng::new(7);
        for d in [1usize, 2, 3, 4, 5, 8, 17, 64] {
            let (n, k) = (12usize, 9usize);
            let points: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
            let centers: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
            let mut weights = vec![1f32; k];
            weights[3] = 0.0; // one dead slot
            let native = assign_native(&points, &centers, &weights, d);
            let simd = assign_simd(&points, &centers, &weights, d);
            for (p, (nv, sv)) in native.iter().zip(simd.iter()).enumerate() {
                assert!(
                    (nv.1 - sv.1).abs() <= 1e-9 * (1.0 + nv.1),
                    "d={d} point {p}: native={nv:?} simd={sv:?}"
                );
                assert!(
                    nv.0 == sv.0 || (native[p].1 - simd[p].1).abs() <= 1e-9 * (1.0 + native[p].1),
                    "d={d} point {p}: winner differs off-tie: native={nv:?} simd={sv:?}"
                );
                assert_ne!(sv.0, 3, "dead slot won at point {p}");
            }
        }
    }

    #[test]
    fn simd_skips_dead_slots_and_empty_centroids() {
        let points = [0.0f32, 0.0];
        let centers = [0.0f32, 0.0, 5.0, 5.0];
        let weights = [0.0f32, 1.0];
        let a = assign_simd(&points, &centers, &weights, 2);
        assert_eq!(a[0].0, 1);
        // no live centroid: sentinel result, same as native
        let none = assign_simd(&points, &centers, &[0.0, 0.0], 2);
        assert_eq!(none[0].0, usize::MAX);
    }
}
