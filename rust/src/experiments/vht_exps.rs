//! VHT experiments: Figs 3-9 and Tables 3-4 of the paper.
//!
//! Instance counts default well below the paper's 1M (this is a 1-core
//! container); `--instances N --seeds K` restore paper scale.

use crate::common::cli::Args;
use crate::streams::random_tree::RandomTreeGenerator;
use crate::streams::random_tweet::RandomTweetGenerator;
use crate::streams::StreamSource;

use super::runner::{run_variant, EngineKind, Outcome, Variant};
use super::{dataset_stream, print_table};

/// Dense configurations: (categorical, numeric) — the paper's 10-10,
/// 100-100, 1k-1k labels.
fn dense_configs(args: &Args) -> Vec<(usize, usize)> {
    if args.flag("large") {
        vec![(10, 10), (100, 100), (1000, 1000)]
    } else {
        vec![(10, 10), (100, 100)]
    }
}

fn sparse_dims(args: &Args) -> Vec<u32> {
    if args.flag("large") {
        vec![100, 1000, 10_000]
    } else {
        vec![100, 1000]
    }
}

fn dense_stream(cfg: (usize, usize), seed: u64) -> Box<dyn StreamSource> {
    Box::new(RandomTreeGenerator::new(cfg.0, cfg.1, 2, seed))
}

fn sparse_stream(dim: u32, seed: u64) -> Box<dyn StreamSource> {
    Box::new(RandomTweetGenerator::new(dim, seed))
}

/// Average an outcome metric over seeds.
fn avg(outs: &[Outcome], f: impl Fn(&Outcome) -> f64) -> f64 {
    outs.iter().map(&f).sum::<f64>() / outs.len().max(1) as f64
}

fn seeds(args: &Args) -> u64 {
    args.u64("seeds", 3)
}

/// Fig 3: VHT local vs MOA — accuracy and execution time, dense + sparse.
pub fn fig3(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 100_000);
    let mut rows = Vec::new();
    for &cfg in &dense_configs(args) {
        for variant in [Variant::Moa, Variant::Local] {
            let outs: Vec<Outcome> = (0..seeds(args))
                .map(|s| {
                    let mut stream = dense_stream(cfg, 100 + s);
                    run_variant(
                        stream.as_mut(),
                        variant,
                        n,
                        EngineKind::LocalDeterministic { feedback_delay: 0 },
                        false,
                        n / 10,
                    )
                })
                .collect();
            rows.push(vec![
                format!("dense {}-{}", cfg.0, cfg.1),
                variant.to_string(),
                format!("{:.3}", avg(&outs, |o| o.accuracy)),
                format!("{:.2}", avg(&outs, |o| o.wall_s)),
            ]);
        }
    }
    for &dim in &sparse_dims(args) {
        for variant in [Variant::Moa, Variant::Local] {
            let outs: Vec<Outcome> = (0..seeds(args))
                .map(|s| {
                    let mut stream = sparse_stream(dim, 200 + s);
                    run_variant(
                        stream.as_mut(),
                        variant,
                        n,
                        EngineKind::LocalDeterministic { feedback_delay: 0 },
                        true,
                        n / 10,
                    )
                })
                .collect();
            rows.push(vec![
                format!("sparse {dim}"),
                variant.to_string(),
                format!("{:.3}", avg(&outs, |o| o.accuracy)),
                format!("{:.2}", avg(&outs, |o| o.wall_s)),
            ]);
        }
    }
    print_table(
        "Fig 3 — VHT local vs MOA (accuracy, time)",
        &["stream", "algorithm", "accuracy", "time (s)"],
        &rows,
    );
    Ok(())
}

/// Variant grid of Figs 4/5.
fn fig45_variants(args: &Args) -> Vec<Variant> {
    let ps = args.usize_list("p", &[2, 4]);
    let mut v = vec![Variant::Local];
    for &p in &ps {
        v.push(Variant::Wok { p });
        v.push(Variant::Wk { p, z: 1 });
        v.push(Variant::Wk { p, z: 10_000 });
        v.push(Variant::Sharding { p });
    }
    v
}

/// Figs 4 (dense) / 5 (sparse): accuracy of local/wok/wk(z)/sharding.
pub fn fig4_5(args: &Args, sparse: bool) -> crate::Result<()> {
    let n = args.u64("instances", 60_000);
    let delay = args.usize("delay", 100);
    let mut rows = Vec::new();
    let configs: Vec<String> = if sparse {
        sparse_dims(args).iter().map(|d| format!("sparse {d}")).collect()
    } else {
        dense_configs(args).iter().map(|c| format!("dense {}-{}", c.0, c.1)).collect()
    };
    for (ci, cname) in configs.iter().enumerate() {
        for variant in fig45_variants(args) {
            let outs: Vec<Outcome> = (0..seeds(args))
                .map(|s| {
                    let mut stream: Box<dyn StreamSource> = if sparse {
                        sparse_stream(sparse_dims(args)[ci], 300 + s)
                    } else {
                        dense_stream(dense_configs(args)[ci], 300 + s)
                    };
                    run_variant(
                        stream.as_mut(),
                        variant,
                        n,
                        EngineKind::LocalDeterministic { feedback_delay: delay },
                        sparse,
                        n / 10,
                    )
                })
                .collect();
            rows.push(vec![
                cname.clone(),
                variant.to_string(),
                format!("{:.3}", avg(&outs, |o| o.accuracy)),
                format!("{:.3}", avg(&outs, |o| o.kappa)),
            ]);
        }
    }
    print_table(
        &format!(
            "Fig {} — accuracy of VHT variants vs sharding ({})",
            if sparse { 5 } else { 4 },
            if sparse { "sparse" } else { "dense" }
        ),
        &["stream", "variant", "accuracy", "kappa"],
        &rows,
    );
    Ok(())
}

/// Figs 6 (dense) / 7 (sparse): accuracy evolution over the stream.
pub fn fig6_7(args: &Args, sparse: bool) -> crate::Result<()> {
    let n = args.u64("instances", 100_000);
    let delay = args.usize("delay", 100);
    let p = args.usize("p", 4);
    let variants = vec![
        Variant::Local,
        Variant::Wok { p },
        Variant::Wk { p, z: 10_000 },
        Variant::Sharding { p },
    ];
    let mut rows = Vec::new();
    for variant in variants {
        let mut stream: Box<dyn StreamSource> = if sparse {
            sparse_stream(1000, 42)
        } else {
            dense_stream((100, 100), 42)
        };
        let out = run_variant(
            stream.as_mut(),
            variant,
            n,
            EngineKind::LocalDeterministic { feedback_delay: delay },
            sparse,
            n / 10,
        );
        for (at, acc) in &out.curve {
            rows.push(vec![variant.to_string(), at.to_string(), format!("{acc:.3}")]);
        }
    }
    print_table(
        &format!(
            "Fig {} — accuracy evolution ({})",
            if sparse { 7 } else { 6 },
            if sparse { "sparse 1k" } else { "dense 100-100" }
        ),
        &["variant", "instances", "cumulative accuracy"],
        &rows,
    );
    Ok(())
}

/// Figs 8 (dense) / 9 (sparse): speedup of VHT wok by parallelism, via
/// the simulated-time engine (see DESIGN.md §3 on the 1-core
/// substitution).
///
/// Faithful setup: per-attribute messages (paper Table 2, no batching),
/// a Storm-like cost model (the paper ran VHT on Storm), a feedback delay
/// so wok's load shedding engages. The speedup baseline is the
/// same-software single-worker run under the same cost model (our rust
/// "MOA" is ~1-2 orders faster than Java MOA, so cross-software ratios —
/// also printed — are not the reproduction target; the *scaling shape*
/// is).
pub fn fig8_9(args: &Args, sparse: bool) -> crate::Result<()> {
    use crate::classifiers::vht::{self, SplitBuffering, VhtConfig};
    use crate::engine::{SimCostModel, SimTimeEngine};
    use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use crate::topology::Event;
    use std::sync::Arc;

    let n = args.u64("instances", 20_000);
    let delay = args.usize("delay", 100);
    let ps = args.usize_list("p", if sparse { &[1, 2, 4, 8, 16] } else { &[1, 2, 4, 8] });
    // optional `--pipeline hash:64,scale,...` preprocessing in front of
    // the VHT topology
    let pipeline = super::validated_pipeline(args)?;
    // Storm-like per-tuple costs (VHT experiments ran on Storm 0.9.3)
    let cost = SimCostModel {
        c_msg_ns: args.f64("cmsg", 2_000.0),
        c_byte_ns: args.f64("cbyte", 2.0),
        tx_frac: args.f64("txfrac", 0.25),
        ..SimCostModel::default()
    };

    let mut rows = Vec::new();
    let configs: Vec<String> = if sparse {
        sparse_dims(args).iter().map(|d| format!("sparse {d}")).collect()
    } else {
        dense_configs(args).iter().map(|c| format!("dense {}-{}", c.0, c.1)).collect()
    };

    let run_sim = |ci: usize, p: usize, delay: usize| -> (f64, u64) {
        let raw: Box<dyn StreamSource> = if sparse {
            sparse_stream(sparse_dims(args)[ci], 400)
        } else {
            dense_stream(dense_configs(args)[ci], 400)
        };
        let mut stream =
            super::maybe_pipeline(raw, pipeline).expect("pipeline spec validated above");
        let config = VhtConfig {
            parallelism: p,
            buffering: SplitBuffering::Discard,
            feedback_delay: delay,
            batch_attributes: false, // per-attribute events, as in Table 2
            sparse,
            ..Default::default()
        };
        let sink = EvalSink::new(stream.schema().n_classes(), 1.0, n);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = vht::build_topology(stream.schema(), &config, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
        let source =
            (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let r = SimTimeEngine::new(cost).run(&topo, handles.entry, source, |_| {});
        (r.throughput(), r.metrics.streams[handles.streams.attribute.0].events)
    };

    for (ci, cname) in configs.iter().enumerate() {
        // cross-software reference: rust sequential tree wall-clock
        let raw: Box<dyn StreamSource> = if sparse {
            sparse_stream(sparse_dims(args)[ci], 400)
        } else {
            dense_stream(dense_configs(args)[ci], 400)
        };
        let mut stream =
            super::maybe_pipeline(raw, pipeline).expect("pipeline spec validated above");
        let moa = run_variant(stream.as_mut(), Variant::Moa, n, EngineKind::Threaded, sparse, n);
        // same-software, same-cost-model baseline: single worker, no delay
        let (base_tput, _) = run_sim(ci, 1, 0);
        for &p in &ps {
            let (tput, attr_events) = run_sim(ci, p, delay);
            rows.push(vec![
                cname.clone(),
                format!("{p}"),
                format!("{:.0}", tput),
                format!("{:.2}x", tput / base_tput.max(1e-9)),
                format!("{:.2}x", tput / moa.throughput.max(1e-9)),
                format!("{}", attr_events),
            ]);
        }
    }
    print_table(
        &format!(
            "Fig {} — VHT wok scaling ({}, simulated p workers; speedup vs 1-worker same-software baseline)",
            if sparse { 9 } else { 8 },
            if sparse { "sparse" } else { "dense" }
        ),
        &["stream", "p", "wok inst/s (sim)", "speedup vs 1w", "vs rust-moa wall", "attr events"],
        &rows,
    );
    Ok(())
}

/// Tables 3 (accuracy) / 4 (time): real-world datasets.
pub fn table3_4(args: &Args, accuracy: bool) -> crate::Result<()> {
    let delay = args.usize("delay", 100);
    let datasets = ["elec", "phy", "covtype"];
    let n_cap = args.u64("instances", 100_000); // covtype twin capped by default
    let variants = vec![
        Variant::Moa,
        Variant::Local,
        Variant::Wok { p: 2 },
        Variant::Wok { p: 4 },
        Variant::Wk { p: 2, z: 1 },
        Variant::Wk { p: 4, z: 1 },
        Variant::Sharding { p: 2 },
        Variant::Sharding { p: 4 },
    ];
    let mut rows = Vec::new();
    for ds in datasets {
        let mut row = vec![ds.to_string()];
        for &variant in &variants {
            let outs: Vec<Outcome> = (0..seeds(args))
                .map(|s| {
                    let mut stream = dataset_stream(ds, 500 + s);
                    run_variant(
                        stream.as_mut(),
                        variant,
                        n_cap,
                        EngineKind::LocalDeterministic { feedback_delay: delay },
                        false,
                        n_cap,
                    )
                })
                .collect();
            row.push(if accuracy {
                format!("{:.1}", 100.0 * avg(&outs, |o| o.accuracy))
            } else {
                format!("{:.2}", avg(&outs, |o| o.wall_s))
            });
        }
        rows.push(row);
    }
    let header: Vec<String> = std::iter::once("dataset".to_string())
        .chain(variants.iter().map(|v| v.to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(
        if accuracy {
            "Table 3 — accuracy (%) on real-world datasets"
        } else {
            "Table 4 — execution time (s) on real-world datasets"
        },
        &header_refs,
        &rows,
    );
    Ok(())
}
