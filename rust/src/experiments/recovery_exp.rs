//! `samoa exp recovery` — price the fault-tolerance layer: checkpoint
//! interval × kill point against accuracy and throughput, on both
//! engines that implement recovery (see the recovery-model section of
//! [`crate::engine`]).
//!
//! Two parts:
//!
//! 1. **Threaded sweep** — the `sync` spec topology (pipeline shards +
//!    StatsSync + Hoeffding tree + evaluator) on [`ThreadedEngine`],
//!    killing one pipeline shard mid-stream via `with_fault` at a grid
//!    of checkpoint intervals × kill points. Each row holds the
//!    recovered run against the no-fault reference: Δn and Δaccuracy
//!    are 0 whenever the replay log covered the whole delta
//!    (`dropped = 0`); a tiny `--replay-cap` makes the loss visible.
//! 2. **Cluster kill** — the `null` spec topology with an injected
//!    worker death (`die=`/`victim=` spec params) on [`ClusterEngine`]:
//!    the victim worker panics mid-run, the coordinator respawns it,
//!    restores the held checkpoint and re-drives the replay log; the
//!    row shows every delivery accounted for. Subprocess mode first,
//!    thread-mode workers as fallback (same protocol, no exec).
//!
//! Knobs: `--n` instances (default 20000), `--p` parallelism (default
//! 2), `--stream` twin (default elec — the sync spec needs a
//! classification stream), `--seed`, `--replay-cap`, `--smoke` one kill
//! per engine for CI, `--peer [det|fast]` kill the worker while
//! worker↔worker links are live: the cluster leg switches to the
//! `relay` spec (whose key-routed hop rides the peer plane — the
//! victim hosts both the peer sender and a sink), and the recovered
//! shard is degraded back to coordinator routing. `--inject N` drives
//! the cluster leg with pipelined injection (the kill then lands with
//! a `FRAME_INJECT` batch in flight, exercising batched replay), and
//! `--tcp` runs the cluster leg over TCP loopback.

use crate::common::cli::Args;
use crate::engine::cluster::{spec, ClusterEngine, PeerMode};
use crate::engine::metrics::EngineMetrics;
use crate::engine::threaded::ThreadedEngine;
use crate::topology::Event;

use super::print_table;

/// Sum the `n`/`correct` pairs every evaluator instance reports — the
/// collect-side twin of `ClusterRun::kv_sum`.
#[derive(Default)]
struct AccTally {
    n: f64,
    correct: f64,
}

impl AccTally {
    fn add(&mut self, proc_: &dyn crate::topology::Processor) {
        for (k, v) in proc_.report() {
            match k {
                "n" => self.n += v,
                "correct" => self.correct += v,
                _ => {}
            }
        }
    }

    fn accuracy(&self) -> f64 {
        if self.n > 0.0 {
            self.correct / self.n
        } else {
            0.0
        }
    }
}

fn source_of(stream: &str, seed: u64, n: u64) -> Box<dyn Iterator<Item = Event>> {
    let mut s = crate::experiments::dataset_stream(stream, seed);
    Box::new((0..n).map_while(move |id| s.next_instance().map(|inst| Event::Instance { id, inst })))
}

fn run_threaded(
    eng: &ThreadedEngine,
    spec_str: &str,
    stream: &str,
    seed: u64,
    n: u64,
) -> crate::Result<(EngineMetrics, AccTally)> {
    let (topo, entry) = spec::build(spec_str)?;
    let mut tally = AccTally::default();
    let m = eng.run(&topo, entry, source_of(stream, seed, n), |_, _, pr| tally.add(pr));
    Ok((m, tally))
}

pub fn recovery(args: &Args) -> crate::Result<()> {
    let smoke = args.flag("smoke");
    let n: u64 = args.u64("n", if smoke { 3_000 } else { 20_000 });
    let p = args.usize("p", 2);
    let stream = args.get_or("stream", "elec").to_string();
    let seed = args.u64("seed", 42);
    let replay_cap = args.usize("replay-cap", 65536);

    // ------------------------------------------- 1. threaded sweep
    // Kill one pipeline shard (pid 0, iid 0); under shuffle it sees
    // about n/p deliveries, so kill points are fractions of that.
    let spec_str = format!("sync:stream={stream}:p={p}:interval=64:seed={seed}");
    let (ref_m, ref_tally) = run_threaded(&ThreadedEngine::default(), &spec_str, &stream, seed, n)?;
    let per_shard = n / p as u64;
    let intervals: &[u64] = if smoke { &[256] } else { &[256, 1024, 4096] };
    let kill_ats: &[u64] = if smoke {
        &[2]
    } else {
        &[4, 2] // divisors of per_shard: kill at 1/4 and 1/2 of the shard's stream
    };
    let mut rows: Vec<Vec<String>> = Vec::new();
    for &interval in intervals {
        for &frac in kill_ats {
            let kill_at = (per_shard / frac).max(1);
            let eng = ThreadedEngine::default()
                .with_checkpoints(interval)
                .with_replay_cap(replay_cap)
                .with_fault(0, 0, kill_at);
            let (m, tally) = run_threaded(&eng, &spec_str, &stream, seed, n)?;
            crate::ensure!(m.recovery.kills == 1, "injected threaded fault did not fire");
            let r = &m.recovery;
            rows.push(vec![
                interval.to_string(),
                kill_at.to_string(),
                r.checkpoints.to_string(),
                r.replayed.to_string(),
                r.replay_dropped.to_string(),
                format!("{:.0}", tally.n),
                format!("{:+.0}", tally.n - ref_tally.n),
                format!("{:.4}", tally.accuracy()),
                format!("{:+.4}", tally.accuracy() - ref_tally.accuracy()),
                format!("{:.0}", m.wall_throughput()),
            ]);
        }
    }
    print_table(
        &format!(
            "threaded recovery sweep (sync topology, {n} inst, p={p}, \
             reference acc {:.4}, {:.0} inst/s)",
            ref_tally.accuracy(),
            ref_m.wall_throughput()
        ),
        &[
            "ckpt every",
            "kill@",
            "ckpts",
            "replayed",
            "dropped",
            "n",
            "Δn",
            "acc",
            "Δacc",
            "inst/s",
        ],
        &rows,
    );

    // ------------------------------------------- 2. cluster kill
    // One worker death per run: sink instance 0 (on worker 0) panics at
    // its `die`th delivery; the coordinator detects the socket failure,
    // respawns the worker and re-drives it from the held checkpoint.
    // Under `--peer` the workload is `relay` (its key-routed hop carries
    // live peer traffic, and worker 0 hosts the peer *sender* too), so
    // the kill exercises the degradation path: outstanding descriptors
    // rerouted from their payloads, markers converted in place, the
    // respawned shard served coordinator-only.
    let peer = PeerMode::parse(args.get("peer"))?;
    let die = (per_shard / 2).max(1);
    let cl_spec = if peer == PeerMode::Off {
        format!("null:p={p}:die={die}:victim=0")
    } else {
        format!("relay:p={p}:die={die}:victim=0")
    };
    let inject = args.usize("inject", 1);
    let intervals: &[u64] = if smoke { &[64] } else { &[64, 256, 1024] };
    let mut rows: Vec<Vec<String>> = Vec::new();
    for &interval in intervals {
        let mut eng = ClusterEngine::new()
            .with_workers(p)
            .with_checkpoints(interval)
            .with_replay_cap(replay_cap)
            .with_inject_window(inject)
            .with_peer(peer);
        if args.flag("tcp") {
            eng = eng.over_tcp();
        }
        let make = || {
            Box::new((0..n).map(|id| Event::Instance {
                id,
                inst: crate::core::instance::Instance::dense(
                    vec![0.25; 8],
                    crate::core::instance::Label::None,
                ),
            })) as Box<dyn Iterator<Item = Event>>
        };
        let (run, mode) = match eng.run_spec(&cl_spec, make()) {
            Ok(run) => (run, "procs"),
            Err(e) => {
                eprintln!(
                    "[recovery] subprocess mode failed for '{cl_spec}' ({e:#}); \
                     falling back to worker threads"
                );
                let (topo, entry) = spec::build(&cl_spec)?;
                (eng.run(&topo, entry, make())?, "threads")
            }
        };
        let r = &run.metrics.recovery;
        crate::ensure!(r.kills == 1, "injected cluster fault did not fire");
        if peer != PeerMode::Off {
            crate::ensure!(
                run.metrics.cluster.peer_frames() > 0,
                "cluster recovery under --peer: no worker↔worker traffic flowed before the kill"
            );
        }
        rows.push(vec![
            interval.to_string(),
            mode.to_string(),
            die.to_string(),
            r.checkpoints.to_string(),
            r.replayed.to_string(),
            r.replay_dropped.to_string(),
            format!("{:.0}", run.kv_sum("seen")),
            n.to_string(),
            format!("{:.0}", run.metrics.wall_throughput()),
        ]);
    }
    let cl_topology =
        if peer == PeerMode::Off { "null topology" } else { "relay topology, peer links" };
    print_table(
        &format!("cluster worker-death recovery ({cl_topology}, {n} inst, {p} workers)"),
        &["ckpt every", "mode", "die@", "ckpts", "replayed", "dropped", "seen", "sent", "inst/s"],
        &rows,
    );
    Ok(())
}
