//! AMRules experiments: Table 5-7 and Figs 12-16 of the paper (§7.3).

use std::sync::Arc;
use std::time::Instant;

use crate::common::cli::Args;
use crate::core::model::Regressor;
use crate::engine::{LocalEngine, SimTimeEngine, ThreadedEngine};
use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use crate::regressors::amrules::{AMRules, AMRulesConfig};
use crate::regressors::{hamr, vamr};
use crate::topology::Event;

use super::{print_table, regression_stream};

const DATASETS: [&str; 3] = ["electricity", "airlines", "waveform"];

fn limit(args: &Args) -> u64 {
    args.u64("instances", 100_000)
}

/// Table 5: rules/features statistics of sequential AMRules (MAMR).
pub fn table5(args: &Args) -> crate::Result<()> {
    let n = limit(args);
    let mut rows = Vec::new();
    for ds in DATASETS {
        let mut stream = regression_stream(ds, 7, n);
        let mut model = AMRules::new(stream.schema().clone(), AMRulesConfig::default());
        let mut count = 0u64;
        while count < n {
            let Some(inst) = stream.next_instance() else { break };
            model.train(&inst);
            count += 1;
        }
        let s = &model.stats;
        rows.push(vec![
            ds.to_string(),
            count.to_string(),
            stream.schema().n_attributes().to_string(),
            s.rules_created.to_string(),
            s.rules_removed.to_string(),
            model.n_rules().to_string(),
            s.features_created.to_string(),
        ]);
    }
    print_table(
        "Table 5 — MAMR rule/feature statistics",
        &[
            "dataset",
            "instances",
            "#attrs",
            "rules created",
            "rules removed",
            "rules live",
            "features created",
        ],
        &rows,
    );
    Ok(())
}

/// Table 6: memory consumption of MAMR.
pub fn table6(args: &Args) -> crate::Result<()> {
    let n = limit(args);
    let mut rows = Vec::new();
    for ds in DATASETS {
        let mut stream = regression_stream(ds, 8, n);
        let mut model = AMRules::new(stream.schema().clone(), AMRulesConfig::default());
        let mut count = 0u64;
        while count < n {
            let Some(inst) = stream.next_instance() else { break };
            model.train(&inst);
            count += 1;
        }
        rows.push(vec![
            ds.to_string(),
            format!("{:.2}", model.model_bytes() as f64 / 1e6),
        ]);
    }
    print_table(
        "Table 6 — MAMR model memory (MB; model state, not JVM heap)",
        &["dataset", "memory (MB)"],
        &rows,
    );
    Ok(())
}

/// Table 7: memory of VAMR's aggregator and learners by parallelism.
pub fn table7(args: &Args) -> crate::Result<()> {
    let n = limit(args);
    let ps = args.usize_list("p", &[1, 2, 4, 8]);
    let mut rows = Vec::new();
    for ds in DATASETS {
        for &p in &ps {
            let mut stream = regression_stream(ds, 9, n);
            let sink = EvalSink::new(0, stream.schema().label_range(), n);
            let sink2 = Arc::clone(&sink);
            let (topo, handles) =
                vamr::build_topology(stream.schema(), &AMRulesConfig::default(), p, move |_| {
                    Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
                });
            let source = (0..n).map_while(|id| {
                stream.next_instance().map(|inst| Event::Instance { id, inst })
            });
            let mut ma_bytes = 0usize;
            let mut learner_bytes = Vec::new();
            LocalEngine::new().run(&topo, handles.entry, source, |inst| {
                ma_bytes = inst[handles.ma.0][0].mem_bytes();
                learner_bytes =
                    inst[handles.learners.0].iter().map(|l| l.mem_bytes()).collect();
            });
            let avg_learner =
                learner_bytes.iter().sum::<usize>() as f64 / learner_bytes.len().max(1) as f64;
            rows.push(vec![
                ds.to_string(),
                p.to_string(),
                format!("{:.2}", ma_bytes as f64 / 1e6),
                format!("{:.2}", avg_learner / 1e6),
            ]);
        }
    }
    print_table(
        "Table 7 — VAMR memory by parallelism (MB; model state)",
        &["dataset", "p", "model aggregator", "avg learner"],
        &rows,
    );
    Ok(())
}

/// One AMRules variant's simulated/wall throughput + errors.
struct AmrOutcome {
    throughput: f64,
    mae: f64,
    rmse: f64,
}

fn run_mamr(ds: &str, n: u64, pipeline: Option<&str>) -> AmrOutcome {
    let mut stream = super::maybe_pipeline(regression_stream(ds, 11, n), pipeline)
        .expect("pipeline spec validated by caller");
    let mut model = AMRules::new(stream.schema().clone(), AMRulesConfig::default());
    let mut measure =
        crate::evaluation::measures::RegressionMeasure::new(stream.schema().label_range(), n);
    let started = Instant::now();
    let mut count = 0u64;
    while count < n {
        let Some(inst) = stream.next_instance() else { break };
        if let Some(y) = inst.numeric_label() {
            measure.add(y, model.predict(&inst));
        }
        model.train(&inst);
        count += 1;
    }
    AmrOutcome {
        throughput: count as f64 / started.elapsed().as_secs_f64().max(1e-9),
        mae: measure.nmae(),
        rmse: measure.nrmse(),
    }
}

/// Run VAMR (r = None) or HAMR (r = Some(replicas)) and report simulated
/// throughput + errors. `p` = learner count (VAMR) / MA count (HAMR, as
/// in Fig. 12's x-axis).
fn run_distributed(
    ds: &str,
    p: usize,
    hamr_learners: Option<usize>,
    n: u64,
    sim: bool,
    pipeline: Option<&str>,
) -> AmrOutcome {
    let mut stream = super::maybe_pipeline(regression_stream(ds, 11, n), pipeline)
        .expect("pipeline spec validated by caller");
    let range = stream.schema().label_range();
    let sink = EvalSink::new(0, range, n);
    let sink2 = Arc::clone(&sink);
    let cfg = AMRulesConfig::default();
    let (topo, entry) = match hamr_learners {
        None => {
            let (t, h) = vamr::build_topology(stream.schema(), &cfg, p, move |_| {
                Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
            });
            (t, h.entry)
        }
        Some(l) => {
            let (t, h) = hamr::build_topology(stream.schema(), &cfg, p, l, move |_| {
                Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
            });
            (t, h.entry)
        }
    };
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let throughput = if sim {
        SimTimeEngine::default().run(&topo, entry, source, |_| {}).throughput()
    } else {
        let started = Instant::now();
        let m = ThreadedEngine::default().run(&topo, entry, source, |_, _, _| {});
        m.source_instances as f64 / started.elapsed().as_secs_f64().max(1e-9)
    };
    let measure = sink.regression.lock().unwrap().clone();
    AmrOutcome { throughput, mae: measure.nmae(), rmse: measure.nrmse() }
}

/// Fig 12: throughput of MAMR / VAMR / HAMR-1 / HAMR-2 by parallelism.
pub fn fig12(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 40_000);
    let pipeline = super::validated_pipeline(args)?;
    let ps = args.usize_list("p", &[1, 2, 4, 8]);
    let mut rows = Vec::new();
    for ds in DATASETS {
        let mamr = run_mamr(ds, n, pipeline);
        rows.push(vec![ds.into(), "MAMR".into(), "-".into(), format!("{:.0}", mamr.throughput)]);
        for &p in &ps {
            let v = run_distributed(ds, p, None, n, true, pipeline);
            let h1 = run_distributed(ds, p, Some(1), n, true, pipeline);
            let h2 = run_distributed(ds, p, Some(2), n, true, pipeline);
            for (name, r) in [("VAMR", v), ("HAMR-1", h1), ("HAMR-2", h2)] {
                rows.push(vec![
                    ds.into(),
                    name.into(),
                    p.to_string(),
                    format!("{:.0}", r.throughput),
                ]);
            }
        }
    }
    print_table(
        "Fig 12 — AMRules throughput (instances/s; distributed = simulated p workers)",
        &["dataset", "variant", "p", "throughput"],
        &rows,
    );
    Ok(())
}

/// Fig 13: max HAMR throughput vs result-message size, with the
/// single-partition reference line from the simtime cost model.
pub fn fig13(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 30_000);
    let pipeline = super::validated_pipeline(args)?;
    let cost = crate::engine::SimCostModel::default();
    let mut rows = Vec::new();
    for ds in DATASETS {
        // measured result-message size = prediction event bytes + label
        let mut stream = super::maybe_pipeline(regression_stream(ds, 13, 1), pipeline)?;
        let inst = stream.next_instance().unwrap();
        let msg_bytes = Event::Instance { id: 0, inst }.wire_bytes() + 24;
        // best throughput over p for HAMR-2
        let mut best = 0f64;
        for p in [1usize, 2, 4, 8] {
            let r = run_distributed(ds, p, Some(2), n, true, pipeline);
            best = best.max(r.throughput);
        }
        // reference line: 1 / per-message cost at this size
        let reference = 1e9 / (cost.c_msg_ns + msg_bytes as f64 * cost.c_byte_ns);
        rows.push(vec![
            ds.to_string(),
            msg_bytes.to_string(),
            format!("{best:.0}"),
            format!("{reference:.0}"),
        ]);
    }
    print_table(
        "Fig 13 — max HAMR throughput vs message size (+ single-partition reference)",
        &["dataset", "msg bytes", "max HAMR inst/s", "reference inst/s"],
        &rows,
    );
    Ok(())
}

/// Figs 14-16: normalized MAE/RMSE of MAMR / VAMR / HAMR per dataset.
pub fn fig14_16(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 60_000);
    let pipeline = super::validated_pipeline(args)?;
    let ps = args.usize_list("p", &[1, 2, 4, 8]);
    let mut rows = Vec::new();
    for ds in DATASETS {
        let mamr = run_mamr(ds, n, pipeline);
        rows.push(vec![
            ds.into(),
            "MAMR".into(),
            "-".into(),
            format!("{:.4}", mamr.mae),
            format!("{:.4}", mamr.rmse),
        ]);
        for &p in &ps {
            let v = run_distributed(ds, p, None, n, false, pipeline);
            rows.push(vec![
                ds.into(),
                "VAMR".into(),
                p.to_string(),
                format!("{:.4}", v.mae),
                format!("{:.4}", v.rmse),
            ]);
            let h = run_distributed(ds, p, Some(2), n, false, pipeline);
            rows.push(vec![
                ds.into(),
                "HAMR-2".into(),
                p.to_string(),
                format!("{:.4}", h.mae),
                format!("{:.4}", h.rmse),
            ]);
        }
    }
    print_table(
        "Figs 14-16 — normalized MAE/RMSE of distributed AMRules",
        &["dataset", "variant", "p", "MAE/range", "RMSE/range"],
        &rows,
    );
    Ok(())
}
