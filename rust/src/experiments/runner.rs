//! Shared experiment runner: executes one classifier variant (the paper's
//! moa / local / wok / wk(z) / sharding) over a stream and reports
//! accuracy, time, throughput, memory and the accuracy-evolution curve.

use std::sync::Arc;
use std::time::Instant;

use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree, LeafPrediction};
use crate::classifiers::sharding::Sharding;
use crate::classifiers::vht::{self, SplitBuffering, VhtConfig};
use crate::core::model::Classifier;
use crate::engine::{LocalEngine, SimTimeEngine, ThreadedEngine};
use crate::evaluation::measures::ClassificationMeasure;
use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use crate::streams::StreamSource;
use crate::topology::Event;

/// The hoeffding-tree variants of §6.3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Variant {
    /// Sequential MOA-style tree.
    Moa,
    /// VHT on the local engine, no feedback delay.
    Local,
    /// VHT wok (discard during splits) with LS parallelism p.
    Wok { p: usize },
    /// VHT wk(z) (buffer + replay) with LS parallelism p.
    Wk { p: usize, z: usize },
    /// Horizontal sharding baseline with p shards.
    Sharding { p: usize },
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Variant::Moa => write!(f, "moa"),
            Variant::Local => write!(f, "local"),
            Variant::Wok { p } => write!(f, "wok p={p}"),
            Variant::Wk { p, z } => write!(f, "wk({z}) p={p}"),
            Variant::Sharding { p } => write!(f, "sharding p={p}"),
        }
    }
}

/// How to execute a distributed variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    /// Deterministic local engine with `feedback_delay` on local-result.
    LocalDeterministic { feedback_delay: usize },
    /// Real threads + queues.
    Threaded,
    /// Instrumented local run + analytic p-worker schedule (scaling
    /// studies on the 1-core testbed; see engine::simtime).
    Sim,
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub variant: String,
    pub accuracy: f64,
    pub kappa: f64,
    pub wall_s: f64,
    /// instances/s — wall-clock for Moa/Local/Threaded, simulated for Sim.
    pub throughput: f64,
    pub model_bytes: usize,
    pub curve: Vec<(u64, f64)>,
    pub shed: u64,
    pub splits: u64,
}

/// Run `variant` over `n` instances of `stream`.
pub fn run_variant(
    stream: &mut dyn StreamSource,
    variant: Variant,
    n: u64,
    engine: EngineKind,
    sparse: bool,
    curve_every: u64,
) -> Outcome {
    match variant {
        Variant::Moa => run_sequential(
            Box::new(HoeffdingTree::new(
                stream.schema().clone(),
                HTConfig {
                    leaf_prediction: LeafPrediction::MajorityClass,
                    sparse,
                    ..Default::default()
                },
            )),
            stream,
            variant,
            n,
            curve_every,
        ),
        Variant::Sharding { p } => run_sequential(
            Box::new(Sharding::new(
                stream.schema().clone(),
                HTConfig {
                    leaf_prediction: LeafPrediction::MajorityClass,
                    sparse,
                    ..Default::default()
                },
                p,
            )),
            stream,
            variant,
            n,
            curve_every,
        ),
        Variant::Local => {
            run_vht(stream, variant, 1, SplitBuffering::Discard, 0, n, engine, sparse, curve_every)
        }
        Variant::Wok { p } => {
            let delay = default_delay(engine);
            let buffering = SplitBuffering::Discard;
            run_vht(stream, variant, p, buffering, delay, n, engine, sparse, curve_every)
        }
        Variant::Wk { p, z } => {
            let delay = default_delay(engine);
            let buffering = SplitBuffering::Buffer(z.max(1));
            run_vht(stream, variant, p, buffering, delay, n, engine, sparse, curve_every)
        }
    }
}

fn default_delay(engine: EngineKind) -> usize {
    match engine {
        EngineKind::LocalDeterministic { feedback_delay } => feedback_delay,
        _ => 0,
    }
}

fn run_sequential(
    mut model: Box<dyn Classifier>,
    stream: &mut dyn StreamSource,
    variant: Variant,
    n: u64,
    curve_every: u64,
) -> Outcome {
    let mut measure = ClassificationMeasure::new(stream.schema().n_classes(), curve_every);
    let started = Instant::now();
    let mut seen = 0;
    while seen < n {
        let Some(inst) = stream.next_instance() else { break };
        if let Some(t) = inst.class() {
            measure.add(t, model.predict(&inst));
        }
        model.train(&inst);
        seen += 1;
    }
    let wall = started.elapsed().as_secs_f64();
    Outcome {
        variant: variant.to_string(),
        accuracy: measure.accuracy(),
        kappa: measure.kappa(),
        wall_s: wall,
        throughput: seen as f64 / wall.max(1e-9),
        model_bytes: model.model_bytes(),
        curve: measure.curve.clone(),
        shed: 0,
        splits: 0,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_vht(
    stream: &mut dyn StreamSource,
    variant: Variant,
    p: usize,
    buffering: SplitBuffering,
    feedback_delay: usize,
    n: u64,
    engine: EngineKind,
    sparse: bool,
    curve_every: u64,
) -> Outcome {
    let config = VhtConfig {
        parallelism: p,
        buffering,
        feedback_delay,
        sparse,
        ..Default::default()
    };
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, curve_every);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = vht::build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });

    // collect source instances up-front so generation cost isn't billed to
    // the topology (the paper's sources are external spouts)
    let mut events = Vec::with_capacity(n as usize);
    for id in 0..n {
        let Some(inst) = stream.next_instance() else { break };
        events.push(Event::Instance { id, inst });
    }

    let mut shed = 0u64;
    let mut splits = 0u64;
    let mut model_bytes = 0usize;
    let started = Instant::now();
    let (wall, throughput) = match engine {
        EngineKind::LocalDeterministic { .. } => {
            let m = LocalEngine::new().run(&topo, handles.entry, events.into_iter(), |inst| {
                model_bytes = inst[1][0].mem_bytes()
                    + inst[2].iter().map(|i| i.mem_bytes()).sum::<usize>();
            });
            let w = started.elapsed().as_secs_f64();
            (w, m.source_instances as f64 / w.max(1e-9))
        }
        EngineKind::Threaded => {
            let m = ThreadedEngine::default().run(
                &topo,
                handles.entry,
                events.into_iter(),
                |_, _, proc_| {
                    model_bytes += proc_.mem_bytes();
                },
            );
            let w = started.elapsed().as_secs_f64();
            (w, m.source_instances as f64 / w.max(1e-9))
        }
        EngineKind::Sim => {
            let sim = SimTimeEngine::default();
            let r = sim.run(&topo, handles.entry, events.into_iter(), |inst| {
                model_bytes = inst[1][0].mem_bytes()
                    + inst[2].iter().map(|i| i.mem_bytes()).sum::<usize>();
            });
            (started.elapsed().as_secs_f64(), r.throughput())
        }
    };
    let _ = (&mut shed, &mut splits);

    let measure = sink.classification.lock().unwrap().clone();
    Outcome {
        variant: variant.to_string(),
        accuracy: measure.accuracy(),
        kappa: measure.kappa(),
        wall_s: wall,
        throughput,
        model_bytes,
        curve: measure.curve.clone(),
        shed,
        splits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streams::random_tree::RandomTreeGenerator;

    #[test]
    fn moa_and_local_agree_on_easy_stream() {
        let mut s1 = RandomTreeGenerator::new(5, 5, 2, 3);
        let moa = run_variant(&mut s1, Variant::Moa, 15_000, EngineKind::Threaded, false, 5_000);
        let mut s2 = RandomTreeGenerator::new(5, 5, 2, 3);
        let local = run_variant(
            &mut s2,
            Variant::Local,
            15_000,
            EngineKind::LocalDeterministic { feedback_delay: 0 },
            false,
            5_000,
        );
        assert!(
            (moa.accuracy - local.accuracy).abs() < 0.06,
            "moa={} local={}",
            moa.accuracy,
            local.accuracy
        );
        assert!(!local.curve.is_empty());
    }

    #[test]
    fn sim_engine_reports_throughput() {
        let mut s = RandomTreeGenerator::new(5, 5, 2, 4);
        let out = run_variant(&mut s, Variant::Wok { p: 4 }, 5_000, EngineKind::Sim, false, 5_000);
        assert!(out.throughput > 0.0);
    }
}
