//! Preprocessing-pipeline experiment: prequential quality & throughput
//! over a preprocessed stream, comparing
//!
//! * the raw stream (no preprocessing baseline),
//! * the standalone [`TransformedStream`] path, and
//! * the topology path ([`crate::preprocess::PipelineProcessor`]) under
//!   the local and threaded engines, across a parallelism sweep with the
//!   stats-sync loop off and on —
//!
//! demonstrating that the two integration styles agree (identical
//! accuracy at parallelism 1), what the pipeline costs, and what the
//! delta-sync protocol buys at `p > 1` (shard-convergent statistics) for
//! both a classifier head (Hoeffding tree) and a regressor head
//! (AMRules), selected by `--learner ht|amrules`.

use std::sync::Arc;
use std::time::Instant;

use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use crate::common::cli::Args;
use crate::core::model::{Classifier, Regressor};
use crate::core::Schema;
use crate::engine::{LocalEngine, ThreadedEngine};
use crate::evaluation::prequential::{
    prequential_run, prequential_run_regression, EvalSink, EvaluatorProcessor, PrequentialConfig,
};
use crate::preprocess::processor::{build_prequential_topology_head, LearnerHead, SyncPolicy};
use crate::preprocess::{parse_pipeline, TransformedStream};
use crate::regressors::amrules::{AMRules, AMRulesConfig};
use crate::streams::StreamSource;
use crate::topology::Event;

use super::print_table;

/// Stream registry for this experiment (generators + dataset twins).
pub fn preprocess_stream(name: &str, seed: u64, dim: u32) -> Box<dyn StreamSource> {
    use crate::streams::*;
    match name {
        "waveform-cls" => Box::new(waveform::WaveformGenerator::classification(seed)),
        "random-tweet" => Box::new(random_tweet::RandomTweetGenerator::new(dim, seed)),
        "random-tree" => Box::new(random_tree::RandomTreeGenerator::new(10, 10, 2, seed)),
        other => super::dataset_stream(other, seed),
    }
}

/// Run the topology path once and report (quality, inst/s, total events).
#[allow(clippy::too_many_arguments)]
fn run_topology(
    stream_name: &str,
    seed: u64,
    dim: u32,
    spec: &str,
    n: u64,
    p: usize,
    sync: Option<SyncPolicy>,
    threaded: bool,
    regression: bool,
) -> (f64, f64, u64) {
    let mut stream = preprocess_stream(stream_name, seed, dim);
    let schema = stream.schema().clone();
    let sink = EvalSink::new(schema.n_classes(), schema.label_range(), n);
    let sink2 = Arc::clone(&sink);
    let spec_owned = spec.to_string();
    let head = if regression {
        LearnerHead::Regressor(Box::new(|s: &Schema| -> Box<dyn Regressor> {
            Box::new(AMRules::new(s.clone(), AMRulesConfig::default()))
        }))
    } else {
        LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
            Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
        }))
    };
    let (topo, handles) = build_prequential_topology_head(
        &schema,
        p,
        sync,
        move |_| parse_pipeline(&spec_owned).expect("validated by caller"),
        head,
        move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
    );
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let started = Instant::now();
    let events = if threaded {
        ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {}).total_events()
    } else {
        LocalEngine::new().run(&topo, handles.entry, source, |_| {}).total_events()
    };
    let wall = started.elapsed().as_secs_f64();
    let quality = if regression { sink.mae() } else { sink.accuracy() };
    (quality, n as f64 / wall.max(1e-9), events)
}

/// `samoa exp preprocess [--stream waveform-cls --pipeline scale,discretize:8
/// --instances 20000 --p 1,2,4 --sync 256 --learner ht|amrules --seed 42]`
pub fn preprocess(args: &Args) -> crate::Result<()> {
    let regression = args.get_or("learner", "ht") == "amrules";
    let stream_name =
        args.get_or("stream", if regression { "waveform" } else { "waveform-cls" });
    let spec = args.get_or("pipeline", "scale,discretize:8");
    parse_pipeline(spec)?; // fail fast on a bad CLI spec
    let n = args.u64("instances", 20_000);
    let ps = args.usize_list("p", &[1, 2, 4]);
    // sync policy spec: a count interval, `drift[:staleness[:delta]]` or
    // `hybrid[:interval[:delta]]`; `0`/`off` disables the sync rows
    let sync = SyncPolicy::parse(args.get_or("sync", "256"))?;
    let seed = args.u64("seed", 42);
    let dim = args.usize("dim", 1000) as u32;
    let quality_col = if regression { "MAE" } else { "accuracy" };

    let mut rows: Vec<Vec<String>> = Vec::new();

    // -- baseline: raw stream, sequential learner
    {
        let mut stream = preprocess_stream(stream_name, seed, dim);
        let schema = stream.schema().clone();
        let cfg = PrequentialConfig { max_instances: n, report_every: n };
        let (quality, tput) = if regression {
            let mut model = AMRules::new(schema, AMRulesConfig::default());
            let r = prequential_run_regression(&mut model, stream.as_mut(), &cfg);
            (r.measure.mae(), r.throughput())
        } else {
            let mut model = HoeffdingTree::new(schema, HTConfig::default());
            let r = prequential_run(&mut model, stream.as_mut(), &cfg);
            (r.final_accuracy(), r.throughput())
        };
        rows.push(vec![
            "raw (no preprocessing)".into(),
            format!("{quality:.4}"),
            format!("{tput:.0}"),
            "-".into(),
        ]);
    }

    // -- standalone TransformedStream, sequential learner
    {
        let stream = preprocess_stream(stream_name, seed, dim);
        let mut ts = TransformedStream::new(stream, parse_pipeline(spec)?);
        let schema = ts.schema().clone();
        let cfg = PrequentialConfig { max_instances: n, report_every: n };
        let (quality, tput) = if regression {
            let mut model = AMRules::new(schema, AMRulesConfig::default());
            let r = prequential_run_regression(&mut model, &mut ts, &cfg);
            (r.measure.mae(), r.throughput())
        } else {
            let mut model = HoeffdingTree::new(schema, HTConfig::default());
            let r = prequential_run(&mut model, &mut ts, &cfg);
            (r.final_accuracy(), r.throughput())
        };
        rows.push(vec![
            "TransformedStream (standalone)".into(),
            format!("{quality:.4}"),
            format!("{tput:.0}"),
            format!("{}B", crate::preprocess::Transform::mem_bytes(ts.pipeline())),
        ]);
    }

    // -- topology path: parallelism sweep, stats-sync off and on
    for &p in &ps {
        let mut syncs = vec![None];
        if sync.is_some() && p > 1 {
            syncs.push(sync);
        }
        for &s in &syncs {
            let (quality, tput, events) =
                run_topology(stream_name, seed, dim, spec, n, p, s, false, regression);
            let label = match s {
                Some(policy) => format!("PipelineProcessor (local, p={p}, sync={policy:?})"),
                None => format!("PipelineProcessor (local, p={p})"),
            };
            rows.push(vec![
                label,
                format!("{quality:.4}"),
                format!("{tput:.0}"),
                format!("{events} events"),
            ]);
        }
    }

    // -- threaded engine (p = 1 keeps arrival order deterministic)
    {
        let (quality, tput, events) =
            run_topology(stream_name, seed, dim, spec, n, 1, None, true, regression);
        rows.push(vec![
            "PipelineProcessor (threaded, p=1)".into(),
            format!("{quality:.4}"),
            format!("{tput:.0}"),
            format!("{events} events"),
        ]);
    }

    print_table(
        &format!(
            "preprocess: {stream_name} | learner = {} | pipeline = {spec} | n = {n}",
            if regression { "amrules" } else { "ht" }
        ),
        &["configuration", quality_col, "inst/s", "pipeline state"],
        &rows,
    );
    println!(
        "note: at p=1 the TransformedStream and PipelineProcessor paths see \
         identical instance order and statistics, so their results match \
         exactly (the preprocess_integration test asserts this). At p>1 \
         each shard learns its own operator statistics unless sync is on: \
         the sync rows emit state deltas per the --sync policy (a count \
         interval, drift[:staleness] for ADWIN-gated emission, or \
         hybrid[:interval]) and converge all shards to the merged global \
         statistics (the stats_sync_integration test pins the p=4 vs p=1 \
         agreement). See `samoa exp sync-cost` for the policy cost study."
    );
    Ok(())
}
