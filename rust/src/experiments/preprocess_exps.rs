//! Preprocessing-pipeline experiment: prequential accuracy & throughput of
//! a Hoeffding tree over a preprocessed stream, comparing
//!
//! * the raw stream (no preprocessing baseline),
//! * the standalone [`TransformedStream`] path, and
//! * the topology path ([`PipelineProcessor`]) under the local and
//!   threaded engines —
//!
//! demonstrating that the two integration styles agree (identical
//! accuracy at parallelism 1) and what the pipeline costs.

use std::sync::Arc;
use std::time::Instant;

use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use crate::common::cli::Args;
use crate::engine::{LocalEngine, ThreadedEngine};
use crate::evaluation::prequential::{
    prequential_run, EvalSink, EvaluatorProcessor, PrequentialConfig,
};
use crate::preprocess::processor::build_prequential_topology;
use crate::preprocess::{parse_pipeline, TransformedStream};
use crate::streams::StreamSource;
use crate::topology::Event;

use super::print_table;

/// Stream registry for this experiment (generators + dataset twins).
pub fn preprocess_stream(name: &str, seed: u64, dim: u32) -> Box<dyn StreamSource> {
    use crate::streams::*;
    match name {
        "waveform-cls" => Box::new(waveform::WaveformGenerator::classification(seed)),
        "random-tweet" => Box::new(random_tweet::RandomTweetGenerator::new(dim, seed)),
        "random-tree" => Box::new(random_tree::RandomTreeGenerator::new(10, 10, 2, seed)),
        other => super::dataset_stream(other, seed),
    }
}

/// `samoa exp preprocess [--stream waveform-cls --pipeline scale,discretize:8
/// --instances 20000 --p 2 --seed 42]`
pub fn preprocess(args: &Args) -> anyhow::Result<()> {
    let stream_name = args.get_or("stream", "waveform-cls");
    let spec = args.get_or("pipeline", "scale,discretize:8");
    let n = args.u64("instances", 20_000);
    // p = 1 keeps stateful operators (running moments) on a single shard,
    // so all four rows are exactly comparable; raise --p to see sharded
    // pipeline statistics (accuracy drifts slightly, throughput scales).
    let p = args.usize("p", 1);
    let seed = args.u64("seed", 42);
    let dim = args.usize("dim", 1000) as u32;

    let mut rows: Vec<Vec<String>> = Vec::new();

    // -- baseline: raw stream, sequential HT
    {
        let mut stream = preprocess_stream(stream_name, seed, dim);
        let schema = stream.schema().clone();
        let mut model = HoeffdingTree::new(schema, HTConfig::default());
        let r = prequential_run(
            &mut model,
            stream.as_mut(),
            &PrequentialConfig { max_instances: n, report_every: n },
        );
        rows.push(vec![
            "raw (no preprocessing)".into(),
            format!("{:.4}", r.final_accuracy()),
            format!("{:.0}", r.throughput()),
            "-".into(),
        ]);
    }

    // -- standalone TransformedStream, sequential HT
    {
        let stream = preprocess_stream(stream_name, seed, dim);
        let mut ts = TransformedStream::new(stream, parse_pipeline(spec)?);
        let schema = ts.schema().clone();
        let mut model = HoeffdingTree::new(schema, HTConfig::default());
        let r = prequential_run(
            &mut model,
            &mut ts,
            &PrequentialConfig { max_instances: n, report_every: n },
        );
        rows.push(vec![
            "TransformedStream + HT".into(),
            format!("{:.4}", r.final_accuracy()),
            format!("{:.0}", r.throughput()),
            format!("{}B", crate::preprocess::Transform::mem_bytes(ts.pipeline())),
        ]);
    }

    // -- topology path, local + threaded engines
    for engine in ["local", "threaded"] {
        let mut stream = preprocess_stream(stream_name, seed, dim);
        let schema = stream.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, n);
        let sink2 = Arc::clone(&sink);
        let spec_owned = spec.to_string();
        let (topo, handles) = build_prequential_topology(
            &schema,
            if engine == "local" { p } else { 1 },
            move |_| parse_pipeline(&spec_owned).expect("validated above"),
            |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let source = (0..n)
            .map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
        let started = Instant::now();
        let events = if engine == "local" {
            LocalEngine::new().run(&topo, handles.entry, source, |_| {}).total_events()
        } else {
            ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {}).total_events()
        };
        let wall = started.elapsed().as_secs_f64();
        rows.push(vec![
            format!("PipelineProcessor ({engine})"),
            format!("{:.4}", sink.accuracy()),
            format!("{:.0}", n as f64 / wall.max(1e-9)),
            format!("{events} events"),
        ]);
    }

    print_table(
        &format!("preprocess: {stream_name} | pipeline = {spec} | n = {n}"),
        &["configuration", "accuracy", "inst/s", "pipeline state"],
        &rows,
    );
    println!(
        "note: at p=1 the TransformedStream and PipelineProcessor paths see \
         identical instance order and statistics, so their accuracies match \
         exactly (the preprocess_integration test asserts this); threaded \
         always runs p=1 to keep arrival order deterministic."
    );
    Ok(())
}
