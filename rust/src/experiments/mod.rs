//! Experiment harness: one entry per table/figure of the paper's
//! evaluation (§6.3 for VHT, §7.3 for AMRules). `samoa exp <id>` prints
//! the same rows/series the paper reports; see DESIGN.md §5 for the
//! experiment index and EXPERIMENTS.md for recorded paper-vs-measured
//! results.
//!
//! Real-dataset experiments use the synthetic twins from
//! [`crate::streams::datasets`] unless the corresponding ARFF file is
//! present under `data/` (see [`dataset_stream`]).

pub mod runner;
pub mod vht_exps;
pub mod amrules_exps;
pub mod preprocess_exps;
pub mod sync_cost;
pub mod flowcontrol;
pub mod cluster_exp;
pub mod recovery_exp;

use crate::common::cli::Args;

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args) -> crate::Result<()> {
    match id {
        "fig3" => vht_exps::fig3(args),
        "fig4" => vht_exps::fig4_5(args, false),
        "fig5" => vht_exps::fig4_5(args, true),
        "fig6" => vht_exps::fig6_7(args, false),
        "fig7" => vht_exps::fig6_7(args, true),
        "fig8" => vht_exps::fig8_9(args, false),
        "fig9" => vht_exps::fig8_9(args, true),
        "table3" => vht_exps::table3_4(args, true),
        "table4" => vht_exps::table3_4(args, false),
        "table5" => amrules_exps::table5(args),
        "table6" => amrules_exps::table6(args),
        "table7" => amrules_exps::table7(args),
        "fig12" => amrules_exps::fig12(args),
        "fig13" => amrules_exps::fig13(args),
        "fig14" | "fig15" | "fig16" => amrules_exps::fig14_16(args),
        "preprocess" => preprocess_exps::preprocess(args),
        "sync-cost" => sync_cost::sync_cost(args),
        "flowcontrol" => flowcontrol::flowcontrol(args),
        "cluster" => cluster_exp::cluster(args),
        "recovery" => recovery_exp::recovery(args),
        "all" => {
            for e in ALL {
                println!("\n================ {e} ================");
                run(e, args)?;
            }
            Ok(())
        }
        other => crate::bail!("unknown experiment '{other}'; available: {ALL:?} / all"),
    }
}

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table3", "table4", "table5",
    "table6", "table7", "fig12", "fig13", "fig14", "preprocess", "sync-cost", "flowcontrol",
    "cluster", "recovery",
];

/// Markdown-ish table printer.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}");
    println!("| {} |", header.join(" | "));
    println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}

/// Parse-check `--pipeline` once (clean CLI error up front) and hand the
/// spec back for per-run wrapping via [`maybe_pipeline`], whose `expect`
/// is then unreachable. Shared by the VHT and AMRules harnesses.
pub fn validated_pipeline(args: &Args) -> crate::Result<Option<&str>> {
    if let Some(spec) = args.get("pipeline") {
        crate::preprocess::parse_pipeline(spec)?;
    }
    Ok(args.get("pipeline"))
}

/// `--pipeline <spec>` support for the VHT / AMRules harnesses: wrap a
/// harness stream in a preprocessing pipeline parsed from the CLI spec
/// (`hash:64,scale,discretize:8,...`). No spec = the stream unchanged.
pub fn maybe_pipeline(
    stream: Box<dyn crate::streams::StreamSource>,
    spec: Option<&str>,
) -> crate::Result<Box<dyn crate::streams::StreamSource>> {
    match spec {
        Some(spec) => Ok(Box::new(crate::preprocess::TransformedStream::new(
            stream,
            crate::preprocess::parse_pipeline(spec)?,
        ))),
        None => Ok(stream),
    }
}

/// Real dataset (from `data/<name>.arff`) or its synthetic twin.
pub fn dataset_stream(name: &str, seed: u64) -> Box<dyn crate::streams::StreamSource> {
    let path = std::path::Path::new("data").join(format!("{name}.arff"));
    if path.exists() {
        match crate::streams::arff::ArffStream::from_file(&path) {
            Ok(s) => {
                eprintln!("[exp] using real dataset {}", path.display());
                return Box::new(s);
            }
            Err(e) => eprintln!("[exp] failed to parse {}: {e}; using twin", path.display()),
        }
    }
    use crate::streams::datasets::*;
    match name {
        "elec" => Box::new(ElecStream::new(seed)),
        "phy" => Box::new(PhyStream::new(seed)),
        "covtype" => Box::new(CovtypeStream::new(seed)),
        "electricity" => Box::new(ElectricityRegStream::new(seed)),
        "airlines" => Box::new(AirlinesStream::new(seed)),
        "waveform" => Box::new(crate::streams::waveform::WaveformGenerator::new(seed)),
        other => panic!("unknown dataset {other}"),
    }
}

/// Regression dataset twin with an instance cap (throughput experiments).
pub fn regression_stream(
    name: &str,
    seed: u64,
    limit: u64,
) -> Box<dyn crate::streams::StreamSource> {
    use crate::streams::datasets::*;
    match name {
        "electricity" => Box::new(ElectricityRegStream::with_limit(seed, limit)),
        "airlines" => Box::new(AirlinesStream::with_limit(seed, limit)),
        "waveform" => Box::new(crate::streams::waveform::WaveformGenerator::new(seed)),
        other => panic!("unknown regression dataset {other}"),
    }
}
