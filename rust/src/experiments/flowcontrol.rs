//! `samoa exp flowcontrol` — the elastic-data-plane study: sweep
//! channel **capacity × batch policy × scheduler** on the threaded
//! engine under a compute-bound stage and report wall throughput next
//! to the flow-control counters (`EngineMetrics::flow`): backpressure
//! stalls and stall time, peak resident queue depth, adaptive
//! grow/shrink steps, work steals, and arena hit rate.
//!
//! What the table shows:
//!
//! * **bounded vs unbounded** — unbounded queues absorb the source
//!   burst into memory (peak queue ≈ input size / p); bounded queues
//!   pin the peak near `capacity × batch` and convert the excess into
//!   producer stalls, at (near) identical throughput: loss-free
//!   elasticity instead of unbounded growth;
//! * **adaptive vs fixed batching** — identical at full rate (the
//!   adaptive edge sits at the cap), while `--trickle` shows the
//!   latency side: adaptive shrinks to per-event sends when idle;
//! * **pinned vs work-stealing** — `p` shards on fewer workers, idle
//!   workers draining hot shards (`steals` column).

use std::time::Instant;

use crate::common::cli::Args;
use crate::engine::ThreadedEngine;
use crate::streams::waveform::WaveformGenerator;
use crate::streams::StreamSource;
use crate::topology::{Ctx, Event, Grouping, Processor, TopologyBuilder};

use super::print_table;

/// Deterministic per-event compute (learner stand-in) — shared with the
/// `engine_throughput` flow-control bench so both measure the same load.
pub struct Burn(pub u64);
impl Processor for Burn {
    fn process(&mut self, _e: Event, _c: &mut Ctx) {
        let mut x = 0u64;
        for i in 0..self.0 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
    }
}

struct FlowOutcome {
    throughput: f64,
    stalls: u64,
    stall_ms: f64,
    peak_queue: u64,
    grows: u64,
    shrinks: u64,
    steals: u64,
    arena_hit: f64,
}

fn run_one(
    capacity: usize,
    adaptive: bool,
    batch: usize,
    workers: Option<usize>,
    p: usize,
    n: u64,
    spin: u64,
) -> FlowOutcome {
    let mut b = TopologyBuilder::new("flowcontrol");
    let w = b.add_processor("burn", p, move |_| Box::new(Burn(spin)));
    let entry = b.stream("in", None, w, Grouping::Key);
    let topo = b.build();

    let mut eng = if capacity == usize::MAX {
        ThreadedEngine::default().unbounded()
    } else {
        ThreadedEngine::new(capacity)
    };
    eng = if adaptive { eng.with_adaptive_batch(batch) } else { eng.with_batch(batch) };
    if let Some(nw) = workers {
        eng = eng.with_workers(nw);
    }

    let mut stream = WaveformGenerator::classification(7);
    let source =
        (0..n).map_while(move |id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let t0 = Instant::now();
    let m = eng.run(&topo, entry, source, |_, _, _| {});
    let wall = t0.elapsed().as_secs_f64().max(1e-12);
    let arena_total = m.flow.arena_reuses + m.flow.arena_allocs;
    FlowOutcome {
        throughput: m.source_instances as f64 / wall,
        stalls: m.flow.backpressure_stalls,
        stall_ms: m.flow.backpressure_stall_ns as f64 / 1e6,
        peak_queue: m.max_peak_queue_events(),
        grows: m.flow.batch_grows,
        shrinks: m.flow.batch_shrinks,
        steals: m.flow.steals,
        arena_hit: m.flow.arena_reuses as f64 / arena_total.max(1) as f64,
    }
}

/// `samoa exp flowcontrol [--instances 60000 --p 4 --spin 2000
/// --capacity 4,64,1024,0 --batch 32 --workers 0,2]`
/// (`--capacity 0` = unbounded; `--workers 0` = pinned)
pub fn flowcontrol(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 60_000);
    let p = args.usize("p", 4);
    let spin = args.u64("spin", 2_000);
    let batch = args.usize("batch", 32);
    let capacities = args.usize_list("capacity", &[4, 64, 1024, 0]);
    let worker_opts = args.usize_list("workers", &[0, 2]);

    let mut rows: Vec<Vec<String>> = Vec::new();
    for &cap_raw in &capacities {
        let capacity = if cap_raw == 0 { usize::MAX } else { cap_raw };
        let cap_label =
            if cap_raw == 0 { "unbounded".to_string() } else { format!("{cap_raw}") };
        for &w_raw in &worker_opts {
            let workers = if w_raw == 0 { None } else { Some(w_raw) };
            let w_label = workers.map_or("pinned".into(), |w: usize| format!("steal:{w}"));
            for adaptive in [false, true] {
                let r = run_one(capacity, adaptive, batch, workers, p, n, spin);
                rows.push(vec![
                    format!(
                        "cap={cap_label} {} {w_label}",
                        if adaptive { "adaptive" } else { "fixed" }
                    ),
                    format!("{:.0}", r.throughput),
                    r.stalls.to_string(),
                    format!("{:.1}", r.stall_ms),
                    r.peak_queue.to_string(),
                    format!("{}/{}", r.grows, r.shrinks),
                    r.steals.to_string(),
                    format!("{:.0}%", r.arena_hit * 100.0),
                ]);
            }
        }
    }

    print_table(
        &format!(
            "flowcontrol: capacity × batch policy × scheduler | waveform-cls n={n} \
             p={p} spin={spin} batch={batch}"
        ),
        &[
            "configuration",
            "inst/s",
            "stalls",
            "stall ms",
            "peak queue (ev)",
            "grow/shrink",
            "steals",
            "arena hit",
        ],
        &rows,
    );
    println!(
        "\nnote: bounded rows pin 'peak queue' near capacity × batch and convert the \
         excess into producer stalls (loss-free backpressure); the unbounded row's peak \
         grows with the input instead. 'steals' counts task quanta run by a non-home \
         worker — the work-stealing scheduler keeping p shards busy on fewer cores."
    );
    Ok(())
}
