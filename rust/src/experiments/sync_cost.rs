//! `samoa exp sync-cost` — the sync-policy cost study: price the
//! stats-sync control traffic of parallel preprocessing pipelines under
//! the simtime cost model (`engine::simtime`, the paper's
//! per-message/per-byte pricing) across **policy × interval ×
//! drift-rate**, charting sync bytes against convergence lag.
//!
//! For every drift rate the study runs a `p = 1` reference (the
//! statistics every shard *should* converge to) and then each sync
//! policy at `p` shards on the same drifting stream
//! ([`crate::streams::drifting::DriftingStream`] over waveform):
//!
//! * **convergence lag** — reference accuracy minus the policy run's
//!   accuracy (how much quality the sync cadence gives up), plus the
//!   cross-shard divergence of the scalers' view means (how far apart
//!   the shards' statistics ended);
//! * **sync cost** — `StatsDelta` + `StatsGlobal` wire bytes and their
//!   share of the simulated communication time.
//!
//! The drift-gated policy's pitch, measured: on a drifting stream it
//! concentrates emissions at the drift points, shipping fewer bytes
//! than a fixed count tight enough to react equally fast.

use std::sync::Arc;

use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use crate::common::cli::Args;
use crate::core::model::Classifier;
use crate::core::Schema;
use crate::engine::simtime::{SimCostModel, SimTimeEngine};
use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use crate::preprocess::processor::{
    build_prequential_topology_head, LearnerHead, PipelineProcessor, SyncPolicy,
};
use crate::preprocess::{Discretizer, Pipeline, StandardScaler};
use crate::streams::drifting::DriftingStream;
use crate::streams::waveform::WaveformGenerator;
use crate::streams::StreamSource;
use crate::topology::Event;

use super::print_table;

struct RunResult {
    accuracy: f64,
    deltas: u64,
    globals: u64,
    sync_bytes: u64,
    /// Mean absolute cross-shard deviation of the scaler view means.
    view_div: f64,
    throughput: f64,
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    policy: Option<SyncPolicy>,
    p: usize,
    n: u64,
    drift_every: u64,
    drift_mag: f64,
    seed: u64,
) -> RunResult {
    let inner = WaveformGenerator::classification(seed);
    let mut stream = DriftingStream::new(inner, drift_every, drift_mag, seed);
    let schema = stream.schema().clone();
    let sink = EvalSink::new(schema.n_classes(), 1.0, n);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_prequential_topology_head(
        &schema,
        p,
        policy,
        |_| Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
        LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
            Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
        })),
        move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
    );
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let mut snaps: Vec<Vec<f64>> = Vec::new();
    let r = SimTimeEngine::default().run(&topo, handles.entry, source, |instances| {
        snaps = instances[handles.pipeline.0]
            .iter()
            .filter_map(|proc_| {
                proc_
                    .as_any()
                    .and_then(|a| a.downcast_ref::<PipelineProcessor>())
                    .and_then(|pp| pp.pipeline().stats_snapshot(0))
            })
            .collect();
    });
    // Moments payload layout: [n × d, mean × d, m2 × d] — compare the
    // shards' view means attribute-wise.
    let view_div = if snaps.len() > 1 {
        let d = snaps[0].len() / 3;
        let mut dev = 0.0;
        for j in 0..d {
            let means: Vec<f64> = snaps.iter().map(|s| s[d + j]).collect();
            let center = means.iter().sum::<f64>() / means.len() as f64;
            dev += means.iter().map(|m| (m - center).abs()).sum::<f64>() / means.len() as f64;
        }
        dev / d as f64
    } else {
        0.0
    };
    let (deltas, globals, sync_bytes) = match (handles.delta, handles.global) {
        (Some(ds), Some(gs)) => (
            r.metrics.streams[ds.0].events,
            r.metrics.streams[gs.0].events,
            r.stream_bytes(ds) + r.stream_bytes(gs),
        ),
        _ => (0, 0, 0),
    };
    RunResult {
        accuracy: sink.accuracy(),
        deltas,
        globals,
        sync_bytes,
        view_div,
        throughput: r.throughput(),
    }
}

/// `samoa exp sync-cost [--instances 12000 --p 4 --drift-every 0,2000
/// --drift-mag 4 --sync 64,256 --staleness 256,1024 --delta 0.002
/// --seed 42]`
pub fn sync_cost(args: &Args) -> crate::Result<()> {
    let n = args.u64("instances", 12_000);
    let p = args.usize("p", 4).max(2);
    let seed = args.u64("seed", 42);
    let drift_mag = args.f64("drift-mag", 4.0);
    let drift_rates = args.usize_list("drift-every", &[0, 2000]);
    let count_intervals = args.usize_list("sync", &[64, 256]);
    let staleness_levels = args.usize_list("staleness", &[256, 1024]);
    let delta = args.f64("delta", 0.002);

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut chart: Vec<(String, u64, f64)> = Vec::new();

    for &drift_every in &drift_rates {
        let drift_every = drift_every as u64;
        let reference = run_one(None, 1, n, drift_every, drift_mag, seed);
        rows.push(vec![
            format!("drift={drift_every} | reference p=1"),
            format!("{:.4}", reference.accuracy),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{:.0}", reference.throughput),
        ]);

        let mut policies: Vec<(String, SyncPolicy)> = Vec::new();
        for &i in &count_intervals {
            policies.push((format!("count:{i}"), SyncPolicy::Count(i as u64)));
        }
        for &s in &staleness_levels {
            let policy = SyncPolicy::Drift { delta, max_staleness: s as u64 };
            policies.push((format!("drift:{s}"), policy));
        }
        if let Some(&i) = count_intervals.first() {
            let policy = SyncPolicy::Hybrid { interval: i as u64, delta };
            policies.push((format!("hybrid:{i}"), policy));
        }

        for (name, policy) in policies {
            let r = run_one(Some(policy), p, n, drift_every, drift_mag, seed);
            let lag = reference.accuracy - r.accuracy;
            rows.push(vec![
                format!("drift={drift_every} | {name} p={p}"),
                format!("{:.4}", r.accuracy),
                format!("{lag:+.4}"),
                format!("{}+{}", r.deltas, r.globals),
                format!("{:.1}KB", r.sync_bytes as f64 / 1024.0),
                format!("{:.4}", r.view_div),
                format!("{:.0}", r.throughput),
            ]);
            chart.push((format!("drift={drift_every} {name}"), r.sync_bytes, lag));
        }
    }

    print_table(
        &format!(
            "sync-cost: policy × interval × drift-rate | waveform-cls n={n} p={p} \
             (simtime cost model: c_msg={:.0}ns c_byte={:.0}ns)",
            SimCostModel::default().c_msg_ns,
            SimCostModel::default().c_byte_ns
        ),
        &[
            "configuration",
            "accuracy",
            "lag vs p=1",
            "deltas+globals",
            "sync bytes",
            "view div",
            "sim inst/s",
        ],
        &rows,
    );

    // ascii chart: sync bytes (bar) vs convergence lag (annotation) —
    // the tradeoff the adaptive policies are supposed to win
    println!("\nsync bytes vs convergence lag:");
    let max_bytes = chart.iter().map(|&(_, b, _)| b).max().unwrap_or(1).max(1);
    for (name, bytes, lag) in &chart {
        let bar = (bytes * 48 / max_bytes) as usize;
        println!(
            "{name:<24} |{:<48}| {:>8.1}KB  lag {lag:+.4}",
            "#".repeat(bar),
            *bytes as f64 / 1024.0
        );
    }
    println!(
        "\nnote: 'lag vs p=1' is the accuracy the sync cadence gives up against \
         a single shard seeing the whole stream; 'view div' is the mean \
         cross-shard deviation of the scaler means at shutdown (0 = shards \
         ended bit-converged). Drift-gated emission concentrates traffic at \
         the drift points: compare its bytes against the count row that \
         reaches the same lag."
    );
    Ok(())
}
