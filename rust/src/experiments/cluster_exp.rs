//! `samoa exp cluster` — the cluster-engine wire-cost study: run real
//! topologies across worker processes (or threads with `--threads`) and
//! measure what the sockets actually charge per frame and per byte,
//! then hold that against the per-message/per-byte prices
//! [`SimCostModel`](crate::engine::simtime::SimCostModel) assumes.
//!
//! Two parts:
//!
//! 1. **Wire-cost sweep** — the `null` spec topology (entry → counting
//!    sinks, no emissions) over a grid of payload sizes. Each run yields
//!    one sample `(frames, socket bytes, coordinator wire ns)`; a
//!    least-squares fit of `ns ≈ c_msg·frames + c_byte·bytes` recovers
//!    the measured per-frame and per-byte costs, printed next to the
//!    cost model's defaults.
//! 2. **Workload rows** — the VHT and StatsSync spec topologies over a
//!    dataset twin, reporting throughput, socket traffic, backpressure
//!    stalls and worker-side accuracy (returned over the wire via
//!    `Processor::report`, exercising the collect phase end-to-end).
//!
//! Caveat printed with the fit: `SimCostModel` prices *logical
//! deliveries* on an idealized DSPE, while this sweep measures the
//! coordinator's socket time (framing included, both directions), so
//! the comparison is a sanity band — same order of magnitude — not a
//! calibration identity.
//!
//! Knobs: `--n` instances (default 20000), `--workers` (default 2),
//! `--window` (default 128), `--inject` source-injection window
//! (default 1; > 1 batches source events into `FRAME_INJECT` frames),
//! `--stream` twin for the workload rows (default elec), `--tcp`
//! loopback TCP instead of Unix sockets, `--threads` worker threads
//! instead of processes, `--smoke` tiny sweep for CI, `--peer
//! [det|fast]` worker↔worker data links (the workload table gains
//! peer-lane columns and a per-link breakdown, and the `relay` row
//! asserts that its key-routed hop left the coordinator's data lane
//! entirely — with `--inject N` it additionally asserts the source
//! events shipped in ≤ ⌈n/N⌉ coordinator round trips).
//!
//! All knobs funnel through one [`EngineConfig`] spec string
//! (`workers=..,window=..,inject=..`), parsed by
//! [`EngineConfig::parse`] — the same surface scripted sweeps use.

use crate::common::cli::Args;
use crate::core::instance::{Instance, Label};
use crate::engine::cluster::{spec, ClusterEngine, ClusterRun, PeerMode};
use crate::engine::EngineConfig;
use crate::engine::simtime::SimCostModel;
use crate::streams::StreamSource;
use crate::topology::Event;

use super::print_table;

/// Run `spec_str`: subprocess mode first (unless `threads`), falling
/// back to thread-mode workers — same protocol, no exec — with a
/// warning if spawning processes is impossible in this environment.
fn run_one(
    eng: &ClusterEngine,
    spec_str: &str,
    threads: bool,
    make_source: &dyn Fn() -> Box<dyn Iterator<Item = Event>>,
) -> crate::Result<(ClusterRun, &'static str)> {
    if !threads {
        match eng.run_spec(spec_str, make_source()) {
            Ok(run) => return Ok((run, "procs")),
            Err(e) => eprintln!(
                "[cluster] subprocess mode failed for '{spec_str}' ({e:#}); \
                 falling back to worker threads"
            ),
        }
    }
    let (topo, entry) = spec::build(spec_str)?;
    Ok((eng.run(&topo, entry, make_source())?, "threads"))
}

/// Least-squares fit of `t ≈ a·f + b·B` over samples `(f, B, t)`.
/// Returns `None` when the grid is degenerate (det ~ 0).
fn fit_two_term(samples: &[(f64, f64, f64)]) -> Option<(f64, f64)> {
    let (mut sff, mut sfb, mut sbb, mut sft, mut sbt) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for &(f, b, t) in samples {
        sff += f * f;
        sfb += f * b;
        sbb += b * b;
        sft += f * t;
        sbt += b * t;
    }
    let det = sff * sbb - sfb * sfb;
    if det.abs() < 1e-6 * sff.max(sbb).max(1.0) {
        return None;
    }
    let a = (sft * sbb - sbt * sfb) / det;
    let b = (sbt * sff - sft * sfb) / det;
    Some((a, b))
}

pub fn cluster(args: &Args) -> crate::Result<()> {
    let smoke = args.flag("smoke");
    let n: u64 = args.u64("n", if smoke { 4_000 } else { 20_000 });
    let workers = args.usize("workers", 2);
    let window = args.usize("window", 128);
    let inject = args.usize("inject", 1);
    let stream_name = args.get_or("stream", "elec").to_string();
    let threads = args.flag("threads");
    let peer = PeerMode::parse(args.get("peer"))?;
    // Exercise the unified config surface end-to-end: compose the CLI
    // knobs into one spec string and parse it back, exactly as a
    // scripted sweep would.
    let mut cfg_spec = format!("workers={workers},window={window},inject={inject}");
    if args.flag("tcp") {
        cfg_spec.push_str(",tcp");
    }
    let cfg = EngineConfig::parse(&cfg_spec)?.with_peer(peer);
    let eng = ClusterEngine::from_config(&cfg);

    // ---------------------------------------------- 1. wire-cost sweep
    let dims: &[usize] = if smoke { &[0, 64] } else { &[0, 16, 64, 256, 1024] };
    let mut samples: Vec<(f64, f64, f64)> = Vec::new();
    let mut rows: Vec<Vec<String>> = Vec::new();
    let spec_str = format!("null:p={workers}");
    for &d in dims {
        let make = move || -> Box<dyn Iterator<Item = Event>> {
            Box::new((0..n).map(move |id| Event::Instance {
                id,
                inst: Instance::dense(vec![0.25; d], Label::None),
            }))
        };
        let (run, mode) = run_one(&eng, &spec_str, threads, &make)?;
        let seen = run.kv_sum("seen");
        crate::ensure!(
            seen == n as f64,
            "cluster null sweep: sinks saw {seen} of {n} instances"
        );
        let c = &run.metrics.cluster;
        let frames = c.total_frames() as f64;
        let bytes = c.total_bytes() as f64;
        let wire_ns = (c.tx_ns + c.rx_ns) as f64;
        samples.push((frames, bytes, wire_ns));
        rows.push(vec![
            d.to_string(),
            mode.to_string(),
            format!("{frames:.0}"),
            format!("{:.1}", bytes / 1024.0),
            format!("{:.1}", wire_ns / 1e6),
            format!("{:.0}", wire_ns / frames.max(1.0)),
            format!("{:.0}", run.metrics.wall_throughput()),
        ]);
    }
    print_table(
        &format!("cluster wire-cost sweep (null topology, {n} inst, {workers} workers)"),
        &["payload f32s", "mode", "frames", "socket KB", "wire ms", "ns/frame", "inst/s"],
        &rows,
    );

    let model = SimCostModel::default();
    match fit_two_term(&samples) {
        Some((c_msg, c_byte)) => {
            print_table(
                "measured wire cost vs SimCostModel (sanity band, not a calibration identity)",
                &["coefficient", "measured", "model", "ratio"],
                &[
                    vec![
                        "c_msg_ns (per frame)".into(),
                        format!("{c_msg:.0}"),
                        format!("{:.0}", model.c_msg_ns),
                        format!("{:.2}x", c_msg / model.c_msg_ns),
                    ],
                    vec![
                        "c_byte_ns (per byte)".into(),
                        format!("{c_byte:.2}"),
                        format!("{:.2}", model.c_byte_ns),
                        format!("{:.2}x", c_byte / model.c_byte_ns),
                    ],
                ],
            );
        }
        None => println!("\n(fit degenerate — widen the payload grid for a cost estimate)"),
    }

    // ------------------------------------------------ 2. workload rows
    let seed = args.u64("seed", 42);
    let specs = [
        format!("relay:p={workers}"),
        format!("vht:stream={stream_name}:p={workers}:seed={seed}"),
        format!("sync:stream={stream_name}:p={workers}:interval=64:seed={seed}"),
    ];
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut link_rows: Vec<Vec<String>> = Vec::new();
    for spec_str in &specs {
        let relay = spec_str.starts_with("relay");
        let name = stream_name.clone();
        let make = move || -> Box<dyn Iterator<Item = Event>> {
            if relay {
                return Box::new((0..n).map(move |id| Event::Instance {
                    id,
                    inst: Instance::dense(vec![0.25; 8], Label::None),
                }));
            }
            let mut s = crate::experiments::dataset_stream(&name, seed);
            Box::new(
                (0..n).map_while(move |id| {
                    s.next_instance().map(|inst| Event::Instance { id, inst })
                }),
            )
        };
        let (run, mode) = run_one(&eng, spec_str, threads, &make)?;
        let c = &run.metrics.cluster;
        if relay {
            let seen = run.kv_sum("seen");
            crate::ensure!(
                seen == n as f64,
                "cluster relay: sinks saw {seen} of {n} instances"
            );
            if peer != PeerMode::Off {
                // The acceptance probe for the peer plane: relay's only
                // data-lane traffic is the source injection itself; every
                // key-routed fwd→sink delivery ships worker→worker, and
                // the per-link counters must be populated.
                crate::ensure!(
                    c.peer_frames() == n && !c.peer_links.is_empty(),
                    "cluster relay under --peer: key-routed deliveries must bypass the \
                     coordinator (data frames {}, peer frames {})",
                    c.data_frames,
                    c.peer_frames()
                );
                if inject <= 1 {
                    crate::ensure!(
                        c.data_frames == n,
                        "cluster relay under --peer: expected one data frame per source \
                         event, got {}",
                        c.data_frames
                    );
                } else {
                    // Pipelined injection: all n source events target fwd
                    // instance 0, so they coalesce into windowed batches —
                    // at most ⌈n/inject⌉ coordinator data round trips.
                    crate::ensure!(
                        c.data_frames <= n.div_ceil(inject as u64)
                            && run.metrics.flow.inject_frames > 0,
                        "cluster relay under --peer --inject {inject}: expected ≤ {} \
                         batched data frames, got {} ({} inject frames)",
                        n.div_ceil(inject as u64),
                        c.data_frames,
                        run.metrics.flow.inject_frames
                    );
                }
            }
            for l in &c.peer_links {
                link_rows.push(vec![
                    format!("w{} -> w{}", l.from, l.to),
                    l.frames.to_string(),
                    format!("{:.1}", l.bytes as f64 / 1024.0),
                    format!("{:.1}", l.wire_bytes as f64 / 1024.0),
                    l.stalls.to_string(),
                ]);
            }
        }
        let evald = run.kv_sum("n");
        let acc = if evald > 0.0 {
            format!("{:.4}", run.kv_sum("correct") / evald)
        } else {
            "-".into()
        };
        rows.push(vec![
            spec_str.clone(),
            mode.to_string(),
            format!("{:.2}", run.metrics.wall_ns as f64 / 1e9),
            format!("{:.0}", run.metrics.wall_throughput()),
            format!("{:.2}", c.total_bytes() as f64 / (1024.0 * 1024.0)),
            c.total_frames().to_string(),
            c.data_frames.to_string(),
            c.peer_frames().to_string(),
            format!("{:.1}", c.peer_bytes() as f64 / 1024.0),
            format!(
                "{}+{}",
                run.metrics.flow.backpressure_stalls, run.metrics.flow.peer_link_stalls
            ),
            acc,
        ]);
    }
    print_table(
        &format!(
            "cluster workloads ({n} inst, {workers} workers, window {window}, \
             inject {inject}, peer {peer:?})"
        ),
        &[
            "spec",
            "mode",
            "wall s",
            "inst/s",
            "socket MB",
            "frames",
            "coord data",
            "peer frames",
            "peer KB",
            "stalls+link",
            "accuracy",
        ],
        &rows,
    );
    if !link_rows.is_empty() {
        print_table(
            "peer links (relay workload)",
            &["link", "frames", "socket KB", "wire KB", "stalls"],
            &link_rows,
        );
    }
    Ok(())
}
