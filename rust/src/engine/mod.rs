//! Execution engines — the DSPE-adapter layer of the paper (§3).
//!
//! Three engines run the same [`crate::topology::Topology`]:
//!
//! * [`local`] — sequential, deterministic, in-process; the analogue of
//!   SAMOA's local execution engine ("VHT local" in the paper). Supports
//!   per-stream delivery *delay* to model the MA↔LS feedback latency of a
//!   distributed deployment deterministically.
//! * [`threaded`] — one OS thread per processor instance, bounded
//!   channels, real backpressure; the analogue of the Storm/Samza
//!   adapters.
//! * [`simtime`] — runs locally while metering per-instance compute cost
//!   and per-stream message volume, then evaluates an analytic p-worker
//!   schedule. This is how scaling figures are produced on this 1-core
//!   testbed (DESIGN.md §3, "substitutions").
//!
//! # Data-plane contract (all three engines)
//!
//! * **Clone-free broadcast**: `All`-grouped routing clones the event
//!   `p − 1` times and *moves* it to the last destination; since every
//!   event payload is Arc-shared (see [`crate::topology`]), a broadcast
//!   performs no heap allocation regardless of payload size. The
//!   `deep_copy_broadcast` knob on [`LocalEngine`]/[`ThreadedEngine`]
//!   restores the pre-refactor deep copies — bench baseline only.
//! * **Micro-batched channels** (threaded only): senders buffer data
//!   events per (sender, destination-instance) edge and flush on
//!   `batch_size`, on input quiesce, and at shutdown; control events
//!   bypass batching. Per-edge FIFO order is preserved at every batch
//!   size (`tests/golden_equivalence.rs` pins this), and `batch_size = 1`
//!   reproduces the unbatched engine.
//! * **Metrics**: `EngineMetrics` counts events *and* bytes per logical
//!   delivery on every engine (a `p`-way broadcast records `p` events and
//!   `p × wire_bytes`) — the quantity the paper's cost model and the
//!   simtime pricer consume. Batching and Arc-sharing change neither.

pub mod metrics;
pub mod local;
pub mod threaded;
pub mod simtime;

pub use local::LocalEngine;
pub use metrics::EngineMetrics;
pub use simtime::{SimCostModel, SimTimeEngine};
pub use threaded::ThreadedEngine;
