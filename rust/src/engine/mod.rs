//! Execution engines — the DSPE-adapter layer of the paper (§3).
//!
//! Three engines run the same [`crate::topology::Topology`]:
//!
//! * [`local`] — sequential, deterministic, in-process; the analogue of
//!   SAMOA's local execution engine ("VHT local" in the paper). Supports
//!   per-stream delivery *delay* to model the MA↔LS feedback latency of a
//!   distributed deployment deterministically.
//! * [`threaded`] — one OS thread per processor instance, bounded
//!   channels, real backpressure; the analogue of the Storm/Samza
//!   adapters.
//! * [`simtime`] — runs locally while metering per-instance compute cost
//!   and per-stream message volume, then evaluates an analytic p-worker
//!   schedule. This is how scaling figures are produced on this 1-core
//!   testbed (DESIGN.md §3, "substitutions").

pub mod metrics;
pub mod local;
pub mod threaded;
pub mod simtime;

pub use local::LocalEngine;
pub use metrics::EngineMetrics;
pub use simtime::{SimCostModel, SimTimeEngine};
pub use threaded::ThreadedEngine;
