//! Execution engines — the DSPE-adapter layer of the paper (§3).
//!
//! Four engines run the same [`crate::topology::Topology`]:
//!
//! * [`local`] — sequential, deterministic, in-process; the analogue of
//!   SAMOA's local execution engine ("VHT local" in the paper). Supports
//!   per-stream delivery *delay* to model the MA↔LS feedback latency of a
//!   distributed deployment deterministically.
//! * [`threaded`] — one OS thread per processor instance, bounded
//!   channels, real backpressure; the analogue of the Storm/Samza
//!   adapters.
//! * [`cluster`] — shards processor instances across OS *processes*
//!   connected by sockets, serializing every delivery through the
//!   [`crate::topology::codec`] wire format; the analogue of a real
//!   multi-node DSPE deployment.
//! * [`simtime`] — runs locally while metering per-instance compute cost
//!   and per-stream message volume, then evaluates an analytic p-worker
//!   schedule. This is how scaling figures are produced on this 1-core
//!   testbed (DESIGN.md §3, "substitutions").
//!
//! # Choosing an engine
//!
//! | engine | parallelism | determinism | what it measures |
//! |---|---|---|---|
//! | [`LocalEngine`] | none (sequential) | bit-exact, the golden reference | logical events/bytes per stream |
//! | [`ThreadedEngine`] | shared-memory threads | per-edge FIFO; totals match local | real wall time, backpressure, steals |
//! | [`ClusterEngine`] | OS processes over sockets | global order matches local (coordinator-sequenced) | real serialization + socket bytes/time |
//! | [`ClusterEngine`] + `with_peer` | OS processes, worker↔worker data links | deterministic mode: bit-identical to local; fast mode: per-link FIFO, totals match | peer-lane frames/bytes/stalls per link |
//! | [`SimTimeEngine`] | analytic p-worker schedule | inherits local | predicted makespan from a cost model |
//!
//! Rules of thumb: start on [`LocalEngine`] (every test pins against
//! it); use [`ThreadedEngine`] to exercise concurrency and flow control
//! on one machine; use [`ClusterEngine`] when the question involves the
//! *wire* — serialization cost, socket throughput, per-process memory
//! isolation — or to validate [`SimCostModel`]'s `c_msg_ns`/`c_byte_ns`
//! against measured socket time (`samoa exp cluster`); use
//! [`SimTimeEngine`] to extrapolate to worker counts the testbed does
//! not have. By default the cluster engine routes every event through
//! the coordinator, so it is a *fidelity* engine, not a speedup engine:
//! its value is that totals stay bit-identical to local while the bytes
//! and nanoseconds in [`metrics::ClusterMetrics`] are real.
//! [`ClusterEngine::with_peer`] adds the peer data plane: eligible data
//! deliveries (undelayed, key-routable) ship on direct worker↔worker
//! sockets and only a small descriptor rides the reply lane, while the
//! coordinator keeps global sequencing, control events, source
//! injection and the quiescence barriers. [`cluster::PeerMode`]
//! `::Deterministic` (the default for `--peer`) pins the receiver-side
//! merge to coordinator-issued slot tokens, keeping runs bit-identical
//! to [`LocalEngine`]; `::Fast` drops the tokens and guarantees only
//! per-link FIFO plus conserved per-stream totals. Per-link traffic and
//! window stalls land in [`metrics::PeerLinkMetrics`].
//!
//! Two injection/routing refinements ride on top of the peer plane:
//!
//! * **Pipelined source injection** (`with_inject_window(w)`, local +
//!   cluster): up to `w` source events are injected between quiescence
//!   barriers instead of one. On [`LocalEngine`] this only coarsens the
//!   drain cadence (the golden reference for the same `w`); on
//!   [`ClusterEngine`] the coordinator additionally coalesces each
//!   batch's same-worker runs into single `FRAME_INJECT` wire frames, so
//!   coordinator data round trips drop from `n` to as low as `n / w`
//!   while every injected delivery still holds one unit of the
//!   destination worker's credit window. Frame/event counts land in
//!   [`metrics::FlowControlMetrics`] (`inject_frames`/`inject_events`).
//!   `w = 1` (the default) is the classic per-event pump and is
//!   bit-identical to runs that never heard of the knob.
//! * **Peer-routed Shuffle streams**: a Shuffle-grouped stream with
//!   destination parallelism > 1 is peer-eligible when its emitting
//!   processor has parallelism 1 (the sole emitter's local round-robin
//!   cursor *is* the global cursor). The Routes frame seeds each
//!   worker's cursor and flags eligibility; workers then advance their
//!   seeded cursors identically to the coordinator's mirror, so
//!   deterministic mode stays bit-identical to [`LocalEngine`] while
//!   shuffle traffic flows worker↔worker. Multi-emitter shuffles keep
//!   the coordinator detour (their global cursor is inherently
//!   coordinator state).
//!
//! # One configuration surface: [`EngineConfig`]
//!
//! All of the knobs above — and the threaded/recovery ones below — live
//! on one builder, [`config::EngineConfig`], which every engine accepts
//! via `from_config` (each engine reads the fields it understands and
//! ignores the rest; see the ownership table in [`config`]):
//!
//! ```no_run
//! use samoa::engine::{ClusterEngine, EngineConfig, ThreadedEngine};
//! let cfg = EngineConfig::new().with_workers(4).with_inject_window(32);
//! let clustered = ClusterEngine::from_config(&cfg);
//! let threaded = ThreadedEngine::from_config(&cfg);
//! ```
//!
//! [`EngineConfig::parse`] accepts the same surface as a comma-separated
//! spec string (`"workers=4,window=256,inject=32,peer=det,tcp"`) for the
//! CLI path. The historical per-engine `with_*` methods survive as thin
//! wrappers over the same fields.
//!
//! # Criterion kernel backend (orthogonal to engine choice)
//!
//! Whatever engine runs the topology, the numeric hot loops inside the
//! processors — VHT split gain, AMRules SDR, CluStream assignment — go
//! through [`crate::runtime`]'s batch entry points, which pick one
//! backend per process:
//!
//! | backend | selected when |
//! |---|---|
//! | `native` | `SAMOA_BACKEND=native`, or the probe finds SIMD not worth it |
//! | `simd` | `SAMOA_BACKEND=simd`, or it wins the one-shot micro-probe under `auto` |
//! | `xla` | `SAMOA_BACKEND=xla` with PJRT bindings + compiled artifacts present |
//!
//! The choice latches on first use and is engine-independent: every
//! worker of a [`ClusterEngine`] run probes once in its own process and
//! all backends agree to ≤ 1e-9 relative (winners bit-match), so golden
//! equivalence across engines is unaffected. See [`crate::runtime`] for
//! the full decision table and fallback rules.
//!
//! # Data-plane contract (all three engines)
//!
//! * **Clone-free broadcast**: `All`-grouped routing clones the event
//!   `p − 1` times and *moves* it to the last destination; since every
//!   event payload is Arc-shared (see [`crate::topology`]), a broadcast
//!   performs no heap allocation regardless of payload size. The
//!   `deep_copy_broadcast` knob on [`LocalEngine`]/[`ThreadedEngine`]
//!   restores the pre-refactor deep copies — bench baseline only.
//! * **Micro-batched channels** (threaded only): senders buffer data
//!   events per (sender, destination-instance) edge and flush on
//!   `batch_size`, on input quiesce, and at shutdown; control events
//!   bypass batching. Per-edge FIFO order is preserved at every batch
//!   size (`tests/golden_equivalence.rs` pins this), and `batch_size = 1`
//!   reproduces the unbatched engine.
//! * **Metrics**: `EngineMetrics` counts events *and* bytes per logical
//!   delivery on every engine (a `p`-way broadcast records `p` events and
//!   `p × wire_bytes`) — the quantity the paper's cost model and the
//!   simtime pricer consume. Batching and Arc-sharing change neither.
//!
//! # Flow control (threaded engine)
//!
//! The threaded data plane is *elastic and loss-free under sustained
//! overload* — the property that lets real DSPEs survive load beyond
//! one machine's memory (Kourtellis et al. 2018; Benczúr et al. on
//! bounded-memory online learning). The knobs, all on
//! [`ThreadedEngine`] (accepted as no-ops by [`LocalEngine`] for
//! configuration parity):
//!
//! * `queue_capacity` — bound of each data channel in batches. A full
//!   channel blocks the producer (one-thread-per-instance mode) or
//!   parks the batch and pauses that sender's input consumption
//!   (work-stealing mode); either way pressure propagates hop by hop
//!   back to the source and resident state stays near
//!   `queue_capacity × batch_size` events per instance, asserted by
//!   `tests/engine_properties.rs`. `unbounded()` removes the bound
//!   (bench baseline: queues then grow with input size).
//! * `batch_size` + `adaptive_batch` — per-edge micro-batch sizing.
//!   Adaptive edges double toward the cap on size-triggered flushes
//!   (hot edge → throughput) and halve toward 1 on idle flushes (cold
//!   edge → latency); `with_batch(n)` pins the size. Batch buffers are
//!   recycled through a [`crate::topology::BatchArena`], so steady-state
//!   batching is allocation-free.
//! * `with_workers(n)` — work-stealing scheduler: `n` OS threads run
//!   all processor instances as lockable tasks (a `p = 8` topology on 4
//!   cores), stealing whichever has queued work. Per-edge FIFO and all
//!   golden outputs are preserved (a task runs on one worker at a
//!   time).
//!
//! **Deadlock freedom** rests on the split control plane: control
//! events ride unbounded priority channels, so feedback loops (VHT's
//! `compute`/`local-result`, the `StatsSync` delta/global rounds) can
//! always make progress no matter how congested the data plane is, and
//! shutdown is staged (per-processor `Shutdown` + quiescence wait,
//! then `Halt`) so shutdown emissions drain deterministically through
//! the bounded channels. Data-plane *cycles* are the one unsupported
//! shape — as on real DSPEs, a data cycle under sustained overload has
//! no finite-memory resolution; route feedback as control events.
//!
//! **Observability/pricing**: stalls, stall time, batch grow/shrink
//! steps, steals and per-instance peak queue depth land in
//! [`EngineMetrics`] (`flow`, `per_instance[..].peak_queue_events`);
//! [`SimCostModel::c_stall_ns`] prices recorded stalls into the simtime
//! makespan (a credit round-trip on a real DSPE).
//!
//! # Recovery model (threaded + cluster engines)
//!
//! SAMOA assumes the underlying SPE recovers failed operators; our
//! engines implement that contract themselves via [`checkpoint`]:
//!
//! | engine | failure unit | detection | recovery path |
//! |---|---|---|---|
//! | [`ThreadedEngine`] | one task (processor instance) | fault injection (`with_fault`) | in-thread respawn + restore + replay |
//! | [`ClusterEngine`] | one worker (process/thread) | socket error mid-run, exit status at spawn | respawn worker, `Restore` frames, re-drive log |
//! | [`ClusterEngine`] + `with_peer` | one worker, peer links attached | same | as above, plus: outstanding peer descriptors re-routed from their logged payloads, queued peer deliveries converted to coordinator routing *in place* (global order preserved), `PeerDown` broadcast, and the respawned worker served coordinator-only for the rest of the run |
//!
//! * **Checkpoints** — with `with_checkpoints(every)` the engine
//!   captures each instance's [`Processor::snapshot`] every `every`
//!   source events, at a quiescent cut (the threaded engine snapshots a
//!   task between deliveries; the cluster coordinator runs a snapshot
//!   round at its source-loop quiescence barrier). Frames use the
//!   [`checkpoint`] format: tagged f64 sections, sparse-compressed,
//!   bounds-checked on decode.
//! * **Replay** — each checkpoint clears a bounded per-instance replay
//!   log (`with_replay_cap`); events delivered since the last
//!   checkpoint are re-applied to the restored instance with emissions
//!   *suppressed* (downstream already saw them — replaying them would
//!   double-count). Recovery is bit-identical whenever the log covered
//!   the whole delta; evictions are counted in
//!   [`metrics::RecoveryMetrics::replay_dropped`] and make the run
//!   approximate (the documented replay tolerance). Pipelined injection
//!   changes nothing here: the coordinator logs every delivery inside a
//!   `FRAME_INJECT` batch individually (marked replied together when the
//!   batch reply lands), and recovery re-drives survivors as ordinary
//!   per-event deliveries — replayed-batch accounting is exact.
//! * **Counters** — checkpoints/bytes/kills/restores/replayed/dropped
//!   land in `EngineMetrics::recovery`; `samoa exp recovery` prices
//!   checkpoint interval × kill rate against accuracy and throughput.
//!
//! [`LocalEngine`]/[`SimTimeEngine`] stay checkpoint-free: they are
//! deterministic single-threaded references with nothing to kill.
//!
//! [`Processor::snapshot`]: crate::topology::processor::Processor::snapshot

pub mod metrics;
pub mod checkpoint;
pub mod config;
pub mod local;
pub mod threaded;
pub mod cluster;
pub mod simtime;

pub use checkpoint::CheckpointStore;
pub use cluster::{ClusterEngine, ClusterRun, InstanceReport, PeerMode};
pub use config::EngineConfig;
pub use local::LocalEngine;
pub use metrics::EngineMetrics;
pub use simtime::{SimCostModel, SimTimeEngine};
pub use threaded::ThreadedEngine;
