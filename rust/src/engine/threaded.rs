//! Threaded engine: one OS thread per processor instance, bounded
//! channels, real backpressure — the in-process analogue of the paper's
//! Storm/Samza adapters.
//!
//! Design notes:
//! * Every processor instance owns a `Receiver<Delivery>`; a shared
//!   routing table of `Sender`s lets any instance emit to any stream.
//! * **Backpressure**: data-plane sends use `SyncSender::send` on a
//!   bounded channel and block when the consumer lags — the Storm
//!   max-spout-pending analogue.
//! * **Deadlock avoidance on feedback loops** (MA→LS→MA): control events
//!   (`Event::is_control`) are routed through a second, *unbounded*
//!   channel per instance, drained with priority. A full data channel can
//!   therefore never wedge the split-decision loop — same reasoning as
//!   Storm's separate system stream.
//! * **Shutdown**: when the source is exhausted the engine waits for
//!   global quiescence (sent == processed, all queues empty), then
//!   broadcasts `Shutdown` and joins.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::topology::builder::Topology;
use crate::topology::processor::Ctx;
use crate::topology::stream::Route;
use crate::topology::{Event, StreamId};

use super::metrics::EngineMetrics;

/// Per-delivery envelope. `stream` kept for metrics.
struct Delivery {
    stream: usize,
    event: Event,
}

struct Mailbox {
    data: SyncSender<Delivery>,
    ctrl: Sender<Delivery>,
}

/// Shared counters for quiescence detection.
struct Flow {
    sent: AtomicU64,
    processed: AtomicU64,
}

/// Multi-threaded engine.
pub struct ThreadedEngine {
    /// Bound of each data channel (Storm max-pending analogue).
    pub queue_capacity: usize,
}

impl Default for ThreadedEngine {
    fn default() -> Self {
        ThreadedEngine { queue_capacity: 1024 }
    }
}

/// Routing state shared by all worker threads.
struct Router {
    topology_streams: Vec<(usize, crate::topology::Grouping)>, // (dest processor, grouping)
    mailboxes: Vec<Vec<Mailbox>>,                              // [processor][instance]
    rr: Vec<AtomicU64>,                                        // per-stream shuffle cursor
    stream_events: Vec<AtomicU64>,
    stream_bytes: Vec<AtomicU64>,
    flow: Flow,
}

impl Router {
    fn route(&self, stream: StreamId, key: u64, event: Event) {
        let (dest, grouping) = self.topology_streams[stream.0];
        let par = self.mailboxes[dest].len();
        let bytes = event.wire_bytes() as u64;
        self.stream_bytes.get(stream.0).map(|b| b.fetch_add(bytes, Ordering::Relaxed));

        let send_one = |i: usize, ev: Event| {
            self.flow.sent.fetch_add(1, Ordering::SeqCst);
            self.stream_events[stream.0].fetch_add(1, Ordering::Relaxed);
            let mb = &self.mailboxes[dest][i];
            if ev.is_control() {
                let _ = mb.ctrl.send(Delivery { stream: stream.0, event: ev });
            } else {
                // blocking send = backpressure
                let _ = mb.data.send(Delivery { stream: stream.0, event: ev });
            }
        };

        let mut rr_cursor = self.rr[stream.0].fetch_add(1, Ordering::Relaxed) as usize;
        match grouping.route(key, par, &mut rr_cursor) {
            Route::One(i) => send_one(i, event),
            Route::All => {
                for i in 0..par {
                    send_one(i, event.clone());
                }
            }
        }
    }
}

impl ThreadedEngine {
    pub fn new(queue_capacity: usize) -> Self {
        ThreadedEngine { queue_capacity }
    }

    /// Run the topology, injecting events from `source` on `entry`.
    /// `collect` receives each processor instance after shutdown for state
    /// extraction (same role as `on_drain` in the local engine, but only
    /// called once at the end — threads own the state meanwhile).
    pub fn run(
        &self,
        topology: &Topology,
        entry: StreamId,
        source: impl Iterator<Item = Event>,
        collect: impl FnMut(usize, usize, &dyn crate::topology::Processor),
    ) -> EngineMetrics {
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let mut metrics = EngineMetrics::new(topology.streams.len(), &shape);
        let started = Instant::now();

        // Build mailboxes.
        let mut receivers: Vec<Vec<(Receiver<Delivery>, Receiver<Delivery>)>> = Vec::new();
        let mut mailboxes: Vec<Vec<Mailbox>> = Vec::new();
        for p in topology.processors.iter() {
            let mut mrow = Vec::new();
            let mut rrow = Vec::new();
            for _ in 0..p.parallelism {
                let (dtx, drx) = sync_channel(self.queue_capacity);
                let (ctx_, crx) = std::sync::mpsc::channel();
                mrow.push(Mailbox { data: dtx, ctrl: ctx_ });
                rrow.push((drx, crx));
            }
            mailboxes.push(mrow);
            receivers.push(rrow);
        }

        let router = Arc::new(Router {
            topology_streams: topology
                .streams
                .iter()
                .map(|s| (s.to.0, s.grouping))
                .collect(),
            mailboxes,
            rr: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_events: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_bytes: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            flow: Flow { sent: AtomicU64::new(0), processed: AtomicU64::new(0) },
        });

        // Spawn worker threads.
        let done: Arc<Mutex<Vec<(usize, usize, Box<dyn crate::topology::Processor>, u64, u64)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for (pid, pdef) in topology.processors.iter().enumerate() {
            for (iid, (drx, crx)) in receivers[pid].drain(..).enumerate().collect::<Vec<_>>() {
                let mut proc_ = (pdef.factory)(iid);
                let router = Arc::clone(&router);
                let done = Arc::clone(&done);
                let par = pdef.parallelism;
                let handle = std::thread::Builder::new()
                    .name(format!("{}-{}", pdef.name, iid))
                    .spawn(move || {
                        let mut busy_ns = 0u64;
                        let mut processed = 0u64;
                        let mut ctx = Ctx::new(iid, par);
                        'outer: loop {
                            // Drain control channel with priority.
                            let delivery = loop {
                                match crx.try_recv() {
                                    Ok(d) => break d,
                                    Err(_) => {}
                                }
                                match drx.try_recv() {
                                    Ok(d) => break d,
                                    Err(std::sync::mpsc::TryRecvError::Empty) => {
                                        // Block on data channel with timeout so
                                        // control stays responsive.
                                        match drx.recv_timeout(std::time::Duration::from_micros(200)) {
                                            Ok(d) => break d,
                                            Err(_) => continue,
                                        }
                                    }
                                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                        match crx.recv() {
                                            Ok(d) => break d,
                                            Err(_) => break 'outer,
                                        }
                                    }
                                }
                            };
                            let is_shutdown = matches!(delivery.event, Event::Shutdown);
                            let t0 = Instant::now();
                            if is_shutdown {
                                proc_.on_shutdown(&mut ctx);
                            } else {
                                proc_.process(delivery.event, &mut ctx);
                            }
                            busy_ns += t0.elapsed().as_nanos() as u64;
                            processed += 1;
                            // Route emissions BEFORE acknowledging the event:
                            // `sent` must rise before `processed` does, or the
                            // quiescence check could observe a false fixpoint.
                            for (s, k, e) in ctx.take() {
                                router.route(s, k, e);
                            }
                            router.flow.processed.fetch_add(1, Ordering::SeqCst);
                            if is_shutdown {
                                break;
                            }
                        }
                        done.lock().unwrap().push((pid, iid, proc_, busy_ns, processed));
                    })
                    .unwrap();
                handles.push(handle);
            }
        }

        // Pump the source from this thread.
        for event in source {
            metrics.source_instances += 1;
            router.route(entry, metrics.source_instances, event);
        }

        // Wait for quiescence: sent == processed, stable across two polls.
        loop {
            let s1 = router.flow.sent.load(Ordering::SeqCst);
            let p1 = router.flow.processed.load(Ordering::SeqCst);
            if s1 == p1 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let s2 = router.flow.sent.load(Ordering::SeqCst);
                let p2 = router.flow.processed.load(Ordering::SeqCst);
                if s2 == p2 && s2 == s1 {
                    break;
                }
            } else {
                std::thread::yield_now();
            }
        }

        // Broadcast shutdown (control plane) and join.
        for (pid, row) in router.mailboxes.iter().enumerate() {
            for (iid, mb) in row.iter().enumerate() {
                let _ = (pid, iid);
                let _ = mb.ctrl.send(Delivery { stream: usize::MAX, event: Event::Shutdown });
            }
        }
        for h in handles {
            let _ = h.join();
        }

        // Collect metrics + state.
        for i in 0..topology.streams.len() {
            metrics.streams[i].events = router.stream_events[i].load(Ordering::Relaxed);
            metrics.streams[i].bytes = router.stream_bytes[i].load(Ordering::Relaxed);
        }
        let mut collect = collect;
        for (pid, iid, proc_, busy, processed) in done.lock().unwrap().iter() {
            metrics.per_instance[*pid][*iid].busy_ns = *busy;
            metrics.per_instance[*pid][*iid].events_processed = *processed;
            collect(*pid, *iid, proc_.as_ref());
        }
        metrics.wall_ns = started.elapsed().as_nanos() as u64;
        metrics
    }
}

// TrySendError import is used indirectly via try_send in earlier revisions;
// keep the type alias to document the backpressure contract.
#[allow(dead_code)]
type _BackpressureWitness = TrySendError<()>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::topology::{Grouping, Processor, TopologyBuilder};
    use std::sync::atomic::AtomicUsize;

    static TOTAL: AtomicUsize = AtomicUsize::new(0);

    struct Add;
    impl Processor for Add {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            TOTAL.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn inst_event(id: u64) -> Event {
        Event::Instance { id, inst: Instance::dense(vec![0.0], Label::None) }
    }

    #[test]
    fn all_events_processed_across_threads() {
        TOTAL.store(0, Ordering::SeqCst);
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("w", 4, |_| Box::new(Add));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let topo = b.build();
        let m = ThreadedEngine::default().run(&topo, entry, (0..1000).map(inst_event), |_, _, _| {});
        assert_eq!(TOTAL.load(Ordering::SeqCst), 1000);
        assert_eq!(m.source_instances, 1000);
        assert_eq!(m.streams[0].events, 1000);
    }

    #[test]
    fn feedback_loop_does_not_deadlock() {
        // a -> b (data), b -> a (control) with tiny queues: must terminate.
        struct Echo {
            data_out: Option<StreamId>,
            ctrl_out: Option<StreamId>,
        }
        impl Processor for Echo {
            fn process(&mut self, e: Event, ctx: &mut Ctx) {
                match e {
                    Event::Instance { id, .. } => {
                        if let Some(s) = self.data_out {
                            // forward as a data-plane attribute event
                            ctx.emit(
                                s,
                                id,
                                Event::Attribute { leaf: id, attr: 0, value: 0.0, class: 0, weight: 1.0 },
                            );
                        }
                    }
                    Event::Attribute { .. } => {
                        if let Some(s) = self.ctrl_out {
                            // close the loop on the control plane
                            ctx.emit(s, 0, Event::Compute { leaf: 0, seq: 0, n_l: 0.0, class_counts: vec![] });
                        }
                    }
                    _ => {}
                }
            }
        }
        let mut b = TopologyBuilder::new("loop");
        let a = b.add_processor("a", 1, |_| {
            Box::new(Echo { data_out: Some(StreamId(1)), ctrl_out: None })
        });
        let c = b.add_processor("c", 1, |_| {
            Box::new(Echo { data_out: None, ctrl_out: Some(StreamId(2)) })
        });
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream("a->c", Some(a), c, Grouping::Shuffle);
        b.stream("c->a", Some(c), a, Grouping::Shuffle);
        let topo = b.build();
        // a forwards Instance as Instance (data), c never generates more
        // data, so the loop closes only via control events.
        let eng = ThreadedEngine::new(2);
        let m = eng.run(&topo, entry, (0..500).map(inst_event), |_, _, _| {});
        assert_eq!(m.source_instances, 500);
    }
}
