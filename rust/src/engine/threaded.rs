//! Threaded engine: bounded channels, real backpressure, adaptive
//! micro-batching and an optional work-stealing scheduler — the
//! in-process analogue of the paper's Storm/Samza adapters, whose data
//! planes are defined by flow control (credit-based backpressure in
//! Flink, max-spout-pending in Storm).
//!
//! # Data plane
//!
//! * Every processor instance owns a data `Receiver<Batch>`; a shared
//!   routing table of senders lets any instance emit to any stream.
//! * **Bounded channels**: data-plane channels are `sync_channel`s of
//!   [`ThreadedEngine::queue_capacity`] batches. A full channel blocks
//!   the producer (pinned mode) or parks the batch and pauses the
//!   producer's input consumption (stealing mode) — so the resident
//!   queue of an instance is capped near `queue_capacity × batch_size`
//!   events no matter how fast the source runs, and pressure propagates
//!   hop by hop back to the source. `queue_capacity = usize::MAX`
//!   (see [`ThreadedEngine::unbounded`]) restores unbounded channels as
//!   a bench baseline. Stalls are counted and timed in
//!   [`EngineMetrics::flow`]; per-instance high-water queue depths land
//!   in `per_instance[..].peak_queue_events`.
//! * **Adaptive micro-batching**: each sender keeps a per-edge buffer
//!   (one per destination *instance*). Under sustained traffic a
//!   size-triggered flush doubles the edge's batch size toward
//!   [`ThreadedEngine::batch_size`] (throughput mode); an idle flush —
//!   the sender's input went quiet with a partial buffer — halves it
//!   toward 1 (latency mode). `with_batch(n)` pins the size instead
//!   (the PR-3 fixed-batch behavior; `with_batch(1)` is the unbatched
//!   engine). The source pump detects slow sources (inter-arrival gap
//!   over ~200µs) and flushes per event, so a trickle is delivered with
//!   per-event latency while a firehose pays one channel send per
//!   batch. Batch buffers are recycled through a
//!   [`crate::topology::BatchArena`], so steady-state batching is
//!   allocation-free.
//! * **Work stealing** ([`ThreadedEngine::with_workers`]): instead of
//!   one OS thread per instance, `n` workers run all instances as
//!   lockable tasks, claiming whichever has queued work — so a `p = 8`
//!   topology runs well on 4 cores and idle workers drain hot shards.
//!   Sends never block a worker: a full channel parks the batch on the
//!   edge and the task stops consuming its *own* input until the park
//!   clears, which is the same backpressure with the worker free to go
//!   drain the congested destination. FIFO per (sender, dest-instance)
//!   edge is preserved — a task is run by at most one worker at a time,
//!   and parked batches are always re-shipped before newer buffers.
//!
//! # Control plane and deadlock freedom
//!
//! Control events (`Event::is_control`) skip the batch buffers and ride
//! a second, *unbounded* channel per instance, drained with priority. A
//! full data channel can therefore never wedge the MA↔LS split-decision
//! loop or the `StatsSync` round protocol — same reasoning as Storm's
//! separate system stream. Cycles in the *data* plane are not supported
//! (as on the real DSPEs, a data cycle under sustained overload has no
//! finite-memory resolution): feedback edges must use control events.
//!
//! # Quiescence and deterministic shutdown
//!
//! `flow.sent` is incremented when an event enters a batch buffer (not
//! when the batch hits the channel), so `sent == processed` can only
//! hold when every buffer and queue has drained. Shutdown is *staged*
//! to kill the old best-effort race where a shard's final emission met
//! an already-exited consumer: processors receive `Shutdown` in
//! processor-id order (the local engine's order), with a quiescence
//! wait after each stage so everything a stage emits from
//! `on_shutdown` is consumed before the next stage flushes; only after
//! the last stage quiesces does an engine-internal `Halt` let workers
//! exit. No worker can observe a closed channel before global
//! quiescence, so shutdown emissions drain deterministically.

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::topology::builder::Topology;
use crate::topology::processor::Ctx;
use crate::topology::stream::Route;
use crate::topology::{BatchArena, Event, StreamId};

use super::metrics::{EngineMetrics, FlowControlMetrics};

/// Data-plane channel payload: one micro-batch of events.
type Batch = Vec<Event>;

/// Lock a mutex, recovering the inner value if a panicking holder
/// poisoned it. A processor panic must surface as *that* panic (the
/// runner joins the thread and the test harness prints it) — not as a
/// cascade of secondary `PoisonError` unwraps from every other thread
/// that touches the wake lock or the collection vector afterwards. The
/// guarded values here (a generation counter, a result vector pushed as
/// the final statement of a worker) are never left half-written.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Control-plane message: a control event, or the engine-internal
/// terminate marker sent only after global post-shutdown quiescence.
enum CtrlMsg {
    Event(Event),
    Halt,
}

/// Data sender: bounded (backpressure) or unbounded (bench baseline).
enum DataTx {
    Bounded(SyncSender<Batch>),
    Unbounded(Sender<Batch>),
}

/// `try_send` outcome. `Gone` (receiver dropped) is impossible before
/// `Halt` by construction; it is still handled by accounting the events
/// as processed so the quiescence check can never hang on them.
enum TrySendErr {
    Full(Batch),
    Gone(Batch),
}

impl DataTx {
    fn try_send(&self, batch: Batch) -> Result<(), TrySendErr> {
        match self {
            DataTx::Bounded(tx) => tx.try_send(batch).map_err(|e| match e {
                TrySendError::Full(b) => TrySendErr::Full(b),
                TrySendError::Disconnected(b) => TrySendErr::Gone(b),
            }),
            DataTx::Unbounded(tx) => tx.send(batch).map_err(|e| TrySendErr::Gone(e.0)),
        }
    }

    fn send_blocking(&self, batch: Batch) -> Result<(), Batch> {
        match self {
            DataTx::Bounded(tx) => tx.send(batch).map_err(|e| e.0),
            DataTx::Unbounded(tx) => tx.send(batch).map_err(|e| e.0),
        }
    }
}

/// Per-destination-instance channel endpoints + queue-depth accounting.
struct Mailbox {
    data: DataTx,
    ctrl: Sender<CtrlMsg>,
    /// Events resident in the data channel. Signed: the sender adds only
    /// AFTER a successful enqueue and the receiver subtracts at dequeue,
    /// so a receiver racing ahead of the sender's add makes this dip
    /// transiently negative — but it can never over-count, keeping
    /// `peak` within the documented `capacity × batch` bound even with
    /// many producers retrying against a full channel.
    depth: AtomicI64,
    /// High-water mark of `depth`.
    peak: AtomicI64,
}

/// Shared counters for quiescence detection.
struct Flow {
    sent: AtomicU64,
    processed: AtomicU64,
}

/// Engine-wide flow-control counters (see `FlowControlMetrics`).
struct FlowStats {
    batches: AtomicU64,
    stalls: AtomicU64,
    stall_ns: AtomicU64,
    grows: AtomicU64,
    shrinks: AtomicU64,
    steals: AtomicU64,
}

/// Engine-wide recovery counters (mirrors `RecoveryMetrics`). Updated by
/// whichever thread runs the recovering task; read once at collection.
#[derive(Default)]
struct RecoveryShared {
    checkpoints: AtomicU64,
    checkpoint_bytes: AtomicU64,
    kills: AtomicU64,
    restores: AtomicU64,
    replayed: AtomicU64,
    replay_dropped: AtomicU64,
}

/// Per-task checkpoint/replay state. Present on every task when
/// checkpointing is on, and on the fault target regardless.
///
/// The protocol: every delivered event (Shutdown excluded) is appended
/// to a bounded replay log *before* it is processed; every
/// `every` events the instance is snapshotted
/// ([`crate::topology::Processor::snapshot`]) and the log cleared. An
/// injected kill swaps in the pre-built `spare` instance, restores the
/// last checkpoint frame into it, and replays the log — with emissions
/// DISCARDED, because the killed instance already shipped everything it
/// processed; re-emitting would double-deliver downstream. Recovery is
/// bit-identical iff the log covered the whole delta (no
/// `replay_dropped`).
struct RecoveryState {
    /// Checkpoint interval in processed events (0 = never checkpoint).
    every: u64,
    since_ckpt: u64,
    /// Events processed by this task (the kill-trigger clock).
    seen: u64,
    /// Latest checkpoint frame (None until the first interval elapses).
    ckpt: Option<Vec<u8>>,
    replay: std::collections::VecDeque<Event>,
    replay_cap: usize,
    /// Fresh replacement instance, pre-built on the main thread from the
    /// topology factory (and pre-seeded with any `with_restore` frame,
    /// so a pre-first-checkpoint kill recovers to the seeded start).
    spare: Option<Box<dyn crate::topology::Processor>>,
    /// Kill after this many processed events (None once fired).
    fault_after: Option<u64>,
}

/// Why a flush was requested — drives the adaptive batch size.
#[derive(Clone, Copy)]
enum Flush {
    /// The buffer reached the edge's current batch size: hot edge, grow.
    Size,
    /// The sender's input went quiet: ship partials now, shrink.
    Idle,
    /// Shutdown/terminal flush: ship everything, no adaptation.
    Final,
}

/// One sender's per-edge state: `bufs[dest processor][dest instance]`.
/// Owned by exactly one thread (a pinned worker, a stealing task, or the
/// source pump), so buffering needs no synchronization.
struct EdgeBuf {
    /// Accumulating FIFO buffer.
    buf: Vec<Event>,
    /// A batch that met a full channel in non-blocking (stealing) mode;
    /// always re-shipped before `buf` so edge FIFO order holds.
    parked: Option<Batch>,
    /// Current adaptive batch size (== the cap when adaptation is off).
    cur: usize,
}

struct OutBuffers {
    bufs: Vec<Vec<EdgeBuf>>,
}

impl OutBuffers {
    fn new(shape: &[usize], batch: usize) -> Self {
        OutBuffers {
            bufs: shape
                .iter()
                .map(|&p| {
                    (0..p)
                        .map(|_| EdgeBuf { buf: Vec::new(), parked: None, cur: batch })
                        .collect()
                })
                .collect(),
        }
    }

    /// True while any edge has a parked batch: the owner must stop
    /// consuming its own data input (backpressure) until the park clears.
    fn congested(&self) -> bool {
        self.bufs.iter().flatten().any(|eb| eb.parked.is_some())
    }

    /// Any event still buffered (parked or accumulating)?
    fn dirty(&self) -> bool {
        self.bufs
            .iter()
            .flatten()
            .any(|eb| eb.parked.is_some() || !eb.buf.is_empty())
    }
}

/// Multi-threaded engine.
pub struct ThreadedEngine {
    /// Bound of each data channel in *batches*; worst-case resident
    /// events per instance is about `queue_capacity × batch_size`.
    /// `usize::MAX` = unbounded (bench baseline, no backpressure).
    pub queue_capacity: usize,
    /// Micro-batch size cap. With `adaptive_batch` the per-edge size
    /// floats in `1..=batch_size`; without it every edge uses exactly
    /// this size (1 = per-event sends, the pre-batching engine).
    pub batch_size: usize,
    /// Adapt per-edge batch sizes (grow when hot, shrink when idle).
    pub adaptive_batch: bool,
    /// `None`: one OS thread per processor instance (pinned). `Some(n)`:
    /// n work-stealing workers run all instances.
    pub workers: Option<usize>,
    /// Bench baseline only: deep-copy every broadcast delivery instead of
    /// the alloc-free shared clone (see `engine_throughput`).
    pub deep_copy_broadcast: bool,
    /// Checkpoint every instance's state every N processed events
    /// (0 = checkpointing off; see the module's recovery notes).
    pub checkpoint_every: u64,
    /// Bound of the per-task replay log, in events. Deltas that outgrow
    /// it lose their oldest events (`recovery.replay_dropped`) and the
    /// recovered run is no longer bit-identical.
    pub replay_cap: usize,
    /// Fault injection: (pid, iid, kill after N processed events).
    fault: Option<(usize, usize, u64)>,
    /// Checkpoint frames applied to instances at startup (rescale /
    /// re-drive): (pid, iid, frame).
    restore_frames: Vec<(usize, usize, Vec<u8>)>,
}

impl Default for ThreadedEngine {
    fn default() -> Self {
        ThreadedEngine {
            queue_capacity: 1024,
            batch_size: 32,
            adaptive_batch: true,
            workers: None,
            deep_copy_broadcast: false,
            checkpoint_every: 0,
            replay_cap: 4096,
            fault: None,
            restore_frames: Vec::new(),
        }
    }
}

impl ThreadedEngine {
    pub fn new(queue_capacity: usize) -> Self {
        ThreadedEngine { queue_capacity, ..Default::default() }
    }

    /// Fixed data-plane micro-batch size (adaptation off; 1 = per-event
    /// sends). `with_adaptive_batch` re-enables adaptation with a cap.
    pub fn with_batch(mut self, batch_size: usize) -> Self {
        self.batch_size = batch_size.max(1);
        self.adaptive_batch = false;
        self
    }

    /// Adaptive micro-batching with the given cap (the default, cap 32).
    pub fn with_adaptive_batch(mut self, cap: usize) -> Self {
        self.batch_size = cap.max(1);
        self.adaptive_batch = true;
        self
    }

    /// Unbounded data channels: no backpressure, queues grow with input
    /// size. Bench baseline for the bounded-queue contract.
    pub fn unbounded(mut self) -> Self {
        self.queue_capacity = usize::MAX;
        self
    }

    /// Run all processor instances on `n` work-stealing workers instead
    /// of one thread per instance.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = Some(n.max(1));
        self
    }

    /// Checkpoint every instance every `every` processed events (0 = off).
    pub fn with_checkpoints(mut self, every: u64) -> Self {
        self.checkpoint_every = every;
        self
    }

    /// Cap the per-task replay log (default 4096 events).
    pub fn with_replay_cap(mut self, cap: usize) -> Self {
        self.replay_cap = cap.max(1);
        self
    }

    /// Inject a fault: kill instance `(pid, iid)` after it has processed
    /// `after` events, then respawn it from the last checkpoint and
    /// replay the delta. The run's `metrics.recovery` records the kill.
    pub fn with_fault(mut self, pid: usize, iid: usize, after: u64) -> Self {
        self.fault = Some((pid, iid, after.max(1)));
        self
    }

    /// Seed instances with checkpoint frames before the run starts —
    /// the restore half of a shard split/merge or a cross-engine
    /// re-drive. Each entry is `(pid, iid, frame)`; frames come from
    /// [`crate::topology::Processor::snapshot`] (possibly merged via
    /// [`super::checkpoint::merge_shard_frames`]).
    pub fn with_restore(mut self, frames: Vec<(usize, usize, Vec<u8>)>) -> Self {
        self.restore_frames = frames;
        self
    }

    /// Build from the unified [`super::EngineConfig`]. Reads every
    /// threaded-engine knob (channels, batching, workers, checkpoints,
    /// fault injection, restore frames); cluster-only fields (`window`,
    /// `peer`, `inject_window`, sockets) do not apply here. Note the
    /// config default `replay_cap` is the cluster-sized 65536, not this
    /// engine's historical 4096 — a config-built engine gets the config's
    /// value.
    pub fn from_config(cfg: &super::EngineConfig) -> Self {
        ThreadedEngine {
            queue_capacity: cfg.queue_capacity,
            batch_size: cfg.batch_size.max(1),
            adaptive_batch: cfg.adaptive_batch,
            workers: cfg.workers,
            deep_copy_broadcast: cfg.deep_copy_broadcast,
            checkpoint_every: cfg.checkpoint_every,
            replay_cap: cfg.replay_cap.max(1),
            fault: cfg.fault,
            restore_frames: cfg.restore_frames.clone(),
        }
    }
}

/// Routing state shared by all worker threads.
/// Work-arrival signal for the stealing scheduler: a generation counter
/// bumped (under the mutex) on every mailbox enqueue, with a condvar an
/// idle worker waits on. Replaces the old fixed 100µs idle sleep — an
/// idle worker now wakes the moment work arrives instead of busy-polling,
/// and a short timeout remains only as a liveness backstop (a wake-up is
/// never *required* for correctness, only for latency). Workers capture
/// the generation *before* scanning for work, so an enqueue racing the
/// scan makes the subsequent wait return immediately — no lost wakeups.
struct Wake {
    generation: Mutex<u64>,
    cv: Condvar,
}

impl Wake {
    fn new() -> Self {
        Wake { generation: Mutex::new(0), cv: Condvar::new() }
    }

    fn notify(&self) {
        *lock_unpoisoned(&self.generation) += 1;
        self.cv.notify_all();
    }

    fn current(&self) -> u64 {
        *lock_unpoisoned(&self.generation)
    }

    /// Block until the generation moves past `seen` or `timeout` expires.
    fn wait_past(&self, seen: u64, timeout: Duration) {
        let mut g = lock_unpoisoned(&self.generation);
        while *g == seen {
            let (g2, res) = self.cv.wait_timeout(g, timeout).unwrap_or_else(|e| e.into_inner());
            g = g2;
            if res.timed_out() {
                return;
            }
        }
    }
}

struct Router {
    topology_streams: Vec<(usize, crate::topology::Grouping)>, // (dest processor, grouping)
    mailboxes: Vec<Vec<Mailbox>>,                              // [processor][instance]
    rr: Vec<AtomicU64>,                                        // per-stream shuffle cursor
    stream_events: Vec<AtomicU64>,
    stream_bytes: Vec<AtomicU64>,
    flow: Flow,
    stats: FlowStats,
    recovery: RecoveryShared,
    arena: BatchArena,
    batch_cap: usize,
    adaptive: bool,
    /// Pinned mode blocks producers on a full channel; stealing mode
    /// parks the batch instead (a worker must never block).
    blocking: bool,
    deep_copy_broadcast: bool,
    /// Stealing-mode idle-worker wakeup (unused in pinned mode, where
    /// blocking channel receives provide the wakeups).
    wake: Wake,
}

impl Router {
    /// Route one emission: metrics + `sent` are counted here, per logical
    /// delivery (a p-way broadcast counts p events and p × wire_bytes,
    /// exactly like the local engine). Data events are buffered per edge;
    /// control events go out immediately on the unbounded channel.
    fn route(&self, out: &mut OutBuffers, stream: StreamId, key: u64, event: Event) {
        let (dest, grouping) = self.topology_streams[stream.0];
        let par = self.mailboxes[dest].len();
        let bytes = event.wire_bytes() as u64;

        let mut rr_cursor = self.rr[stream.0].fetch_add(1, Ordering::Relaxed) as usize;
        match grouping.route(key, par, &mut rr_cursor) {
            Route::One(i) => self.send_one(out, stream.0, dest, i, bytes, event),
            Route::All => {
                // zero-copy fan-out: shared clones + one move (cf. local)
                for i in 0..par - 1 {
                    let copy = event.broadcast_clone(self.deep_copy_broadcast);
                    self.send_one(out, stream.0, dest, i, bytes, copy);
                }
                self.send_one(out, stream.0, dest, par - 1, bytes, event);
            }
        }
    }

    fn send_one(
        &self,
        out: &mut OutBuffers,
        stream: usize,
        dest: usize,
        i: usize,
        bytes: u64,
        event: Event,
    ) {
        // `sent` rises at buffer time so quiescence can never be observed
        // while an event sits in a batch buffer.
        self.flow.sent.fetch_add(1, Ordering::SeqCst);
        self.stream_events[stream].fetch_add(1, Ordering::Relaxed);
        self.stream_bytes[stream].fetch_add(bytes, Ordering::Relaxed);
        if event.is_control() {
            if self.mailboxes[dest][i].ctrl.send(CtrlMsg::Event(event)).is_err() {
                // receiver gone (impossible pre-Halt; keep flow balanced)
                self.flow.processed.fetch_add(1, Ordering::SeqCst);
            } else if !self.blocking {
                self.wake.notify();
            }
        } else {
            let eb = &mut out.bufs[dest][i];
            eb.buf.push(event);
            if eb.buf.len() >= eb.cur {
                self.flush_edge(eb, dest, i, Flush::Size);
            }
        }
    }

    /// Deliver one batch to a mailbox, with depth/peak accounting and
    /// stall metering. Blocks on a full channel in pinned mode; hands
    /// the batch back (`Some`) in stealing mode so the caller parks it.
    fn ship(&self, mb: &Mailbox, batch: Batch) -> Option<Batch> {
        let len = batch.len() as i64;
        let bump = |mb: &Mailbox| {
            let depth = mb.depth.fetch_add(len, Ordering::SeqCst) + len;
            mb.peak.fetch_max(depth, Ordering::Relaxed);
        };
        match mb.data.try_send(batch) {
            Ok(()) => {
                bump(mb);
                self.stats.batches.fetch_add(1, Ordering::Relaxed);
                if !self.blocking {
                    self.wake.notify();
                }
                None
            }
            Err(TrySendErr::Full(batch)) => {
                if self.blocking {
                    // one stall = one backpressure event (the blocked send)
                    self.stats.stalls.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    match mb.data.send_blocking(batch) {
                        Ok(()) => {
                            bump(mb);
                            let ns = t0.elapsed().as_nanos() as u64;
                            self.stats.stall_ns.fetch_add(ns, Ordering::Relaxed);
                            self.stats.batches.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(lost) => self.account_lost(lost),
                    }
                    None
                } else {
                    // stall counting happens at the park transition in
                    // flush_edge, NOT here: retries of an already-parked
                    // batch would otherwise inflate the counter with the
                    // poll frequency instead of counting backpressure
                    // events, breaking comparability with pinned mode
                    Some(batch)
                }
            }
            Err(TrySendErr::Gone(lost)) => {
                self.account_lost(lost);
                None
            }
        }
    }

    /// Receiver gone (only reachable after Halt, i.e. post-quiescence):
    /// count the events processed so flow stays balanced. Depth was not
    /// yet bumped for an unsent batch, so there is nothing to undo.
    fn account_lost(&self, lost: Batch) {
        self.flow.processed.fetch_add(lost.len() as u64, Ordering::SeqCst);
    }

    /// Flush one edge: parked batch first (FIFO), then the buffer if the
    /// reason calls for it. Returns the number of batches shipped.
    fn flush_edge(&self, eb: &mut EdgeBuf, dest: usize, i: usize, reason: Flush) -> usize {
        let mb = &self.mailboxes[dest][i];
        let mut shipped = 0usize;
        if let Some(batch) = eb.parked.take() {
            match self.ship(mb, batch) {
                Some(b) => {
                    eb.parked = Some(b);
                    return shipped;
                }
                None => shipped += 1,
            }
        }
        let ship_buf = match reason {
            Flush::Size => eb.buf.len() >= eb.cur,
            Flush::Idle | Flush::Final => !eb.buf.is_empty(),
        };
        if !ship_buf {
            return shipped;
        }
        if self.adaptive {
            match reason {
                // hot edge: the buffer filled before input went quiet
                Flush::Size if eb.cur < self.batch_cap => {
                    eb.cur = (eb.cur * 2).min(self.batch_cap);
                    self.stats.grows.fetch_add(1, Ordering::Relaxed);
                }
                // cold edge: partial buffer shipped on idle
                Flush::Idle if eb.buf.len() < eb.cur && eb.cur > 1 => {
                    eb.cur = (eb.cur / 2).max(1);
                    self.stats.shrinks.fetch_add(1, Ordering::Relaxed);
                }
                _ => {}
            }
        }
        // Ship in chunks of at most `batch_cap` events: a buffer that
        // grew past the cap (a parked stealing-mode edge kept
        // accumulating, or an adaptive shrink halved `cur` under a
        // partial buffer) must not enter the channel as one oversized
        // batch, or the `capacity × batch` resident-depth bound would
        // silently stretch. The common case (buf ≤ cap) stays a single
        // pointer swap. A Size flush keeps a sub-`cur` remainder
        // buffered (it is still accumulating); Idle/Final drain fully.
        loop {
            let more = match reason {
                Flush::Size => eb.buf.len() >= eb.cur,
                Flush::Idle | Flush::Final => !eb.buf.is_empty(),
            };
            if !more {
                return shipped;
            }
            let chunk = if eb.buf.len() <= self.batch_cap {
                // per-event edges (below the arena minimum) skip the
                // shared pool: a global lock round-trip per event costs
                // more than the allocation it saves
                let repl = if eb.buf.len() >= BatchArena::MIN_CAPACITY {
                    self.arena.take()
                } else {
                    Vec::new()
                };
                std::mem::replace(&mut eb.buf, repl)
            } else {
                let mut c = if self.batch_cap >= BatchArena::MIN_CAPACITY {
                    self.arena.take()
                } else {
                    Vec::new()
                };
                c.extend(eb.buf.drain(..self.batch_cap));
                c
            };
            match self.ship(mb, chunk) {
                Some(b) => {
                    // unparked → parked transition: one backpressure event
                    self.stats.stalls.fetch_add(1, Ordering::Relaxed);
                    eb.parked = Some(b);
                    return shipped;
                }
                None => shipped += 1,
            }
        }
    }

    /// Idle flush: the sender's input went quiet — ship partial buffers
    /// (shrinking adaptive edges) and retry parked batches.
    fn flush_idle(&self, out: &mut OutBuffers) {
        for (dest, row) in out.bufs.iter_mut().enumerate() {
            for (i, eb) in row.iter_mut().enumerate() {
                if eb.parked.is_some() || !eb.buf.is_empty() {
                    self.flush_edge(eb, dest, i, Flush::Idle);
                }
            }
        }
    }

    /// Retry parked batches and ship size-ready buffers (stealing mode's
    /// quantum prologue). Returns the number of batches shipped.
    fn flush_ready(&self, out: &mut OutBuffers) -> usize {
        let mut shipped = 0;
        for (dest, row) in out.bufs.iter_mut().enumerate() {
            for (i, eb) in row.iter_mut().enumerate() {
                if eb.parked.is_some() || eb.buf.len() >= eb.cur {
                    shipped += self.flush_edge(eb, dest, i, Flush::Size);
                }
            }
        }
        shipped
    }

    /// Terminal flush: ship everything, waiting out full channels. In
    /// pinned mode sends block, so one pass suffices. In stealing mode
    /// parked batches are retried until the consumers drain them —
    /// consumers always make progress (workers never block), so this
    /// terminates; zero-loss is not traded away for a time cap. A
    /// receiver that is actually gone is handled inside `ship`
    /// (accounted and dropped), so this cannot spin on a dead consumer.
    fn flush_final(&self, out: &mut OutBuffers) {
        loop {
            for (dest, row) in out.bufs.iter_mut().enumerate() {
                for (i, eb) in row.iter_mut().enumerate() {
                    if eb.parked.is_some() || !eb.buf.is_empty() {
                        self.flush_edge(eb, dest, i, Flush::Final);
                    }
                }
            }
            if !out.dirty() {
                return;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Process one delivered event: run the processor (or `on_shutdown` for
/// the Shutdown marker), route its emissions, then acknowledge it.
/// Emissions are routed BEFORE `processed` rises, or the quiescence
/// check could observe a false fixpoint.
#[allow(clippy::too_many_arguments)]
fn handle_one(
    proc_: &mut Box<dyn crate::topology::Processor>,
    ctx: &mut Ctx,
    router: &Router,
    out: &mut OutBuffers,
    busy_ns: &mut u64,
    processed: &mut u64,
    event: Event,
) {
    let is_shutdown = matches!(event, Event::Shutdown);
    let t0 = Instant::now();
    if is_shutdown {
        proc_.on_shutdown(ctx);
    } else {
        proc_.process(event, ctx);
    }
    *busy_ns += t0.elapsed().as_nanos() as u64;
    *processed += 1;
    for (s, k, e) in ctx.take() {
        router.route(out, s, k, e);
    }
    router.flow.processed.fetch_add(1, Ordering::SeqCst);
}

/// `handle_one` plus the recovery protocol (see [`RecoveryState`]): log
/// the event, process it, then run the checkpoint/kill schedule.
#[allow(clippy::too_many_arguments)]
fn handle_recovered(
    proc_: &mut Box<dyn crate::topology::Processor>,
    ctx: &mut Ctx,
    router: &Router,
    out: &mut OutBuffers,
    busy_ns: &mut u64,
    processed: &mut u64,
    rec: &mut Option<RecoveryState>,
    event: Event,
) {
    let active = match rec {
        Some(r) => r.every > 0 || r.fault_after.is_some(),
        None => false,
    };
    if !active || matches!(event, Event::Shutdown) {
        handle_one(proc_, ctx, router, out, busy_ns, processed, event);
        return;
    }
    let r = rec.as_mut().unwrap();
    if r.replay.len() >= r.replay_cap {
        r.replay.pop_front();
        router.recovery.replay_dropped.fetch_add(1, Ordering::Relaxed);
    }
    r.replay.push_back(event.clone());
    handle_one(proc_, ctx, router, out, busy_ns, processed, event);
    r.seen += 1;
    if r.fault_after == Some(r.seen) {
        // Kill the instance mid-stream and bring up its replacement.
        // Everything the dead instance processed has already been
        // routed, so the replay below rebuilds *state only* — the
        // scratch emissions are discarded, not re-routed.
        r.fault_after = None;
        router.recovery.kills.fetch_add(1, Ordering::Relaxed);
        let mut fresh = r.spare.take().expect("fault target has no spare instance");
        if let Some(frame) = &r.ckpt {
            fresh
                .restore(frame)
                .expect("checkpoint frame rejected by respawned instance");
        }
        router.recovery.restores.fetch_add(1, Ordering::Relaxed);
        for e in r.replay.iter() {
            router.recovery.replayed.fetch_add(1, Ordering::Relaxed);
            fresh.process(e.clone(), ctx);
            ctx.take(); // already delivered pre-kill: suppress re-emission
        }
        *proc_ = fresh;
        return;
    }
    if r.every > 0 {
        r.since_ckpt += 1;
        if r.since_ckpt >= r.every {
            r.since_ckpt = 0;
            if let Some(frame) = proc_.snapshot() {
                router.recovery.checkpoints.fetch_add(1, Ordering::Relaxed);
                router
                    .recovery
                    .checkpoint_bytes
                    .fetch_add(frame.len() as u64, Ordering::Relaxed);
                r.ckpt = Some(frame);
                // Only a captured frame covers the logged delta; for a
                // snapshot-less processor the log keeps accumulating so
                // a kill replays the whole (bounded) history instead of
                // silently losing everything before this boundary.
                r.replay.clear();
            }
        }
    }
}

/// A processor instance as a stealable unit of work (stealing mode).
struct Task {
    pid: usize,
    iid: usize,
    proc_: Box<dyn crate::topology::Processor>,
    drx: Receiver<Batch>,
    crx: Receiver<CtrlMsg>,
    ctx: Ctx,
    out: OutBuffers,
    busy_ns: u64,
    processed: u64,
    halted: bool,
    rec: Option<RecoveryState>,
}

/// Control events drained per quantum before data is considered.
const CTRL_QUANTUM: usize = 32;
/// Data batches drained per quantum before the worker moves on (keeps
/// one hot task from starving the rest when workers < tasks).
const DATA_QUANTUM: usize = 4;
/// Inter-arrival gap beyond which the source is considered slow and its
/// partial batches are flushed per event (latency mode).
const SOURCE_IDLE: Duration = Duration::from_micros(200);

/// Run one scheduling quantum of a task. Returns true if any work was
/// done (flush progress, control events, or data batches).
fn run_quantum(router: &Router, t: &mut Task) -> bool {
    let mut did = router.flush_ready(&mut t.out) > 0;
    for _ in 0..CTRL_QUANTUM {
        match t.crx.try_recv() {
            Ok(CtrlMsg::Halt) => {
                router.flush_final(&mut t.out);
                t.halted = true;
                return true;
            }
            Ok(CtrlMsg::Event(e)) => {
                handle_recovered(
                    &mut t.proc_, &mut t.ctx, router, &mut t.out, &mut t.busy_ns,
                    &mut t.processed, &mut t.rec, e,
                );
                did = true;
            }
            Err(_) => break,
        }
    }
    // Backpressure: while an output edge is parked, do not consume our
    // own input — upstream pressure then reaches our input channel.
    if !t.out.congested() {
        for _ in 0..DATA_QUANTUM {
            match t.drx.try_recv() {
                Ok(mut batch) => {
                    let mb = &router.mailboxes[t.pid][t.iid];
                    mb.depth.fetch_sub(batch.len() as i64, Ordering::SeqCst);
                    for e in batch.drain(..) {
                        handle_recovered(
                            &mut t.proc_, &mut t.ctx, router, &mut t.out, &mut t.busy_ns,
                            &mut t.processed, &mut t.rec, e,
                        );
                    }
                    router.arena.put(batch);
                    did = true;
                    if t.out.congested() {
                        break;
                    }
                }
                Err(_) => {
                    router.flush_idle(&mut t.out);
                    break;
                }
            }
        }
    }
    did
}

impl ThreadedEngine {
    /// Run the topology, injecting events from `source` on `entry`.
    /// `collect` receives each processor instance after shutdown for state
    /// extraction (same role as `on_drain` in the local engine, but only
    /// called once at the end — threads own the state meanwhile).
    pub fn run(
        &self,
        topology: &Topology,
        entry: StreamId,
        source: impl Iterator<Item = Event>,
        collect: impl FnMut(usize, usize, &dyn crate::topology::Processor),
    ) -> EngineMetrics {
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let n_instances: usize = shape.iter().sum();
        let mut metrics = EngineMetrics::new(topology.streams.len(), &shape);
        let started = Instant::now();
        let batch = self.batch_size.max(1);

        // Build mailboxes.
        let mut receivers: Vec<Vec<(Receiver<Batch>, Receiver<CtrlMsg>)>> = Vec::new();
        let mut mailboxes: Vec<Vec<Mailbox>> = Vec::new();
        for p in topology.processors.iter() {
            let mut mrow = Vec::new();
            let mut rrow = Vec::new();
            for _ in 0..p.parallelism {
                let (dtx, drx) = if self.queue_capacity == usize::MAX {
                    let (tx, rx) = std::sync::mpsc::channel();
                    (DataTx::Unbounded(tx), rx)
                } else {
                    let (tx, rx) = sync_channel(self.queue_capacity);
                    (DataTx::Bounded(tx), rx)
                };
                let (ctx_, crx) = std::sync::mpsc::channel();
                mrow.push(Mailbox {
                    data: dtx,
                    ctrl: ctx_,
                    depth: AtomicI64::new(0),
                    peak: AtomicI64::new(0),
                });
                rrow.push((drx, crx));
            }
            mailboxes.push(mrow);
            receivers.push(rrow);
        }

        let router = Arc::new(Router {
            topology_streams: topology
                .streams
                .iter()
                .map(|s| (s.to.0, s.grouping))
                .collect(),
            mailboxes,
            rr: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_events: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_bytes: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            flow: Flow { sent: AtomicU64::new(0), processed: AtomicU64::new(0) },
            stats: FlowStats {
                batches: AtomicU64::new(0),
                stalls: AtomicU64::new(0),
                stall_ns: AtomicU64::new(0),
                grows: AtomicU64::new(0),
                shrinks: AtomicU64::new(0),
                steals: AtomicU64::new(0),
            },
            recovery: RecoveryShared::default(),
            arena: BatchArena::new(4 * n_instances + 32),
            batch_cap: batch,
            adaptive: self.adaptive_batch,
            blocking: self.workers.is_none(),
            deep_copy_broadcast: self.deep_copy_broadcast,
            wake: Wake::new(),
        });

        // Startup restore frames (rescale / re-drive) and fault targets,
        // all resolved on the main thread before any worker spawns.
        let mut restore_map: std::collections::HashMap<(usize, usize), Vec<u8>> =
            self.restore_frames.iter().cloned().map(|(p, i, f)| ((p, i), f)).collect();
        // Build the per-instance recovery state (and its spare instance)
        // on the main thread; `Processor: Send` lets it cross into the
        // worker. Restore frames are applied to the primary *and* the
        // spare, so a kill before the first checkpoint still recovers to
        // the seeded start rather than a blank factory instance.
        let mk_rec = |pid: usize,
                      iid: usize,
                      proc_: &mut Box<dyn crate::topology::Processor>,
                      factory: &dyn Fn(usize) -> Box<dyn crate::topology::Processor>,
                      restore_map: &mut std::collections::HashMap<(usize, usize), Vec<u8>>|
         -> Option<RecoveryState> {
            let frame = restore_map.remove(&(pid, iid));
            if let Some(f) = &frame {
                proc_.restore(f).expect("startup restore frame rejected");
                router.recovery.restores.fetch_add(1, Ordering::Relaxed);
            }
            let fault_after = match self.fault {
                Some((fp, fi, n)) if fp == pid && fi == iid => Some(n),
                _ => None,
            };
            if self.checkpoint_every == 0 && fault_after.is_none() {
                return None;
            }
            let spare = fault_after.map(|_| {
                let mut s = factory(iid);
                if let Some(f) = &frame {
                    s.restore(f).expect("startup restore frame rejected by spare");
                }
                s
            });
            Some(RecoveryState {
                every: self.checkpoint_every,
                since_ckpt: 0,
                seen: 0,
                ckpt: None,
                replay: std::collections::VecDeque::new(),
                replay_cap: self.replay_cap,
                spare,
                fault_after,
            })
        };

        // Spawn execution: pinned threads or a stealing worker pool.
        let done: Arc<Mutex<Vec<(usize, usize, Box<dyn crate::topology::Processor>, u64, u64)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        let mut slots_arc: Option<Arc<Vec<Mutex<Task>>>> = None;

        match self.workers {
            None => {
                for (pid, pdef) in topology.processors.iter().enumerate() {
                    let rrow: Vec<_> = receivers[pid].drain(..).enumerate().collect();
                    for (iid, (drx, crx)) in rrow {
                        let mut proc_ = (pdef.factory)(iid);
                        let mut rec =
                            mk_rec(pid, iid, &mut proc_, &pdef.factory, &mut restore_map);
                        let router = Arc::clone(&router);
                        let done = Arc::clone(&done);
                        let par = pdef.parallelism;
                        let shape = shape.clone();
                        let handle = std::thread::Builder::new()
                            .name(format!("{}-{}", pdef.name, iid))
                            .spawn(move || {
                                let mut busy_ns = 0u64;
                                let mut processed = 0u64;
                                let mut ctx = Ctx::new(iid, par);
                                let mut out = OutBuffers::new(&shape, router.batch_cap);

                                'outer: loop {
                                    enum Work {
                                        Ctrl(CtrlMsg),
                                        Data(Batch),
                                    }
                                    let work = loop {
                                        if let Ok(c) = crx.try_recv() {
                                            break Work::Ctrl(c);
                                        }
                                        match drx.try_recv() {
                                            Ok(b) => break Work::Data(b),
                                            Err(TryRecvError::Empty) => {
                                                // Input quiet: ship partial
                                                // batches (shrinking adaptive
                                                // edges), then block briefly so
                                                // control stays responsive.
                                                router.flush_idle(&mut out);
                                                let wait = Duration::from_micros(200);
                                                match drx.recv_timeout(wait) {
                                                    Ok(b) => break Work::Data(b),
                                                    Err(RecvTimeoutError::Timeout) => continue,
                                                    Err(RecvTimeoutError::Disconnected) => {
                                                        match crx.recv() {
                                                            Ok(c) => break Work::Ctrl(c),
                                                            Err(_) => break 'outer,
                                                        }
                                                    }
                                                }
                                            }
                                            Err(TryRecvError::Disconnected) => match crx.recv() {
                                                Ok(c) => break Work::Ctrl(c),
                                                Err(_) => break 'outer,
                                            },
                                        }
                                    };
                                    match work {
                                        Work::Ctrl(CtrlMsg::Halt) => break 'outer,
                                        Work::Ctrl(CtrlMsg::Event(e)) => {
                                            handle_recovered(
                                                &mut proc_, &mut ctx, &router, &mut out,
                                                &mut busy_ns, &mut processed, &mut rec, e,
                                            );
                                        }
                                        Work::Data(mut batch) => {
                                            let mb = &router.mailboxes[pid][iid];
                                            mb.depth
                                                .fetch_sub(batch.len() as i64, Ordering::SeqCst);
                                            for e in batch.drain(..) {
                                                handle_recovered(
                                                    &mut proc_, &mut ctx, &router, &mut out,
                                                    &mut busy_ns, &mut processed, &mut rec, e,
                                                );
                                            }
                                            router.arena.put(batch);
                                        }
                                    }
                                }
                                router.flush_final(&mut out);
                                lock_unpoisoned(&done).push((pid, iid, proc_, busy_ns, processed));
                            })
                            .unwrap();
                        handles.push(handle);
                    }
                }
            }
            Some(n_workers) => {
                let mut tasks = Vec::with_capacity(n_instances);
                for (pid, pdef) in topology.processors.iter().enumerate() {
                    let rrow: Vec<_> = receivers[pid].drain(..).enumerate().collect();
                    for (iid, (drx, crx)) in rrow {
                        let mut proc_ = (pdef.factory)(iid);
                        let rec = mk_rec(pid, iid, &mut proc_, &pdef.factory, &mut restore_map);
                        tasks.push(Mutex::new(Task {
                            pid,
                            iid,
                            proc_,
                            drx,
                            crx,
                            ctx: Ctx::new(iid, pdef.parallelism),
                            out: OutBuffers::new(&shape, batch),
                            busy_ns: 0,
                            processed: 0,
                            halted: false,
                            rec,
                        }));
                    }
                }
                let slots = Arc::new(tasks);
                let halted = Arc::new(AtomicUsize::new(0));
                let n_tasks = slots.len();
                for w in 0..n_workers.min(n_tasks.max(1)) {
                    let slots = Arc::clone(&slots);
                    let halted = Arc::clone(&halted);
                    let router = Arc::clone(&router);
                    let handle = std::thread::Builder::new()
                        .name(format!("steal-w{w}"))
                        .spawn(move || {
                            let n_workers = n_workers.max(1);
                            loop {
                                // Capture the wake generation BEFORE the
                                // scan: an enqueue racing the scan bumps it
                                // and the wait below returns immediately.
                                let wake_gen = router.wake.current();
                                let mut progress = false;
                                for k in 0..n_tasks {
                                    let idx = (w + k) % n_tasks;
                                    let Ok(mut t) = slots[idx].try_lock() else { continue };
                                    if t.halted {
                                        continue;
                                    }
                                    let did = run_quantum(&router, &mut t);
                                    if did && idx % n_workers != w {
                                        router.stats.steals.fetch_add(1, Ordering::Relaxed);
                                    }
                                    if t.halted {
                                        halted.fetch_add(1, Ordering::SeqCst);
                                        // crisp exit for workers idling in
                                        // the wait below
                                        router.wake.notify();
                                    }
                                    progress |= did;
                                }
                                if halted.load(Ordering::SeqCst) == n_tasks {
                                    break;
                                }
                                if !progress {
                                    // Sleep until work arrives (send-side
                                    // notify) instead of busy-polling; the
                                    // timeout is a liveness backstop only.
                                    router.wake.wait_past(wake_gen, Duration::from_millis(1));
                                }
                            }
                        })
                        .unwrap();
                    handles.push(handle);
                }
                slots_arc = Some(slots);
            }
        }

        // Pump the source from this thread (with its own batch buffers).
        // Under adaptive batching a slow source (inter-arrival gap beyond
        // SOURCE_IDLE) gets its events flushed immediately — latency
        // mode; fixed batching keeps the strict size-based flushes of
        // the PR-3 plane (partial source buffers ship only at exhaustion).
        let mut src_out = OutBuffers::new(&shape, batch);
        let mut source = source;
        loop {
            // Time only the iterator's own `next()`: the gap must not
            // include route()'s backpressure stalls, or sustained
            // downstream overload would be misclassified as a trickle
            // source and shrink batches exactly when batching matters.
            let t_next = Instant::now();
            let Some(event) = source.next() else { break };
            let slow = t_next.elapsed() > SOURCE_IDLE;
            metrics.source_instances += 1;
            router.route(&mut src_out, entry, metrics.source_instances, event);
            if slow && self.adaptive_batch {
                router.flush_idle(&mut src_out);
            }
            // stealing mode: parked batches are the source's backpressure
            while src_out.congested() {
                router.flush_ready(&mut src_out);
                if src_out.congested() {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        }
        router.flush_final(&mut src_out);

        // Wait for quiescence: sent == processed, stable across two polls.
        // `sent` includes buffered events, so this can only fire once every
        // batch buffer in the system has drained.
        let quiesce = || loop {
            let s1 = router.flow.sent.load(Ordering::SeqCst);
            let p1 = router.flow.processed.load(Ordering::SeqCst);
            if s1 == p1 {
                std::thread::sleep(Duration::from_millis(2));
                let s2 = router.flow.sent.load(Ordering::SeqCst);
                let p2 = router.flow.processed.load(Ordering::SeqCst);
                if s2 == p2 && s2 == s1 {
                    break;
                }
            } else {
                std::thread::yield_now();
            }
        };
        quiesce();

        // Staged shutdown in processor-id order (the local engine's
        // sequence): each stage's on_shutdown emissions fully drain —
        // through bounded channels and all — before the next stage runs,
        // so no shutdown emission can meet an exited consumer.
        for row in router.mailboxes.iter() {
            for mb in row.iter() {
                router.flow.sent.fetch_add(1, Ordering::SeqCst);
                if mb.ctrl.send(CtrlMsg::Event(Event::Shutdown)).is_err() {
                    router.flow.processed.fetch_add(1, Ordering::SeqCst);
                }
                router.wake.notify();
            }
            quiesce();
        }

        // Global post-shutdown quiescence reached: workers may now exit.
        for row in router.mailboxes.iter() {
            for mb in row.iter() {
                let _ = mb.ctrl.send(CtrlMsg::Halt);
                router.wake.notify();
            }
        }
        for h in handles {
            let _ = h.join();
        }

        // Collect metrics + state.
        for i in 0..topology.streams.len() {
            metrics.streams[i].events = router.stream_events[i].load(Ordering::Relaxed);
            metrics.streams[i].bytes = router.stream_bytes[i].load(Ordering::Relaxed);
        }
        for (pid, row) in router.mailboxes.iter().enumerate() {
            for (iid, mb) in row.iter().enumerate() {
                metrics.per_instance[pid][iid].peak_queue_events =
                    mb.peak.load(Ordering::Relaxed).max(0) as u64;
            }
        }
        metrics.flow = FlowControlMetrics {
            batches_sent: router.stats.batches.load(Ordering::Relaxed),
            backpressure_stalls: router.stats.stalls.load(Ordering::Relaxed),
            backpressure_stall_ns: router.stats.stall_ns.load(Ordering::Relaxed),
            batch_grows: router.stats.grows.load(Ordering::Relaxed),
            batch_shrinks: router.stats.shrinks.load(Ordering::Relaxed),
            steals: router.stats.steals.load(Ordering::Relaxed),
            arena_reuses: router.arena.reuses(),
            arena_allocs: router.arena.allocations(),
        };
        metrics.recovery = super::metrics::RecoveryMetrics {
            checkpoints: router.recovery.checkpoints.load(Ordering::Relaxed),
            checkpoint_bytes: router.recovery.checkpoint_bytes.load(Ordering::Relaxed),
            kills: router.recovery.kills.load(Ordering::Relaxed),
            restores: router.recovery.restores.load(Ordering::Relaxed),
            replayed: router.recovery.replayed.load(Ordering::Relaxed),
            replay_dropped: router.recovery.replay_dropped.load(Ordering::Relaxed),
        };
        let mut collect = collect;
        match slots_arc {
            Some(slots) => {
                let slots = Arc::try_unwrap(slots)
                    .unwrap_or_else(|_| panic!("worker kept a task slot alive"));
                for slot in slots {
                    let t = slot.into_inner().unwrap_or_else(|e| e.into_inner());
                    metrics.per_instance[t.pid][t.iid].busy_ns = t.busy_ns;
                    metrics.per_instance[t.pid][t.iid].events_processed = t.processed;
                    collect(t.pid, t.iid, t.proc_.as_ref());
                }
            }
            None => {
                for (pid, iid, proc_, busy, processed) in lock_unpoisoned(&done).iter() {
                    metrics.per_instance[*pid][*iid].busy_ns = *busy;
                    metrics.per_instance[*pid][*iid].events_processed = *processed;
                    collect(*pid, *iid, proc_.as_ref());
                }
            }
        }
        metrics.wall_ns = started.elapsed().as_nanos() as u64;
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::topology::{Grouping, Processor, TopologyBuilder};
    use std::sync::atomic::AtomicUsize;

    static TOTAL: AtomicUsize = AtomicUsize::new(0);

    struct Add;
    impl Processor for Add {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            TOTAL.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn inst_event(id: u64) -> Event {
        Event::Instance { id, inst: Instance::dense(vec![0.0], Label::None) }
    }

    #[test]
    fn all_events_processed_across_threads() {
        TOTAL.store(0, Ordering::SeqCst);
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("w", 4, |_| Box::new(Add));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let topo = b.build();
        let m =
            ThreadedEngine::default().run(&topo, entry, (0..1000).map(inst_event), |_, _, _| {});
        assert_eq!(TOTAL.load(Ordering::SeqCst), 1000);
        assert_eq!(m.source_instances, 1000);
        assert_eq!(m.streams[0].events, 1000);
        // events moved in batches, and steady state reuses buffers
        assert!(m.flow.batches_sent > 0);
        assert!(m.flow.arena_reuses + m.flow.arena_allocs > 0);
    }

    /// Conservation must hold at every fixed batch size, including the
    /// unbatched (`1`) and larger-than-stream (`4096`) extremes. Uses a
    /// per-test counter (not the shared TOTAL static) so it cannot race
    /// with `all_events_processed_across_threads` under parallel `cargo
    /// test`.
    #[test]
    fn batch_sizes_conserve_events() {
        struct CountInto(Arc<AtomicUsize>);
        impl Processor for CountInto {
            fn process(&mut self, _e: Event, _c: &mut Ctx) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        for batch in [1usize, 2, 32, 4096] {
            let count = Arc::new(AtomicUsize::new(0));
            let count2 = Arc::clone(&count);
            let mut b = TopologyBuilder::new("t");
            let a = b.add_processor("w", 3, move |_| Box::new(CountInto(Arc::clone(&count2))));
            let entry = b.stream("src", None, a, Grouping::Shuffle);
            let topo = b.build();
            let m = ThreadedEngine::default()
                .with_batch(batch)
                .run(&topo, entry, (0..777).map(inst_event), |_, _, _| {});
            assert_eq!(count.load(Ordering::SeqCst), 777, "batch={batch}");
            assert_eq!(m.streams[0].events, 777, "batch={batch}");
        }
    }

    /// Work-stealing mode: conservation and full state collection with
    /// fewer workers than instances, and with more workers than tasks.
    #[test]
    fn steal_mode_conserves_and_collects() {
        for workers in [1usize, 2, 8] {
            let count = Arc::new(AtomicUsize::new(0));
            let count2 = Arc::clone(&count);
            struct CountInto(Arc<AtomicUsize>);
            impl Processor for CountInto {
                fn process(&mut self, _e: Event, _c: &mut Ctx) {
                    self.0.fetch_add(1, Ordering::SeqCst);
                }
            }
            let mut b = TopologyBuilder::new("t");
            let a = b.add_processor("w", 5, move |_| Box::new(CountInto(Arc::clone(&count2))));
            let entry = b.stream("src", None, a, Grouping::Shuffle);
            let topo = b.build();
            let mut collected = 0;
            let m = ThreadedEngine::default().with_workers(workers).run(
                &topo,
                entry,
                (0..900).map(inst_event),
                |_, _, _| collected += 1,
            );
            assert_eq!(count.load(Ordering::SeqCst), 900, "workers={workers}");
            assert_eq!(m.streams[0].events, 900, "workers={workers}");
            assert_eq!(collected, 5, "workers={workers}");
            let processed: u64 =
                m.per_instance[0].iter().map(|i| i.events_processed).sum();
            // 900 data events + 5 shutdown markers
            assert_eq!(processed, 905, "workers={workers}");
        }
    }

    /// Bounded channels bound the resident queue: a slow consumer behind
    /// a tiny channel keeps peak depth near capacity × batch while the
    /// producer stalls, and nothing is lost.
    #[test]
    fn bounded_queue_bounds_depth_and_stalls() {
        struct SlowCount(Arc<AtomicUsize>);
        impl Processor for SlowCount {
            fn process(&mut self, _e: Event, _c: &mut Ctx) {
                std::thread::sleep(Duration::from_micros(50));
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let count = Arc::new(AtomicUsize::new(0));
        let count2 = Arc::clone(&count);
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("slow", 1, move |_| Box::new(SlowCount(Arc::clone(&count2))));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let topo = b.build();
        let (capacity, batch) = (2usize, 4usize);
        let m = ThreadedEngine::new(capacity)
            .with_batch(batch)
            .run(&topo, entry, (0..600).map(inst_event), |_, _, _| {});
        assert_eq!(count.load(Ordering::SeqCst), 600);
        // resident bound: `capacity` batches in the channel plus one
        // received-but-not-yet-decremented batch at the consumer (one
        // extra batch of slack kept for safety)
        let bound = ((capacity + 2) * batch) as u64;
        assert!(
            m.max_peak_queue_events() <= bound,
            "peak {} exceeds bound {bound}",
            m.max_peak_queue_events()
        );
        assert!(m.flow.backpressure_stalls > 0, "tiny queue never stalled");
    }

    #[test]
    fn feedback_loop_does_not_deadlock() {
        // a -> b (data), b -> a (control) with tiny queues: must terminate.
        struct Echo {
            data_out: Option<StreamId>,
            ctrl_out: Option<StreamId>,
        }
        impl Processor for Echo {
            fn process(&mut self, e: Event, ctx: &mut Ctx) {
                match e {
                    Event::Instance { id, .. } => {
                        if let Some(s) = self.data_out {
                            // forward as a data-plane attribute event
                            ctx.emit(
                                s,
                                id,
                                Event::Attribute {
                                    leaf: id,
                                    attr: 0,
                                    value: 0.0,
                                    class: 0,
                                    weight: 1.0,
                                },
                            );
                        }
                    }
                    Event::Attribute { .. } => {
                        if let Some(s) = self.ctrl_out {
                            // close the loop on the control plane
                            ctx.emit(
                                s,
                                0,
                                Event::Compute {
                                    leaf: 0,
                                    seq: 0,
                                    n_l: 0.0,
                                    class_counts: Arc::new(vec![]),
                                },
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
        let mut b = TopologyBuilder::new("loop");
        let a = b.add_processor("a", 1, |_| {
            Box::new(Echo { data_out: Some(StreamId(1)), ctrl_out: None })
        });
        let c = b.add_processor("c", 1, |_| {
            Box::new(Echo { data_out: None, ctrl_out: Some(StreamId(2)) })
        });
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream("a->c", Some(a), c, Grouping::Shuffle);
        b.stream("c->a", Some(c), a, Grouping::Shuffle);
        let topo = b.build();
        // a forwards Instance as Attribute (data), c never generates more
        // data, so the loop closes only via control events.
        let eng = ThreadedEngine::new(2);
        let m = eng.run(&topo, entry, (0..500).map(inst_event), |_, _, _| {});
        assert_eq!(m.source_instances, 500);
    }

    /// Adaptive batching reacts to a slow source: partial buffers are
    /// flushed on idle and the per-edge batch size shrinks toward 1 (the
    /// latency mode), without a single backpressure stall.
    #[test]
    fn adaptive_batch_shrinks_on_trickle() {
        struct CountInto(Arc<AtomicUsize>);
        impl Processor for CountInto {
            fn process(&mut self, _e: Event, _c: &mut Ctx) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let count = Arc::new(AtomicUsize::new(0));
        let count2 = Arc::clone(&count);
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("w", 1, move |_| Box::new(CountInto(Arc::clone(&count2))));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let topo = b.build();
        let trickle = (0..40u64).map(|id| {
            std::thread::sleep(Duration::from_millis(1));
            inst_event(id)
        });
        let m = ThreadedEngine::default().run(&topo, entry, trickle, |_, _, _| {});
        assert_eq!(count.load(Ordering::SeqCst), 40);
        assert!(m.flow.batch_shrinks > 0, "trickle never shrank the batch: {:?}", m.flow);
        assert_eq!(m.flow.backpressure_stalls, 0);
    }
}
