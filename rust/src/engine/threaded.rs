//! Threaded engine: one OS thread per processor instance, bounded
//! channels, real backpressure — the in-process analogue of the paper's
//! Storm/Samza adapters.
//!
//! Design notes:
//! * Every processor instance owns a `Receiver`; a shared routing table
//!   of `Sender`s lets any instance emit to any stream.
//! * **Micro-batched data plane**: each sender keeps a small per-edge
//!   buffer (one `Vec<Event>` per destination *instance*), flushed when
//!   it reaches [`ThreadedEngine::batch_size`] events or when the
//!   sender's own input goes quiet — so one bounded-channel send
//!   amortizes over up to `batch_size` events instead of paying channel
//!   synchronization per event. Order within a (sender, dest-instance)
//!   edge is preserved: buffers are FIFO and flushes are in-order
//!   appends. `batch_size = 1` reproduces the per-event sends of the
//!   pre-batching engine.
//! * **Backpressure**: data-plane sends use `SyncSender::send` on a
//!   bounded channel (capacity counted in *batches*) and block when the
//!   consumer lags — the Storm max-spout-pending analogue.
//! * **Deadlock avoidance on feedback loops** (MA→LS→MA): control events
//!   (`Event::is_control`) skip the batch buffers entirely and ride a
//!   second, *unbounded* channel per instance, drained with priority. A
//!   full data channel can therefore never wedge the split-decision
//!   loop, and a latency-critical control event is never parked behind a
//!   half-full batch — same reasoning as Storm's separate system stream.
//! * **Quiescence accounting**: `flow.sent` is incremented when an event
//!   enters a batch buffer (not when the batch hits the channel), so
//!   `sent == processed` can only hold when every buffer has drained —
//!   a buffered event can never be mistaken for quiescence. Workers
//!   flush their buffers before blocking on an empty input, so buffered
//!   events always make progress.
//! * **Shutdown**: when the source is exhausted the engine waits for
//!   global quiescence (sent == processed, all queues empty), then
//!   broadcasts `Shutdown` on the control plane; a worker receiving it
//!   runs `on_shutdown`, routes + flushes everything it emitted, and
//!   exits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::topology::builder::Topology;
use crate::topology::processor::Ctx;
use crate::topology::stream::Route;
use crate::topology::{Event, StreamId};

use super::metrics::EngineMetrics;

/// Data-plane channel payload: one micro-batch of events.
type Batch = Vec<Event>;

struct Mailbox {
    data: SyncSender<Batch>,
    ctrl: Sender<Event>,
}

/// Shared counters for quiescence detection.
struct Flow {
    sent: AtomicU64,
    processed: AtomicU64,
}

/// Multi-threaded engine.
pub struct ThreadedEngine {
    /// Bound of each data channel in *batches* (Storm max-pending
    /// analogue; worst-case in-flight events per edge is
    /// `queue_capacity × batch_size`).
    pub queue_capacity: usize,
    /// Data-plane micro-batch size: events buffered per (sender,
    /// dest-instance) edge before a channel send. 1 = unbatched
    /// (pre-batching per-event sends).
    pub batch_size: usize,
    /// Bench baseline only: deep-copy every broadcast delivery instead of
    /// the alloc-free shared clone (see `engine_throughput`).
    pub deep_copy_broadcast: bool,
}

impl Default for ThreadedEngine {
    fn default() -> Self {
        ThreadedEngine { queue_capacity: 1024, batch_size: 32, deep_copy_broadcast: false }
    }
}

/// Per-sender batch buffers: `bufs[dest processor][dest instance]`.
/// Thread-local by construction — every sender (worker thread or the
/// source pump) owns one, so buffering needs no synchronization at all.
struct OutBuffers {
    bufs: Vec<Vec<Batch>>,
}

impl OutBuffers {
    fn new(shape: &[usize]) -> Self {
        OutBuffers {
            bufs: shape.iter().map(|&p| (0..p).map(|_| Vec::new()).collect()).collect(),
        }
    }
}

/// Routing state shared by all worker threads.
struct Router {
    topology_streams: Vec<(usize, crate::topology::Grouping)>, // (dest processor, grouping)
    mailboxes: Vec<Vec<Mailbox>>,                              // [processor][instance]
    rr: Vec<AtomicU64>,                                        // per-stream shuffle cursor
    stream_events: Vec<AtomicU64>,
    stream_bytes: Vec<AtomicU64>,
    flow: Flow,
    batch_size: usize,
    deep_copy_broadcast: bool,
}

impl Router {
    /// Route one emission: metrics + `sent` are counted here, per logical
    /// delivery (a p-way broadcast counts p events and p × wire_bytes,
    /// exactly like the local engine). Data events are buffered per edge;
    /// control events go out immediately on the unbounded channel.
    fn route(&self, out: &mut OutBuffers, stream: StreamId, key: u64, event: Event) {
        let (dest, grouping) = self.topology_streams[stream.0];
        let par = self.mailboxes[dest].len();
        let bytes = event.wire_bytes() as u64;

        let mut rr_cursor = self.rr[stream.0].fetch_add(1, Ordering::Relaxed) as usize;
        match grouping.route(key, par, &mut rr_cursor) {
            Route::One(i) => self.send_one(out, stream.0, dest, i, bytes, event),
            Route::All => {
                // zero-copy fan-out: shared clones + one move (cf. local)
                for i in 0..par - 1 {
                    let copy = event.broadcast_clone(self.deep_copy_broadcast);
                    self.send_one(out, stream.0, dest, i, bytes, copy);
                }
                self.send_one(out, stream.0, dest, par - 1, bytes, event);
            }
        }
    }

    fn send_one(
        &self,
        out: &mut OutBuffers,
        stream: usize,
        dest: usize,
        i: usize,
        bytes: u64,
        event: Event,
    ) {
        // `sent` rises at buffer time so quiescence can never be observed
        // while an event sits in a batch buffer.
        self.flow.sent.fetch_add(1, Ordering::SeqCst);
        self.stream_events[stream].fetch_add(1, Ordering::Relaxed);
        self.stream_bytes[stream].fetch_add(bytes, Ordering::Relaxed);
        if event.is_control() {
            let _ = self.mailboxes[dest][i].ctrl.send(event);
        } else {
            let buf = &mut out.bufs[dest][i];
            buf.push(event);
            if buf.len() >= self.batch_size {
                // blocking send = backpressure
                let _ = self.mailboxes[dest][i].data.send(std::mem::take(buf));
            }
        }
    }

    /// Ship every non-empty batch buffer (stream-quiesce / shutdown flush).
    fn flush(&self, out: &mut OutBuffers) {
        for (dest, row) in out.bufs.iter_mut().enumerate() {
            for (i, buf) in row.iter_mut().enumerate() {
                if !buf.is_empty() {
                    let _ = self.mailboxes[dest][i].data.send(std::mem::take(buf));
                }
            }
        }
    }
}

impl ThreadedEngine {
    pub fn new(queue_capacity: usize) -> Self {
        ThreadedEngine { queue_capacity, ..Default::default() }
    }

    /// Set the data-plane micro-batch size (1 = per-event sends).
    pub fn with_batch(mut self, batch_size: usize) -> Self {
        self.batch_size = batch_size.max(1);
        self
    }

    /// Run the topology, injecting events from `source` on `entry`.
    /// `collect` receives each processor instance after shutdown for state
    /// extraction (same role as `on_drain` in the local engine, but only
    /// called once at the end — threads own the state meanwhile).
    pub fn run(
        &self,
        topology: &Topology,
        entry: StreamId,
        source: impl Iterator<Item = Event>,
        collect: impl FnMut(usize, usize, &dyn crate::topology::Processor),
    ) -> EngineMetrics {
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let mut metrics = EngineMetrics::new(topology.streams.len(), &shape);
        let started = Instant::now();

        // Build mailboxes.
        let mut receivers: Vec<Vec<(Receiver<Batch>, Receiver<Event>)>> = Vec::new();
        let mut mailboxes: Vec<Vec<Mailbox>> = Vec::new();
        for p in topology.processors.iter() {
            let mut mrow = Vec::new();
            let mut rrow = Vec::new();
            for _ in 0..p.parallelism {
                let (dtx, drx) = sync_channel(self.queue_capacity);
                let (ctx_, crx) = std::sync::mpsc::channel();
                mrow.push(Mailbox { data: dtx, ctrl: ctx_ });
                rrow.push((drx, crx));
            }
            mailboxes.push(mrow);
            receivers.push(rrow);
        }

        let router = Arc::new(Router {
            topology_streams: topology
                .streams
                .iter()
                .map(|s| (s.to.0, s.grouping))
                .collect(),
            mailboxes,
            rr: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_events: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            stream_bytes: topology.streams.iter().map(|_| AtomicU64::new(0)).collect(),
            flow: Flow { sent: AtomicU64::new(0), processed: AtomicU64::new(0) },
            batch_size: self.batch_size.max(1),
            deep_copy_broadcast: self.deep_copy_broadcast,
        });

        // Spawn worker threads.
        let done: Arc<Mutex<Vec<(usize, usize, Box<dyn crate::topology::Processor>, u64, u64)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for (pid, pdef) in topology.processors.iter().enumerate() {
            for (iid, (drx, crx)) in receivers[pid].drain(..).enumerate().collect::<Vec<_>>() {
                let mut proc_ = (pdef.factory)(iid);
                let router = Arc::clone(&router);
                let done = Arc::clone(&done);
                let par = pdef.parallelism;
                let shape = shape.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("{}-{}", pdef.name, iid))
                    .spawn(move || {
                        let mut busy_ns = 0u64;
                        let mut processed = 0u64;
                        let mut ctx = Ctx::new(iid, par);
                        let mut out = OutBuffers::new(&shape);

                        /// Process one delivered event; returns true on
                        /// Shutdown.
                        fn handle_one(
                            proc_: &mut Box<dyn crate::topology::Processor>,
                            ctx: &mut Ctx,
                            router: &Router,
                            out: &mut OutBuffers,
                            busy_ns: &mut u64,
                            processed: &mut u64,
                            event: Event,
                        ) -> bool {
                            let is_shutdown = matches!(event, Event::Shutdown);
                            let t0 = Instant::now();
                            if is_shutdown {
                                proc_.on_shutdown(ctx);
                            } else {
                                proc_.process(event, ctx);
                            }
                            *busy_ns += t0.elapsed().as_nanos() as u64;
                            *processed += 1;
                            // Route emissions BEFORE acknowledging the event:
                            // `sent` must rise before `processed` does, or the
                            // quiescence check could observe a false fixpoint.
                            for (s, k, e) in ctx.take() {
                                router.route(out, s, k, e);
                            }
                            router.flow.processed.fetch_add(1, Ordering::SeqCst);
                            is_shutdown
                        }

                        'outer: loop {
                            // Drain control channel with priority; data
                            // arrives in batches.
                            enum Work {
                                Ctrl(Event),
                                Data(Batch),
                            }
                            let work = loop {
                                match crx.try_recv() {
                                    Ok(d) => break Work::Ctrl(d),
                                    Err(_) => {}
                                }
                                match drx.try_recv() {
                                    Ok(b) => break Work::Data(b),
                                    Err(TryRecvError::Empty) => {
                                        // Input quiet: flush partial batches so
                                        // downstream (and the quiescence check)
                                        // never wait on our buffers, then block
                                        // with a timeout so control stays
                                        // responsive.
                                        router.flush(&mut out);
                                        let wait = std::time::Duration::from_micros(200);
                                        match drx.recv_timeout(wait) {
                                            Ok(b) => break Work::Data(b),
                                            Err(RecvTimeoutError::Timeout) => continue,
                                            Err(RecvTimeoutError::Disconnected) => {
                                                match crx.recv() {
                                                    Ok(d) => break Work::Ctrl(d),
                                                    Err(_) => break 'outer,
                                                }
                                            }
                                        }
                                    }
                                    Err(TryRecvError::Disconnected) => match crx.recv() {
                                        Ok(d) => break Work::Ctrl(d),
                                        Err(_) => break 'outer,
                                    },
                                }
                            };
                            match work {
                                Work::Ctrl(d) => {
                                    if handle_one(
                                        &mut proc_, &mut ctx, &router, &mut out,
                                        &mut busy_ns, &mut processed, d,
                                    ) {
                                        router.flush(&mut out);
                                        break 'outer;
                                    }
                                }
                                Work::Data(batch) => {
                                    for d in batch {
                                        if handle_one(
                                            &mut proc_, &mut ctx, &router, &mut out,
                                            &mut busy_ns, &mut processed, d,
                                        ) {
                                            router.flush(&mut out);
                                            break 'outer;
                                        }
                                    }
                                }
                            }
                        }
                        router.flush(&mut out);
                        done.lock().unwrap().push((pid, iid, proc_, busy_ns, processed));
                    })
                    .unwrap();
                handles.push(handle);
            }
        }

        // Pump the source from this thread (with its own batch buffers).
        let mut src_out = OutBuffers::new(&shape);
        for event in source {
            metrics.source_instances += 1;
            router.route(&mut src_out, entry, metrics.source_instances, event);
        }
        router.flush(&mut src_out);

        // Wait for quiescence: sent == processed, stable across two polls.
        // `sent` includes buffered events, so this can only fire once every
        // batch buffer in the system has drained.
        loop {
            let s1 = router.flow.sent.load(Ordering::SeqCst);
            let p1 = router.flow.processed.load(Ordering::SeqCst);
            if s1 == p1 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let s2 = router.flow.sent.load(Ordering::SeqCst);
                let p2 = router.flow.processed.load(Ordering::SeqCst);
                if s2 == p2 && s2 == s1 {
                    break;
                }
            } else {
                std::thread::yield_now();
            }
        }

        // Broadcast shutdown (control plane, unbatched) and join.
        for row in router.mailboxes.iter() {
            for mb in row.iter() {
                let _ = mb.ctrl.send(Event::Shutdown);
            }
        }
        for h in handles {
            let _ = h.join();
        }

        // Collect metrics + state.
        for i in 0..topology.streams.len() {
            metrics.streams[i].events = router.stream_events[i].load(Ordering::Relaxed);
            metrics.streams[i].bytes = router.stream_bytes[i].load(Ordering::Relaxed);
        }
        let mut collect = collect;
        for (pid, iid, proc_, busy, processed) in done.lock().unwrap().iter() {
            metrics.per_instance[*pid][*iid].busy_ns = *busy;
            metrics.per_instance[*pid][*iid].events_processed = *processed;
            collect(*pid, *iid, proc_.as_ref());
        }
        metrics.wall_ns = started.elapsed().as_nanos() as u64;
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::topology::{Grouping, Processor, TopologyBuilder};
    use std::sync::atomic::AtomicUsize;

    static TOTAL: AtomicUsize = AtomicUsize::new(0);

    struct Add;
    impl Processor for Add {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            TOTAL.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn inst_event(id: u64) -> Event {
        Event::Instance { id, inst: Instance::dense(vec![0.0], Label::None) }
    }

    #[test]
    fn all_events_processed_across_threads() {
        TOTAL.store(0, Ordering::SeqCst);
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("w", 4, |_| Box::new(Add));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let topo = b.build();
        let m =
            ThreadedEngine::default().run(&topo, entry, (0..1000).map(inst_event), |_, _, _| {});
        assert_eq!(TOTAL.load(Ordering::SeqCst), 1000);
        assert_eq!(m.source_instances, 1000);
        assert_eq!(m.streams[0].events, 1000);
    }

    /// Conservation must hold at every batch size, including the
    /// unbatched (`1`) and larger-than-stream (`4096`) extremes. Uses a
    /// per-test counter (not the shared TOTAL static) so it cannot race
    /// with `all_events_processed_across_threads` under parallel `cargo
    /// test`.
    #[test]
    fn batch_sizes_conserve_events() {
        struct CountInto(Arc<AtomicUsize>);
        impl Processor for CountInto {
            fn process(&mut self, _e: Event, _c: &mut Ctx) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        for batch in [1usize, 2, 32, 4096] {
            let count = Arc::new(AtomicUsize::new(0));
            let count2 = Arc::clone(&count);
            let mut b = TopologyBuilder::new("t");
            let a = b.add_processor("w", 3, move |_| Box::new(CountInto(Arc::clone(&count2))));
            let entry = b.stream("src", None, a, Grouping::Shuffle);
            let topo = b.build();
            let m = ThreadedEngine::default()
                .with_batch(batch)
                .run(&topo, entry, (0..777).map(inst_event), |_, _, _| {});
            assert_eq!(count.load(Ordering::SeqCst), 777, "batch={batch}");
            assert_eq!(m.streams[0].events, 777, "batch={batch}");
        }
    }

    #[test]
    fn feedback_loop_does_not_deadlock() {
        // a -> b (data), b -> a (control) with tiny queues: must terminate.
        struct Echo {
            data_out: Option<StreamId>,
            ctrl_out: Option<StreamId>,
        }
        impl Processor for Echo {
            fn process(&mut self, e: Event, ctx: &mut Ctx) {
                match e {
                    Event::Instance { id, .. } => {
                        if let Some(s) = self.data_out {
                            // forward as a data-plane attribute event
                            ctx.emit(
                                s,
                                id,
                                Event::Attribute {
                                    leaf: id,
                                    attr: 0,
                                    value: 0.0,
                                    class: 0,
                                    weight: 1.0,
                                },
                            );
                        }
                    }
                    Event::Attribute { .. } => {
                        if let Some(s) = self.ctrl_out {
                            // close the loop on the control plane
                            ctx.emit(
                                s,
                                0,
                                Event::Compute {
                                    leaf: 0,
                                    seq: 0,
                                    n_l: 0.0,
                                    class_counts: Arc::new(vec![]),
                                },
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
        let mut b = TopologyBuilder::new("loop");
        let a = b.add_processor("a", 1, |_| {
            Box::new(Echo { data_out: Some(StreamId(1)), ctrl_out: None })
        });
        let c = b.add_processor("c", 1, |_| {
            Box::new(Echo { data_out: None, ctrl_out: Some(StreamId(2)) })
        });
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream("a->c", Some(a), c, Grouping::Shuffle);
        b.stream("c->a", Some(c), a, Grouping::Shuffle);
        let topo = b.build();
        // a forwards Instance as Instance (data), c never generates more
        // data, so the loop closes only via control events.
        let eng = ThreadedEngine::new(2);
        let m = eng.run(&topo, entry, (0..500).map(inst_event), |_, _, _| {});
        assert_eq!(m.source_instances, 500);
    }
}
