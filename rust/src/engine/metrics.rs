//! Engine-side observability: per-stream and per-processor counters.

/// Per-stream traffic counters.
#[derive(Clone, Debug, Default)]
pub struct StreamMetrics {
    pub events: u64,
    pub bytes: u64,
}

/// Per-processor-instance execution counters.
#[derive(Clone, Debug, Default)]
pub struct InstanceMetrics {
    pub events_processed: u64,
    pub busy_ns: u64,
    /// High-water mark of *events resident in this instance's data
    /// queue* (threaded engine only; the local engine delivers
    /// synchronously and leaves this 0). With a bounded channel this is
    /// capped near `queue_capacity × batch_size` regardless of input
    /// size — the backpressure contract the engine tests assert.
    pub peak_queue_events: u64,
}

/// Data-plane flow-control counters (threaded engine; zero elsewhere).
#[derive(Clone, Debug, Default)]
pub struct FlowControlMetrics {
    /// Micro-batches shipped over data channels.
    pub batches_sent: u64,
    /// Sends that found the bounded channel full (each one is a
    /// backpressure event: the producer blocked — pinned mode — or
    /// parked the batch and stopped consuming input — stealing mode).
    pub backpressure_stalls: u64,
    /// Wall time producers spent blocked in full-channel sends (pinned
    /// mode; stealing mode never blocks, it re-schedules).
    pub backpressure_stall_ns: u64,
    /// Adaptive batcher grow steps (pressure → throughput mode).
    pub batch_grows: u64,
    /// Adaptive batcher shrink steps (idle → latency mode).
    pub batch_shrinks: u64,
    /// Work-stealing mode: task quanta executed by a non-home worker.
    pub steals: u64,
    /// Cluster peer mode: deliveries that found their worker↔worker
    /// link's in-flight window full (the coordinator blocked on the
    /// oldest outstanding reply before scheduling the slot).
    pub peer_link_stalls: u64,
    /// Wall time spent in those per-link stalls.
    pub peer_link_stall_ns: u64,
    /// Cluster pipelined injection (`with_inject_window` > 1): windowed
    /// `FRAME_INJECT` frames shipped — each one replaces `inject_events /
    /// inject_frames` per-event coordinator round trips on average.
    pub inject_frames: u64,
    /// Deliveries carried inside those injection frames. Every one still
    /// holds a unit of the destination worker's in-flight window (the
    /// credit-based backpressure contract is per event, not per frame).
    pub inject_events: u64,
    /// Batch buffers recycled through the arena (vs fresh allocations
    /// in `arena_allocs`).
    pub arena_reuses: u64,
    pub arena_allocs: u64,
}

/// One worker↔worker data link of the cluster engine's peer mode: who
/// talks to whom, how much, and how often the link's in-flight window
/// stalled the schedule. `from == to` is the self-link (a worker
/// delivering to an instance it owns without a coordinator round trip;
/// those frames never touch a socket but are counted for completeness).
#[derive(Clone, Debug, Default)]
pub struct PeerLinkMetrics {
    /// Sending worker index.
    pub from: u32,
    /// Receiving worker index.
    pub to: u32,
    /// Peer delivery frames shipped over this link.
    pub frames: u64,
    /// Socket bytes of those frames (length prefix included).
    pub bytes: u64,
    /// Logical `Event::wire_bytes` of the shipped deliveries (the
    /// quantity `StreamMetrics::bytes` counts — kept per link so the
    /// framing overhead per link is `bytes - wire_bytes`).
    pub wire_bytes: u64,
    /// Deliveries on this link that hit the per-link in-flight window.
    pub stalls: u64,
}

/// Socket-plane counters of the cluster engine (zero elsewhere). Unlike
/// [`StreamMetrics::bytes`] — which prices logical deliveries via
/// `Event::wire_bytes` identically on every engine — these count the
/// bytes and frames that actually crossed sockets, including protocol
/// framing and the coordinator↔worker round trips. The difference
/// between the two is exactly what the `samoa exp cluster` sweep feeds
/// back into `SimCostModel` validation.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// Worker processes/threads the run sharded instances across.
    pub workers: u64,
    /// Data-lane `Deliver` frames sent coordinator → workers.
    pub data_frames: u64,
    /// Control-lane frames sent coordinator → workers (control events,
    /// shutdown, collection — the priority lane).
    pub ctrl_frames: u64,
    /// `Emissions`/`Report` frames received back from workers.
    pub reply_frames: u64,
    /// Encoded bytes written to worker sockets (both lanes, framing
    /// included).
    pub tx_bytes: u64,
    /// Encoded bytes read back from worker sockets.
    pub rx_bytes: u64,
    /// Wall time the coordinator spent writing/flushing sockets.
    pub tx_ns: u64,
    /// Wall time the coordinator spent blocked reading replies.
    pub rx_ns: u64,
    /// Peer mode: schedule frames (`FRAME_PEER_SCHED`) the coordinator
    /// sent on control lanes (deterministic mode; each batches many slot
    /// tokens). Counted inside `ctrl_frames` too — this splits them out.
    pub sched_frames: u64,
    /// Peer mode: one entry per worker↔worker link that carried
    /// traffic, accumulated coordinator-side from the per-delivery
    /// descriptors in worker replies. Empty when peer mode is off.
    pub peer_links: Vec<PeerLinkMetrics>,
}

impl ClusterMetrics {
    /// Total frames that crossed the wire in either direction
    /// (coordinator lanes only; peer-link frames are in `peer_frames`).
    pub fn total_frames(&self) -> u64 {
        self.data_frames + self.ctrl_frames + self.reply_frames
    }

    /// Total socket bytes in either direction (coordinator lanes only).
    pub fn total_bytes(&self) -> u64 {
        self.tx_bytes + self.rx_bytes
    }

    /// Peer delivery frames shipped worker↔worker across all links.
    pub fn peer_frames(&self) -> u64 {
        self.peer_links.iter().map(|l| l.frames).sum()
    }

    /// Socket bytes of all peer-link frames (self-link bytes included,
    /// though those never cross a socket).
    pub fn peer_bytes(&self) -> u64 {
        self.peer_links.iter().map(|l| l.bytes).sum()
    }
}

/// Fault-tolerance counters (threaded + cluster engines with
/// checkpointing enabled; zero elsewhere). See [`crate::engine::checkpoint`]
/// for the snapshot format these count.
#[derive(Clone, Debug, Default)]
pub struct RecoveryMetrics {
    /// Checkpoint frames captured (one per instance per round).
    pub checkpoints: u64,
    /// Total encoded bytes of all captured checkpoint frames.
    pub checkpoint_bytes: u64,
    /// Injected or detected failures (killed tasks / dead workers).
    pub kills: u64,
    /// Instances rebuilt from a checkpoint (or fresh, when none existed).
    pub restores: u64,
    /// Events replayed from the bounded replay log after restores.
    pub replayed: u64,
    /// Events the bounded replay log had already evicted when a failure
    /// hit — the "documented replay tolerance": a recovered run is
    /// bit-identical iff this stays 0.
    pub replay_dropped: u64,
}

/// Aggregated engine metrics, returned by every engine run.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// Indexed by StreamId.
    pub streams: Vec<StreamMetrics>,
    /// `per_instance[processor][instance]`.
    pub per_instance: Vec<Vec<InstanceMetrics>>,
    /// Source instances injected.
    pub source_instances: u64,
    /// Wall-clock of the whole run.
    pub wall_ns: u64,
    /// Flow-control counters (threaded engine; default-zero elsewhere).
    pub flow: FlowControlMetrics,
    /// Socket-plane counters (cluster engine; default-zero elsewhere).
    pub cluster: ClusterMetrics,
    /// Fault-tolerance counters (checkpointing engines; zero elsewhere).
    pub recovery: RecoveryMetrics,
}

impl EngineMetrics {
    pub fn new(n_streams: usize, shape: &[usize]) -> Self {
        EngineMetrics {
            streams: vec![StreamMetrics::default(); n_streams],
            per_instance: shape
                .iter()
                .map(|&p| vec![InstanceMetrics::default(); p])
                .collect(),
            source_instances: 0,
            wall_ns: 0,
            flow: FlowControlMetrics::default(),
            cluster: ClusterMetrics::default(),
            recovery: RecoveryMetrics::default(),
        }
    }

    /// Source-instance throughput in instances/second of wall time.
    pub fn wall_throughput(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.source_instances as f64 / (self.wall_ns as f64 * 1e-9)
    }

    /// Total events across all streams.
    pub fn total_events(&self) -> u64 {
        self.streams.iter().map(|s| s.events).sum()
    }

    /// Total busy time of a logical processor across instances.
    pub fn busy_ns(&self, processor: usize) -> u64 {
        self.per_instance[processor].iter().map(|i| i.busy_ns).sum()
    }

    /// Busiest instance of a logical processor (load-imbalance probe).
    pub fn max_busy_ns(&self, processor: usize) -> u64 {
        self.per_instance[processor]
            .iter()
            .map(|i| i.busy_ns)
            .max()
            .unwrap_or(0)
    }

    /// Highest per-instance resident queue depth seen anywhere in the
    /// run, in events (the backpressure-bound probe).
    pub fn max_peak_queue_events(&self) -> u64 {
        self.per_instance
            .iter()
            .flatten()
            .map(|i| i.peak_queue_events)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::new(1, &[1]);
        m.source_instances = 1000;
        m.wall_ns = 1_000_000_000;
        assert!((m.wall_throughput() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn busy_aggregation() {
        let mut m = EngineMetrics::new(0, &[2]);
        m.per_instance[0][0].busy_ns = 10;
        m.per_instance[0][1].busy_ns = 30;
        assert_eq!(m.busy_ns(0), 40);
        assert_eq!(m.max_busy_ns(0), 30);
    }
}
