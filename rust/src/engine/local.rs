//! Local engine: sequential, deterministic execution of a topology —
//! SAMOA's local mode ("VHT local" / "MAMR" rows in the paper's tables).
//!
//! Semantics:
//! * After each injected source instance, the event graph is drained to
//!   quiescence (BFS order), so by default every split decision completes
//!   before the next instance arrives — exactly the paper's `local`
//!   algorithm with "no communication and feedback delays".
//! * Streams built with `stream_delayed(..., delay = d)` hold their events
//!   in a side buffer released only after `d` further source instances
//!   have been injected. Putting a delay on the LS→MA `local-result`
//!   stream reproduces the distributed feedback delay *deterministically*,
//!   which is how the accuracy experiments (Figs 4-7) distinguish
//!   `wok`/`wk(z)` from `local` without requiring wall-clock asynchrony.

use std::collections::VecDeque;
use std::time::Instant;

use crate::topology::builder::Topology;
use crate::topology::processor::{Ctx, Processor};
use crate::topology::stream::Route;
use crate::topology::Event;

use super::metrics::EngineMetrics;

/// A pending delivery: (processor, instance, event).
type Delivery = (usize, usize, Event);

/// Deterministic sequential engine.
pub struct LocalEngine {
    /// Instrument `process()` calls with wall-clock timing. Costs a timer
    /// syscall per event; enabled by the simtime engine, off by default.
    pub measure_busy: bool,
    /// Bench baseline only: force the pre-refactor deep copy on every
    /// broadcast delivery instead of the alloc-free shared clone. The
    /// `engine_throughput` bench uses this to report the before/after of
    /// the zero-copy data plane; leave `false` everywhere else.
    pub deep_copy_broadcast: bool,
    /// Source events injected per quiescence barrier. 1 (default) is the
    /// classic inject-drain-inject loop; `w > 1` routes a batch of `w`
    /// source events (each stamped with its own source count so delayed
    /// streams mature identically) before draining once — the golden
    /// reference for the cluster engine's pipelined injection at the
    /// same window. Delayed-stream release stays per event; only the
    /// drain cadence coarsens.
    pub inject_window: usize,
}

impl Default for LocalEngine {
    fn default() -> Self {
        LocalEngine { measure_busy: false, deep_copy_broadcast: false, inject_window: 1 }
    }
}

/// Materialized processor instances + routing state.
struct Runtime {
    /// instances[p][i]
    instances: Vec<Vec<Box<dyn Processor>>>,
    parallelism: Vec<usize>,
    /// Round-robin cursors per stream (shuffle grouping).
    rr: Vec<usize>,
}

impl LocalEngine {
    pub fn new() -> Self {
        Self::default()
    }

    // ------------------------------------------------------------------
    // Flow-control knob parity with `ThreadedEngine` (all no-ops here):
    // the local engine delivers every emission synchronously from one
    // queue, so there are no channels to bound, no batches to size and
    // no workers to schedule. Harness code can hold an engine choice in
    // one configuration path and apply the same knobs to either engine.
    // ------------------------------------------------------------------

    /// No-op (parity with [`super::ThreadedEngine::with_batch`]).
    pub fn with_batch(self, _batch_size: usize) -> Self {
        self
    }

    /// No-op (parity with [`super::ThreadedEngine::with_adaptive_batch`]).
    pub fn with_adaptive_batch(self, _cap: usize) -> Self {
        self
    }

    /// No-op (parity with [`super::ThreadedEngine::unbounded`]).
    pub fn unbounded(self) -> Self {
        self
    }

    /// No-op (parity with [`super::ThreadedEngine::with_workers`]).
    pub fn with_workers(self, _n: usize) -> Self {
        self
    }

    /// Inject up to `n` source events per quiescence barrier.
    pub fn with_inject_window(mut self, n: usize) -> Self {
        self.inject_window = n.max(1);
        self
    }

    /// Build from the unified [`super::EngineConfig`] (reads
    /// `measure_busy`, `deep_copy_broadcast` and `inject_window`; the
    /// sequential engine has no channels, workers or checkpoints, so the
    /// remaining knobs do not apply).
    pub fn from_config(cfg: &super::EngineConfig) -> Self {
        LocalEngine {
            measure_busy: cfg.measure_busy,
            deep_copy_broadcast: cfg.deep_copy_broadcast,
            inject_window: cfg.inject_window.max(1),
        }
    }

    /// Run `topology`, injecting `source` events on `entry`, and return
    /// engine metrics. `source` yields (key, event) pairs; each yielded
    /// event counts as one source instance for delay bookkeeping.
    pub fn run(
        &self,
        topology: &Topology,
        entry: crate::topology::StreamId,
        source: impl Iterator<Item = Event>,
        mut on_drain: impl FnMut(&mut [Vec<Box<dyn Processor>>]),
    ) -> EngineMetrics {
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let mut metrics = EngineMetrics::new(topology.streams.len(), &shape);
        let mut rt = Runtime {
            instances: topology
                .processors
                .iter()
                .map(|p| (0..p.parallelism).map(|i| (p.factory)(i)).collect())
                .collect(),
            parallelism: shape.clone(),
            rr: vec![0; topology.streams.len()],
        };

        // Delayed-stream buffers: (release_at_source_count, delivery)
        let mut delayed: VecDeque<(u64, Delivery)> = VecDeque::new();
        let mut queue: VecDeque<Delivery> = VecDeque::new();
        let started = Instant::now();

        let inject = self.inject_window.max(1);
        let mut batched = 0usize;
        for event in source {
            metrics.source_instances += 1;
            let now = metrics.source_instances;

            // Release matured delayed deliveries first (FIFO per maturity).
            while delayed.front().map_or(false, |(at, _)| *at <= now) {
                queue.push_back(delayed.pop_front().unwrap().1);
            }

            self.route(
                topology, &mut rt, &mut metrics, entry, 0, event, &mut queue, &mut delayed, now,
            );
            batched += 1;
            if batched >= inject {
                self.drain(topology, &mut rt, &mut metrics, &mut queue, &mut delayed, now);
                on_drain(&mut rt.instances);
                batched = 0;
            }
        }
        if batched > 0 {
            let now = metrics.source_instances;
            self.drain(topology, &mut rt, &mut metrics, &mut queue, &mut delayed, now);
            on_drain(&mut rt.instances);
        }

        // Flush: release all still-delayed events, drain, then shutdown.
        let fin = u64::MAX;
        while let Some((_, d)) = delayed.pop_front() {
            queue.push_back(d);
        }
        self.drain(topology, &mut rt, &mut metrics, &mut queue, &mut delayed, fin);
        for p in 0..rt.instances.len() {
            for i in 0..rt.instances[p].len() {
                let mut ctx = Ctx::new(i, rt.parallelism[p]);
                rt.instances[p][i].on_shutdown(&mut ctx);
                for (s, k, e) in ctx.take() {
                    self.route(
                        topology, &mut rt, &mut metrics, s, k, e, &mut queue, &mut delayed, fin,
                    );
                }
                // Drain between on_shutdown calls: emissions of an
                // earlier processor (e.g. a pipeline shard's final stats
                // delta) must be observable by a later processor's
                // on_shutdown (e.g. the stats aggregator's partial-round
                // flush) — otherwise shutdown stragglers are silently
                // dropped.
                while let Some((_, d)) = delayed.pop_front() {
                    queue.push_back(d);
                }
                self.drain(topology, &mut rt, &mut metrics, &mut queue, &mut delayed, fin);
            }
        }

        metrics.wall_ns = started.elapsed().as_nanos() as u64;
        on_drain(&mut rt.instances);
        metrics
    }

    /// Route one emission to the queue (or the delayed buffer).
    #[allow(clippy::too_many_arguments)]
    fn route(
        &self,
        topology: &Topology,
        rt: &mut Runtime,
        metrics: &mut EngineMetrics,
        stream: crate::topology::StreamId,
        key: u64,
        event: Event,
        queue: &mut VecDeque<Delivery>,
        delayed: &mut VecDeque<(u64, Delivery)>,
        now: u64,
    ) {
        let def = &topology.streams[stream.0];
        let dest = def.to.0;
        let par = rt.parallelism[dest];
        let sm = &mut metrics.streams[stream.0];

        let mut push = |d: Delivery, bytes: usize| {
            sm.events += 1;
            sm.bytes += bytes as u64;
            if def.delay == 0 || now == u64::MAX {
                queue.push_back(d);
            } else {
                delayed.push_back((now + def.delay as u64, d));
            }
        };

        match def.grouping.route(key, par, &mut rt.rr[stream.0]) {
            Route::One(i) => {
                let bytes = event.wire_bytes();
                push((dest, i, event), bytes);
            }
            Route::All => {
                // Zero-copy fan-out: `Event::clone` is pointer bumps (all
                // payloads are Arc-shared), and the last destination takes
                // the original by move. Wire bytes are still charged per
                // logical delivery — sharing is an in-process optimization,
                // not a change to the paper's cost model.
                let bytes = event.wire_bytes();
                for i in 0..par - 1 {
                    push((dest, i, event.broadcast_clone(self.deep_copy_broadcast)), bytes);
                }
                push((dest, par - 1, event), bytes);
            }
        }
    }

    /// Drain the immediate queue to quiescence.
    #[allow(clippy::too_many_arguments)]
    fn drain(
        &self,
        topology: &Topology,
        rt: &mut Runtime,
        metrics: &mut EngineMetrics,
        queue: &mut VecDeque<Delivery>,
        delayed: &mut VecDeque<(u64, Delivery)>,
        now: u64,
    ) {
        while let Some((p, i, event)) = queue.pop_front() {
            let mut ctx = Ctx::new(i, rt.parallelism[p]);
            if self.measure_busy {
                let t0 = Instant::now();
                rt.instances[p][i].process(event, &mut ctx);
                let im = &mut metrics.per_instance[p][i];
                im.busy_ns += t0.elapsed().as_nanos() as u64;
                im.events_processed += 1;
            } else {
                rt.instances[p][i].process(event, &mut ctx);
                metrics.per_instance[p][i].events_processed += 1;
            }
            for (s, k, e) in ctx.take() {
                self.route(topology, rt, metrics, s, k, e, queue, delayed, now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Grouping, TopologyBuilder};

    /// Counts events; forwards each to `out` if present.
    struct Counter {
        seen: u64,
        out: Option<crate::topology::StreamId>,
    }

    impl Processor for Counter {
        fn process(&mut self, e: Event, ctx: &mut Ctx) {
            self.seen += 1;
            if let (Some(s), Event::Instance { id, inst }) = (self.out, e) {
                ctx.emit(s, id, Event::Instance { id, inst });
            }
        }

        fn mem_bytes(&self) -> usize {
            self.seen as usize // smuggle the count out for assertions
        }
    }

    fn inst_event(id: u64) -> Event {
        Event::Instance {
            id,
            inst: crate::core::Instance::dense(vec![0.0], crate::core::instance::Label::None),
        }
    }

    #[test]
    fn pipeline_counts() {
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 1, |_| Box::new(Counter { seen: 0, out: None }));
        let c = b.add_processor("c", 3, |_| Box::new(Counter { seen: 0, out: None }));
        // wire: source -> a -> c (key grouped)
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        let _ac = b.stream("a->c", Some(a), c, Grouping::Key);
        let topo = {
            // re-create with forwarding now that we know the stream id
            let mut b = TopologyBuilder::new("t");
            let a2 = b.add_processor("a", 1, move |_| {
                Box::new(Counter { seen: 0, out: Some(crate::topology::StreamId(1)) })
            });
            let c2 = b.add_processor("c", 3, |_| Box::new(Counter { seen: 0, out: None }));
            let entry2 = b.stream("src", None, a2, Grouping::Shuffle);
            b.stream("a->c", Some(a2), c2, Grouping::Key);
            assert_eq!(entry2, entry);
            assert_eq!(a2, a);
            assert_eq!(c2, c);
            b.build()
        };

        let mut downstream_total = 0;
        let m = LocalEngine::new().run(
            &topo,
            entry,
            (0..100).map(inst_event),
            |inst| {
                downstream_total = inst[1].iter().map(|p| p.mem_bytes()).sum();
            },
        );
        assert_eq!(m.source_instances, 100);
        assert_eq!(m.streams[0].events, 100);
        assert_eq!(m.streams[1].events, 100);
        assert_eq!(downstream_total, 100);
    }

    #[test]
    fn broadcast_fans_out() {
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 4, |_| Box::new(Counter { seen: 0, out: None }));
        let entry = b.stream("src", None, a, Grouping::All);
        let topo = b.build();
        let mut total = 0;
        LocalEngine::new().run(&topo, entry, (0..10).map(inst_event), |inst| {
            total = inst[0].iter().map(|p| p.mem_bytes()).sum();
        });
        assert_eq!(total, 40); // 10 events × 4 instances
    }

    #[test]
    fn inject_window_coarsens_drain_cadence_only() {
        let build = || {
            let mut b = TopologyBuilder::new("t");
            let a = b.add_processor("a", 1, |_| Box::new(Counter { seen: 0, out: None }));
            let entry = b.stream("src", None, a, Grouping::Shuffle);
            (b.build(), entry)
        };

        let (topo, entry) = build();
        let mut drains = 0u32;
        let m = LocalEngine::new().with_inject_window(8).run(
            &topo,
            entry,
            (0..20).map(inst_event),
            |_| drains += 1,
        );
        assert_eq!(m.source_instances, 20);
        assert_eq!(m.streams[0].events, 20);
        // Two full batches (8, 16), one partial (20), one post-shutdown.
        assert_eq!(drains, 4);

        let (topo, entry) = build();
        let base = LocalEngine::new().run(&topo, entry, (0..20).map(inst_event), |_| {});
        assert_eq!(base.streams[0].events, m.streams[0].events);
        assert_eq!(base.streams[0].bytes, m.streams[0].bytes);
    }

    #[test]
    fn delayed_stream_defers_delivery() {
        // a forwards to b over a delayed stream; b's count must lag.
        struct Fwd(crate::topology::StreamId);
        impl Processor for Fwd {
            fn process(&mut self, e: Event, ctx: &mut Ctx) {
                if let Event::Instance { id, inst } = e {
                    ctx.emit(self.0, id, Event::Instance { id, inst });
                }
            }
        }
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 1, |_| Box::new(Fwd(crate::topology::StreamId(1))));
        let c = b.add_processor("c", 1, |_| Box::new(Counter { seen: 0, out: None }));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream_delayed("a->c", Some(a), c, Grouping::Shuffle, 5);
        let topo = b.build();

        let mut counts = Vec::new();
        let m = LocalEngine::new().run(&topo, entry, (0..10).map(inst_event), |inst| {
            counts.push(inst[1][0].mem_bytes());
        });
        // event emitted at source count k matures at k+5, so after the
        // n-th instance c has seen max(0, n-5) events
        assert_eq!(counts[4], 0);
        assert_eq!(counts[9], 5);
        assert_eq!(m.source_instances, 10);
        // final flush delivers everything
        assert_eq!(*counts.last().unwrap(), 10);
    }
}
