//! Checkpoint frames — the serialization layer of engine fault
//! tolerance.
//!
//! SAMOA itself delegates recovery to the underlying SPE; our in-tree
//! engines had none, so a killed task or worker lost the whole run.
//! This module gives every engine one shared snapshot format:
//! a processor's recoverable state is a list of tagged `f64` sections
//! (the flat-vector shape `MergeableState::snapshot` already produces),
//! encoded into one length-checked binary frame per `(processor,
//! instance)`.
//!
//! # Frame format
//!
//! ```text
//! frame   := version: u8 (=1)  n_sections: u32  section*
//! section := tag: u32  enc: u8  len: u32  payload: f64 × len
//! ```
//!
//! Integers and floats are fixed-width little-endian via the event
//! codec's writers ([`crate::topology::codec`]), and decoding goes
//! through the same bounds-checked [`Reader`] discipline: truncated
//! frames, bogus section counts and over-long length prefixes return
//! `Err`, never panic and never over-allocate.
//!
//! `enc` selects the payload encoding:
//!
//! * `0` — dense: `len` raw f64 words, bit-exact (NaN payload bits
//!   survive `to_le_bytes`).
//! * `1` — sparse: the PR 4 stats wire layout `[NaN, d, mask…, value ×
//!   m]` (see [`crate::preprocess::wire`]) where the mask flags every
//!   word whose *bit pattern* is non-zero. Only `+0.0` words are
//!   omitted, so decoding scatters into a zero vector and reproduces
//!   the original bit-for-bit (`-0.0` and NaNs are "changed" and ride
//!   in the value list).
//!
//! The explicit `enc` byte — rather than the NaN-tag dispatch the
//! stats path uses — exists because checkpoint sections may *begin*
//! with a legitimate NaN (e.g. a captured stats payload); sections pick
//! whichever encoding is smaller per [`wire::pick_smaller`]'s policy,
//! so compression never inflates a frame.
//!
//! # Section tags
//!
//! Tags below [`TAG_META_BASE`] are pipeline stage indices (the
//! `stats_snapshot` vector of stage `tag`); tags at or above it carry
//! processor-specific metadata (sync-policy counters, evaluator
//! measures, aggregator counts). Each `Processor::snapshot` impl
//! documents its own tag map; the frame layer treats tags as opaque.

use std::collections::HashMap;

use crate::preprocess::wire;
use crate::topology::codec::{put_f64, put_u32, put_u8, Reader};
use crate::Result;

/// Frame format version written by [`encode_frame`].
pub const VERSION: u8 = 1;

/// First tag reserved for non-stage (metadata) sections. Stage sections
/// use `tag == stage index`, which is always far below this.
pub const TAG_META_BASE: u32 = 0x0001_0000;

/// Upper bound accepted for one frame's section count and payload
/// lengths (guards the coordinator against corrupt frames exactly like
/// `codec::MAX_FRAME_BYTES` guards event decode).
pub const MAX_SECTION_LEN: usize = 1 << 24;

/// Encode tagged sections into one checkpoint frame. Each section's
/// payload is stored dense or sparse, whichever is smaller.
pub fn encode_frame(sections: &[(u32, Vec<f64>)]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u8(&mut out, VERSION);
    put_u32(&mut out, sections.len() as u32);
    for (tag, payload) in sections {
        let (enc, stored) = compress(payload);
        put_u32(&mut out, *tag);
        put_u8(&mut out, enc);
        put_u32(&mut out, stored.len() as u32);
        for v in &stored {
            put_f64(&mut out, *v);
        }
    }
    out
}

/// Decode a checkpoint frame back into `(tag, payload)` sections, in
/// frame order, with sparse sections expanded to their dense form.
pub fn decode_frame(frame: &[u8]) -> Result<Vec<(u32, Vec<f64>)>> {
    let mut r = Reader::new(frame);
    let version = r.u8()?;
    crate::ensure!(version == VERSION, "checkpoint: unknown frame version {version}");
    let n = r.u32()? as usize;
    crate::ensure!(n <= MAX_SECTION_LEN, "checkpoint: bogus section count {n}");
    let mut sections = Vec::with_capacity(n.min(64));
    for _ in 0..n {
        let tag = r.u32()?;
        let enc = r.u8()?;
        let len = r.u32()? as usize;
        crate::ensure!(
            len * 8 <= r.remaining() && len <= MAX_SECTION_LEN,
            "checkpoint: section length {len} exceeds frame remainder {}",
            r.remaining()
        );
        let mut stored = Vec::with_capacity(len);
        for _ in 0..len {
            stored.push(r.f64()?);
        }
        sections.push((tag, decompress(enc, stored)?));
    }
    crate::ensure!(r.remaining() == 0, "checkpoint: {} trailing bytes", r.remaining());
    Ok(sections)
}

/// Look up one section's payload by tag (first match).
pub fn section<'a>(sections: &'a [(u32, Vec<f64>)], tag: u32) -> Option<&'a [f64]> {
    sections.iter().find(|(t, _)| *t == tag).map(|(_, p)| p.as_slice())
}

/// Pick the smaller of the dense payload and its sparse re-encoding.
/// Returns `(enc, stored)`; bit-exact in both directions.
fn compress(payload: &[f64]) -> (u8, Vec<f64>) {
    let changed: Vec<bool> = payload.iter().map(|v| v.to_bits() != 0).collect();
    let m = changed.iter().filter(|&&c| c).count();
    // sparse = [NaN, d, mask…, values…]; skip building it when it
    // cannot win (pick_smaller's tie-goes-dense policy).
    let sparse_len = 2 + wire::mask_words(payload.len()) + m;
    if sparse_len >= payload.len() {
        return (0, payload.to_vec());
    }
    let mut sparse = Vec::with_capacity(sparse_len);
    sparse.push(f64::NAN);
    sparse.push(payload.len() as f64);
    wire::encode_mask(&mut sparse, &changed);
    for (v, c) in payload.iter().zip(&changed) {
        if *c {
            sparse.push(*v);
        }
    }
    (1, sparse)
}

/// Inverse of [`compress`]: expand a stored section to its dense form.
fn decompress(enc: u8, stored: Vec<f64>) -> Result<Vec<f64>> {
    match enc {
        0 => Ok(stored),
        1 => {
            crate::ensure!(
                stored.len() >= 2 && stored[0].is_nan(),
                "checkpoint: sparse section missing NaN tag"
            );
            let d = stored[1] as usize;
            crate::ensure!(
                stored[1] >= 0.0 && stored[1].fract() == 0.0 && d <= MAX_SECTION_LEN,
                "checkpoint: bogus sparse dimension {}",
                stored[1]
            );
            let words = wire::mask_words(d);
            crate::ensure!(stored.len() >= 2 + words, "checkpoint: sparse mask truncated");
            let cols = wire::decode_mask(&stored[2..2 + words], d)
                .ok_or_else(|| crate::anyhow!("checkpoint: sparse mask decode failed"))?;
            let values = &stored[2 + words..];
            crate::ensure!(
                values.len() == cols.len(),
                "checkpoint: sparse section has {} values for {} set columns",
                values.len(),
                cols.len()
            );
            let mut dense = vec![0.0; d];
            for (j, v) in cols.into_iter().zip(values) {
                dense[j] = *v;
            }
            Ok(dense)
        }
        other => crate::bail!("checkpoint: unknown section encoding {other}"),
    }
}

/// Coordinator-held store of the latest checkpoint frame per
/// `(processor, instance)`. Both engines write into one of these during
/// checkpoint rounds and read it back when respawning.
#[derive(Default, Debug, Clone)]
pub struct CheckpointStore {
    frames: HashMap<(usize, usize), Vec<u8>>,
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the latest frame for `(pid, iid)`, replacing any older one.
    pub fn put(&mut self, pid: usize, iid: usize, frame: Vec<u8>) {
        self.frames.insert((pid, iid), frame);
    }

    pub fn get(&self, pid: usize, iid: usize) -> Option<&[u8]> {
        self.frames.get(&(pid, iid)).map(|f| f.as_slice())
    }

    /// All held frames for processor `pid`, in instance order.
    pub fn instances_of(&self, pid: usize) -> Vec<(usize, &[u8])> {
        let mut v: Vec<(usize, &[u8])> = self
            .frames
            .iter()
            .filter(|((p, _), _)| *p == pid)
            .map(|((_, i), f)| (*i, f.as_slice()))
            .collect();
        v.sort_by_key(|(i, _)| *i);
        v
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Total bytes currently held (feeds the recovery metrics).
    pub fn bytes(&self) -> usize {
        self.frames.values().map(|f| f.len()).sum()
    }
}

/// The link a logged delivery originally traveled on. With the cluster
/// engine's peer data plane, deliveries reach a worker over several
/// links — the coordinator's lanes plus one peer link per sending
/// worker — and the replay log keys every entry by its origin so a
/// re-drive after a worker death can account (and meter) per link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogOrigin {
    /// Shipped by the coordinator (control or data lane).
    Coordinator,
    /// Shipped worker→worker by `sender`; the coordinator logged it
    /// from the sender's reply descriptor (recovery mode ships the
    /// payload in the descriptor precisely so this log stays complete).
    Peer { sender: usize },
}

/// One logged delivery awaiting a checkpoint that covers it.
#[derive(Clone, Debug)]
pub struct ReplayEntry<T> {
    pub item: T,
    pub origin: LogOrigin,
    /// The reply was consumed (and its emissions routed) pre-death; a
    /// re-drive of this entry rebuilds receiver state only.
    pub replied: bool,
}

/// Bounded replay log of one delivery *destination* (a cluster worker),
/// holding every delivery since the destination's last checkpoint with
/// its origin link. `base` is the absolute index of `entries.front()`
/// and only grows, so a stale reference can never alias a newer entry
/// after an overflow pop or a checkpoint clear.
#[derive(Clone, Debug, Default)]
pub struct ReplayLog<T> {
    entries: std::collections::VecDeque<ReplayEntry<T>>,
    base: u64,
}

impl<T> ReplayLog<T> {
    pub fn new() -> Self {
        ReplayLog { entries: std::collections::VecDeque::new(), base: 0 }
    }

    /// Append an entry, evicting the oldest when `cap` is reached.
    /// Returns the entry's absolute index and whether an eviction
    /// happened (an eviction voids the bit-identical recovery guarantee
    /// for this destination — count it in `replay_dropped`).
    pub fn push(&mut self, item: T, origin: LogOrigin, cap: usize) -> (u64, bool) {
        let mut dropped = false;
        if self.entries.len() >= cap.max(1) {
            self.entries.pop_front();
            self.base += 1;
            dropped = true;
        }
        let abs = self.base + self.entries.len() as u64;
        self.entries.push_back(ReplayEntry { item, origin, replied: false });
        (abs, dropped)
    }

    /// Mark the entry at absolute index `abs` as replied, if it is
    /// still in the log (it may have been evicted or cleared).
    pub fn mark_replied(&mut self, abs: u64) {
        if abs >= self.base {
            if let Some(entry) = self.entries.get_mut((abs - self.base) as usize) {
                entry.replied = true;
            }
        }
    }

    /// A checkpoint at full quiescence covers every logged delivery:
    /// clear them all (the base keeps growing).
    pub fn clear_covered(&mut self) {
        self.base += self.entries.len() as u64;
        self.entries.clear();
    }

    /// Take every entry for a re-drive, advancing the base past them.
    pub fn drain_for_redrive(&mut self) -> Vec<ReplayEntry<T>> {
        let entries: Vec<ReplayEntry<T>> = self.entries.drain(..).collect();
        self.base += entries.len() as u64;
        entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Rescale support: merge the per-shard stage sections of several
/// pipeline-shard checkpoint frames into one frame whose stage payloads
/// are the *merged* statistics, using `scratch` (a pipeline of the same
/// shape, freshly built) as the merge arena. Metadata sections
/// (`tag >= TAG_META_BASE`) are per-shard counters and do not survive a
/// rescale — the new shards restart them at the merged state's cut
/// point. The merged frame can be replicated to any number of new
/// shards: every `MergeableState` adopts a full snapshot exactly, so a
/// split simply hands each new shard the same global statistics.
pub fn merge_shard_frames(
    frames: &[&[u8]],
    scratch: &mut crate::preprocess::Pipeline,
) -> Result<Vec<u8>> {
    crate::ensure!(!frames.is_empty(), "checkpoint: no shard frames to merge");
    let stages = scratch.stateful_stages();
    let mut seen_first = vec![false; stages.len()];
    for frame in frames {
        let sections = decode_frame(frame)?;
        for (si, &stage) in stages.iter().enumerate() {
            let Some(payload) = section(&sections, stage as u32) else {
                crate::bail!("checkpoint: shard frame missing stage {stage} section");
            };
            if seen_first[si] {
                scratch.stats_merge(stage, payload);
            } else {
                scratch.stats_apply(stage, payload);
                seen_first[si] = true;
            }
        }
    }
    let merged: Vec<(u32, Vec<f64>)> = stages
        .iter()
        .map(|&stage| (stage as u32, scratch.stats_snapshot(stage).unwrap_or_default()))
        .collect();
    Ok(encode_frame(&merged))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips_dense_and_sparse() {
        let sections = vec![
            (0u32, vec![1.0, 0.0, -0.5, 3.25]),
            // mostly zeros → stored sparse
            (1u32, {
                let mut v = vec![0.0; 200];
                v[3] = 7.0;
                v[199] = -0.0;
                v
            }),
            (TAG_META_BASE, vec![]),
        ];
        let frame = encode_frame(&sections);
        let back = decode_frame(&frame).unwrap();
        assert_eq!(sections.len(), back.len());
        for ((t1, p1), (t2, p2)) in sections.iter().zip(&back) {
            assert_eq!(t1, t2);
            let b1: Vec<u64> = p1.iter().map(|x| x.to_bits()).collect();
            let b2: Vec<u64> = p2.iter().map(|x| x.to_bits()).collect();
            assert_eq!(b1, b2);
        }
    }

    #[test]
    fn nan_and_negative_zero_survive_compression() {
        let mut payload = vec![0.0; 100];
        payload[0] = f64::from_bits(0x7FF8_DEAD_BEEF_0001);
        payload[50] = -0.0;
        payload[99] = f64::from_bits(0xFFF8_0000_0000_0042);
        let (enc, stored) = compress(&payload);
        assert_eq!(enc, 1, "mostly-zero payload must pick the sparse form");
        let back = decompress(enc, stored).unwrap();
        let bits: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u64> = payload.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want);
    }

    #[test]
    fn every_truncation_is_rejected() {
        let frame = encode_frame(&[(0, vec![1.0, 2.0]), (7, vec![0.0; 64])]);
        for cut in 0..frame.len() {
            assert!(decode_frame(&frame[..cut]).is_err(), "cut at {cut}/{}", frame.len());
        }
    }

    #[test]
    fn corrupt_headers_are_rejected() {
        assert!(decode_frame(&[]).is_err(), "empty");
        assert!(decode_frame(&[9]).is_err(), "bad version");
        let mut frame = encode_frame(&[(0, vec![1.0])]);
        frame[9] = 7; // section enc byte
        assert!(decode_frame(&frame).is_err(), "unknown encoding");
        // section count far beyond the buffer must not allocate
        let mut bogus = vec![VERSION];
        bogus.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&bogus).is_err());
    }

    #[test]
    fn store_tracks_latest_per_instance() {
        let mut store = CheckpointStore::new();
        store.put(1, 0, vec![1, 2, 3]);
        store.put(1, 1, vec![4]);
        store.put(1, 0, vec![5, 6]);
        assert_eq!(store.get(1, 0), Some(&[5u8, 6][..]));
        assert_eq!(store.len(), 2);
        assert_eq!(store.bytes(), 3);
        let insts = store.instances_of(1);
        assert_eq!(insts.len(), 2);
        assert_eq!(insts[0].0, 0);
        assert_eq!(insts[1].0, 1);
    }
}
